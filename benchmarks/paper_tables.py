"""Paper-table benchmarks: one function per table/figure of the paper.

Scale: REPRO_BENCH_SCALE=small (default; 2^12 jobs × 2 workloads — CI
friendly) or full (paper scale: 2^16 jobs × 8 workloads, RAND averaged
over 4 repeats). All results land in experiments/repro/*.json and are
summarized by EXPERIMENTS.md §Repro.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import metrics, simulator, workload

OUT_DIR = "experiments/repro"
POLICIES = ("fifo", "lrtp", "rand", "fitgpp")


def _scale():
    full = os.environ.get("REPRO_BENCH_SCALE", "small") == "full"
    return {
        "n_jobs": 2 ** 16 if full else 2 ** 12,
        "n_workloads": 8 if full else 2,
        "rand_repeats": 4 if full else 1,
    }


def _run_policy(cfg: SimConfig, jobs_list, policy: str, repeats: int = 1):
    results = []
    for rep in range(repeats):
        for jobs in jobs_list:
            c = dataclasses.replace(cfg, policy=policy, seed=cfg.seed + rep)
            results.append(simulator.simulate(c, jobs))
    return metrics.pooled_tables(metrics.merge_results(results))


def _gen_workloads(cfg: SimConfig, n: int, trace: bool = False):
    gen = workload.generate_trace_proxy if trace else workload.generate
    return [gen(cfg, seed=cfg.seed + 1000 * i) for i in range(n)]


def table1_slowdowns() -> Dict:
    """Table 1 (+ Tables 2/3 from the same runs): synthetic workloads."""
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=1)
    jobs = _gen_workloads(cfg, sc["n_workloads"])
    out = {}
    for pol in POLICIES:
        reps = sc["rand_repeats"] if pol == "rand" else 1
        out[pol] = _run_policy(cfg, jobs, pol, reps)
    return out


def table4_preemption_counts() -> Dict:
    """Table 4: P = infinity preemption-count distribution."""
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=10 ** 9)
    jobs = _gen_workloads(cfg, sc["n_workloads"])
    return {pol: _run_policy(cfg, jobs, pol)
            for pol in ("lrtp", "rand", "fitgpp")}


def table5_trace() -> Dict:
    """Table 5: heavy-tailed trace PROXY (real PFN trace is private)."""
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"], load=1.3),
                    s=4.0, max_preemptions=1)
    jobs = _gen_workloads(cfg, sc["n_workloads"], trace=True)
    return {pol: _run_policy(cfg, jobs, pol) for pol in POLICIES}


def fig4_s_sensitivity() -> Dict:
    """Fig. 4: slowdowns vs s (GP relative weight)."""
    sc = _scale()
    out = {}
    for s in (0.0, 1.0, 2.0, 4.0, 8.0):
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                        s=s, max_preemptions=1)
        jobs = _gen_workloads(cfg, sc["n_workloads"])
        out[str(s)] = _run_policy(cfg, jobs, "fitgpp")
    return out


def fig5_p_sensitivity() -> Dict:
    """Fig. 5: slowdowns vs P (max preemptions per job)."""
    sc = _scale()
    out = {}
    for P in (1, 2, 4, 16, 10 ** 9):
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                        s=4.0, max_preemptions=P)
        jobs = _gen_workloads(cfg, sc["n_workloads"])
        out[str(P)] = _run_policy(cfg, jobs, "fitgpp")
    return out


def fig6_te_proportion() -> Dict:
    """Fig. 6: 95th-pct slowdowns vs TE fraction of the workload."""
    sc = _scale()
    out = {}
    for frac in (0.1, 0.3, 0.5, 0.7):
        wl = WorkloadSpec(n_jobs=sc["n_jobs"], te_fraction=frac)
        cfg = SimConfig(workload=wl, s=4.0, max_preemptions=1)
        jobs = _gen_workloads(cfg, sc["n_workloads"])
        out[str(frac)] = {pol: _run_policy(cfg, jobs, pol)
                          for pol in POLICIES}
    return out


def fig7_gp_scale() -> Dict:
    """Fig. 7: 95th-pct slowdowns vs GP length scale, s in {4, 8}."""
    sc = _scale()
    out = {}
    for scale in (1.0, 2.0, 4.0, 8.0):
        row = {}
        wl = WorkloadSpec(n_jobs=sc["n_jobs"], gp_scale=scale)
        for pol in POLICIES:
            cfg = SimConfig(workload=wl, s=4.0, max_preemptions=1)
            jobs = _gen_workloads(cfg, sc["n_workloads"])
            row[pol] = _run_policy(cfg, jobs, pol)
        for s in (8.0,):
            cfg = SimConfig(workload=wl, s=s, max_preemptions=1)
            jobs = _gen_workloads(cfg, sc["n_workloads"])
            row[f"fitgpp_s{s:g}"] = _run_policy(cfg, jobs, "fitgpp")
        out[str(scale)] = row
    return out


ALL = {
    "table1_slowdowns": table1_slowdowns,
    "table4_preemption_counts": table4_preemption_counts,
    "table5_trace": table5_trace,
    "fig4_s_sensitivity": fig4_s_sensitivity,
    "fig5_p_sensitivity": fig5_p_sensitivity,
    "fig6_te_proportion": fig6_te_proportion,
    "fig7_gp_scale": fig7_gp_scale,
}


def run_all(names=None) -> List[tuple]:
    os.makedirs(OUT_DIR, exist_ok=True)
    rows = []
    for name, fn in ALL.items():
        if names and name not in names:
            continue
        t0 = time.time()
        res = fn()
        dt = time.time() - t0
        with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
            json.dump(res, f, indent=1, default=float)
        derived = _headline(name, res)
        rows.append((name, dt * 1e6, derived))
    return rows


def _headline(name: str, res: Dict) -> str:
    try:
        if name == "table1_slowdowns":
            drop = 1 - res["fitgpp"]["TE"]["p95"] / res["fifo"]["TE"]["p95"]
            be = res["fitgpp"]["BE"]["p50"] / res["fifo"]["BE"]["p50"] - 1
            return f"TE_p95_drop={drop * 100:.1f}%;BE_p50_delta={be * 100:+.1f}%"
        if name == "table4_preemption_counts":
            r = res["lrtp"]["preempted_frac"] / \
                max(res["fitgpp"]["preempted_frac"], 1e-9)
            return f"lrtp_over_fitgpp_preemptions={r:.1f}x"
        if name == "table5_trace":
            be = res["fitgpp"]["BE"]["p50"] / res["fifo"]["BE"]["p50"] - 1
            return f"trace_BE_p50_delta={be * 100:+.1f}%"
        if name == "fig4_s_sensitivity":
            iv0 = res["0.0"]["intervals"]["p50"]
            iv4 = res["4.0"]["intervals"]["p50"]
            return f"interval_p50_s0={iv0:.1f};s4={iv4:.1f}"
        if name == "fig5_p_sensitivity":
            vals = [res[k]["TE"]["p95"] for k in res]
            return f"TE_p95_range={max(vals) - min(vals):.3f}"
        if name == "fig6_te_proportion":
            return ";".join(f"te{k}={res[k]['fitgpp']['TE']['p95']:.2f}"
                            for k in res)
        if name == "fig7_gp_scale":
            return ";".join(f"gp{k}={res[k]['fitgpp']['TE']['p95']:.2f}"
                            for k in res)
    except Exception as e:                                # noqa: BLE001
        return f"err:{e!r}"
    return ""
