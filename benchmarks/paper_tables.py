"""Paper-table benchmarks: one function per table/figure of the paper.

table1/table4/fig4/fig5/fig6/fig7 run through the sweep fabric
(``repro.core.sweep_fabric``, DESIGN.md §11): for each policy, every
(cell × workload × repeat) trial of a table is flattened into ONE
trial table — s, P and seed ride as traced per-trial columns — so the
whole table is a single compile and a single device dispatch, sharded
across every visible device (``mesh_for_sweep``; plain vmap on one
device, bit-identical either way). Pooling per cell happens on host
from the per-job outputs. table5 stays on the reference engine as the
cross-engine spot check.

Resched-interval percentiles from the fabric are the JAX engine's
last-gap statistic (one signal→resume gap per job — the same number
``api.run_experiment(engine="jax")`` reports), where the reference
engine pools every gap; preemption counts and slowdowns agree across
engines for the deterministic policies.

Scale: REPRO_BENCH_SCALE=small (default; 2^12 jobs × 2 workloads — CI
friendly), full (paper scale: 2^16 jobs × 8 workloads, RAND averaged
over 4 repeats) or tiny (2^9 jobs — smoke). All results land in
experiments/repro/*.json and are summarized by EXPERIMENTS.md §Repro.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import metrics, simulator, sweep_fabric, workload
from repro.core.types import JobSet

OUT_DIR = "experiments/repro"
POLICIES = ("fifo", "lrtp", "rand", "fitgpp")

# one trial of a table: (cell key, workload, s, P, sim seed)
Trial = Tuple[str, JobSet, float, int, int]


def _scale():
    scale = os.environ.get("REPRO_BENCH_SCALE", "small")
    return {
        "n_jobs": {"full": 2 ** 16, "tiny": 2 ** 9}.get(scale, 2 ** 12),
        "n_workloads": 8 if scale == "full" else 2,
        "rand_repeats": 4 if scale == "full" else 1,
    }


def _run_policy(cfg: SimConfig, jobs_list, policy: str, repeats: int = 1):
    """Reference-engine path (table5 cross-engine spot check)."""
    results = []
    for rep in range(repeats):
        for jobs in jobs_list:
            c = dataclasses.replace(cfg, policy=policy, seed=cfg.seed + rep)
            results.append(simulator.simulate(c, jobs))
    return metrics.pooled_tables(metrics.merge_results(results))


def _fabric_cells(policy: str, trials: Sequence[Trial],
                  cfg: SimConfig) -> Dict[str, Dict]:
    """Every trial of one table, one policy, ONE fabric run.

    s/P/seed are traced per-trial columns, so the full table compiles
    once per policy and dispatches once — sharded over the local
    device mesh when more than one device is visible. Returns the
    per-cell pooled tables (the paper pools its workloads per cell).
    """
    c = dataclasses.replace(cfg, policy=policy)
    table = sweep_fabric.build_table(
        [t[1] for t in trials],
        np.asarray([t[2] for t in trials], np.float32),
        np.asarray([t[3] for t in trials], np.int32),
        np.asarray([t[4] for t in trials], np.uint32))
    res = sweep_fabric.run_table(c, table, out="per_job", donate=False)
    return {key: sweep_fabric.pooled_tables(
                res, [i for i, t in enumerate(trials) if t[0] == key])
            for key in dict.fromkeys(t[0] for t in trials)}


def _gen_workloads(cfg: SimConfig, n: int, trace: bool = False):
    gen = workload.generate_trace_proxy if trace else workload.generate
    return [gen(cfg, seed=cfg.seed + 1000 * i) for i in range(n)]


def table1_slowdowns() -> Dict:
    """Table 1 (+ Tables 2/3 from the same runs): synthetic workloads."""
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=1)
    jobs = _gen_workloads(cfg, sc["n_workloads"])
    out = {}
    for pol in POLICIES:
        reps = sc["rand_repeats"] if pol == "rand" else 1
        trials = [("all", js, 4.0, 1, cfg.seed + rep)
                  for rep in range(reps) for js in jobs]
        out[pol] = _fabric_cells(pol, trials, cfg)["all"]
    return out


def table4_preemption_counts() -> Dict:
    """Table 4: P = infinity preemption-count distribution."""
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=10 ** 9)
    jobs = _gen_workloads(cfg, sc["n_workloads"])
    trials = [("all", js, 4.0, 10 ** 9, cfg.seed) for js in jobs]
    return {pol: _fabric_cells(pol, trials, cfg)["all"]
            for pol in ("lrtp", "rand", "fitgpp")}


def table5_trace() -> Dict:
    """Table 5: heavy-tailed trace PROXY (real PFN trace is private).

    Stays on the reference engine — the one per-policy loop kept as a
    cross-engine spot check against the fabric tables.
    """
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"], load=1.3),
                    s=4.0, max_preemptions=1)
    jobs = _gen_workloads(cfg, sc["n_workloads"], trace=True)
    return {pol: _run_policy(cfg, jobs, pol) for pol in POLICIES}


def fig4_s_sensitivity() -> Dict:
    """Fig. 4: slowdowns vs s (GP relative weight).

    Workload generation is independent of s, so every s-cell shares
    the same jobsets and the whole figure is one fabric run with a
    traced s column.
    """
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=1)
    jobs = _gen_workloads(cfg, sc["n_workloads"])
    trials = [(str(s), js, s, 1, cfg.seed)
              for s in (0.0, 1.0, 2.0, 4.0, 8.0) for js in jobs]
    return _fabric_cells("fitgpp", trials, cfg)


def fig5_p_sensitivity() -> Dict:
    """Fig. 5: slowdowns vs P (max preemptions per job)."""
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=1)
    jobs = _gen_workloads(cfg, sc["n_workloads"])
    trials = [(str(P), js, 4.0, P, cfg.seed)
              for P in (1, 2, 4, 16, 10 ** 9) for js in jobs]
    return _fabric_cells("fitgpp", trials, cfg)


def fig6_te_proportion() -> Dict:
    """Fig. 6: 95th-pct slowdowns vs TE fraction of the workload."""
    sc = _scale()
    fracs = (0.1, 0.3, 0.5, 0.7)
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=1)
    jobs = {frac: _gen_workloads(
                dataclasses.replace(cfg, workload=WorkloadSpec(
                    n_jobs=sc["n_jobs"], te_fraction=frac)),
                sc["n_workloads"])
            for frac in fracs}
    trials = [(str(frac), js, 4.0, 1, cfg.seed)
              for frac in fracs for js in jobs[frac]]
    per_pol = {pol: _fabric_cells(pol, trials, cfg) for pol in POLICIES}
    return {str(frac): {pol: per_pol[pol][str(frac)] for pol in POLICIES}
            for frac in fracs}


def fig7_gp_scale() -> Dict:
    """Fig. 7: 95th-pct slowdowns vs GP length scale, s in {4, 8}.

    The fitgpp run carries the s=8 cells as extra trials of the same
    table (traced s column), so the figure is still one fabric run
    per policy.
    """
    sc = _scale()
    scales = (1.0, 2.0, 4.0, 8.0)
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=1)
    jobs = {gp: _gen_workloads(
                dataclasses.replace(cfg, workload=WorkloadSpec(
                    n_jobs=sc["n_jobs"], gp_scale=gp)),
                sc["n_workloads"])
            for gp in scales}
    per_pol = {}
    for pol in POLICIES:
        trials = [(str(gp), js, 4.0, 1, cfg.seed)
                  for gp in scales for js in jobs[gp]]
        if pol == "fitgpp":
            trials += [(f"{gp}|s8", js, 8.0, 1, cfg.seed)
                       for gp in scales for js in jobs[gp]]
        per_pol[pol] = _fabric_cells(pol, trials, cfg)
    out = {}
    for gp in scales:
        row = {pol: per_pol[pol][str(gp)] for pol in POLICIES}
        row["fitgpp_s8"] = per_pol["fitgpp"][f"{gp}|s8"]
        out[str(gp)] = row
    return out


ALL = {
    "table1_slowdowns": table1_slowdowns,
    "table4_preemption_counts": table4_preemption_counts,
    "table5_trace": table5_trace,
    "fig4_s_sensitivity": fig4_s_sensitivity,
    "fig5_p_sensitivity": fig5_p_sensitivity,
    "fig6_te_proportion": fig6_te_proportion,
    "fig7_gp_scale": fig7_gp_scale,
}


def run_all(names=None) -> List[tuple]:
    os.makedirs(OUT_DIR, exist_ok=True)
    rows = []
    for name, fn in ALL.items():
        if names and name not in names:
            continue
        t0 = time.time()
        res = fn()
        dt = time.time() - t0
        with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
            json.dump(res, f, indent=1, default=float)
        derived = _headline(name, res)
        rows.append((name, dt * 1e6, derived))
    return rows


def _headline(name: str, res: Dict) -> str:
    try:
        if name == "table1_slowdowns":
            drop = 1 - res["fitgpp"]["TE"]["p95"] / res["fifo"]["TE"]["p95"]
            be = res["fitgpp"]["BE"]["p50"] / res["fifo"]["BE"]["p50"] - 1
            return f"TE_p95_drop={drop * 100:.1f}%;BE_p50_delta={be * 100:+.1f}%"
        if name == "table4_preemption_counts":
            r = res["lrtp"]["preempted_frac"] / \
                max(res["fitgpp"]["preempted_frac"], 1e-9)
            return f"lrtp_over_fitgpp_preemptions={r:.1f}x"
        if name == "table5_trace":
            be = res["fitgpp"]["BE"]["p50"] / res["fifo"]["BE"]["p50"] - 1
            return f"trace_BE_p50_delta={be * 100:+.1f}%"
        if name == "fig4_s_sensitivity":
            iv0 = res["0.0"]["intervals"]["p50"]
            iv4 = res["4.0"]["intervals"]["p50"]
            return f"interval_p50_s0={iv0:.1f};s4={iv4:.1f}"
        if name == "fig5_p_sensitivity":
            vals = [res[k]["TE"]["p95"] for k in res]
            return f"TE_p95_range={max(vals) - min(vals):.3f}"
        if name == "fig6_te_proportion":
            return ";".join(f"te{k}={res[k]['fitgpp']['TE']['p95']:.2f}"
                            for k in res)
        if name == "fig7_gp_scale":
            return ";".join(f"gp{k}={res[k]['fitgpp']['TE']['p95']:.2f}"
                            for k in res)
    except Exception as e:                                # noqa: BLE001
        return f"err:{e!r}"
    return ""
