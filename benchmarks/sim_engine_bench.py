"""Simulator-engine benchmarks: reference (numpy) tick vs event-driven
advancement, the JAX engine, and the vmapped sweep throughput that the
mesh distribution relies on.

``python -m benchmarks.sim_engine_bench --json`` additionally emits
``BENCH_sim_engine.json`` — tick vs event-driven throughput (jobs
simulated per second) on a sparse long-horizon workload, with the
bit-exactness of the two modes re-verified in-run (DESIGN.md §4) —
per-scenario timings over the full registered scenario suite
(``repro.scenarios``, DESIGN.md §5): the reference event engine plus
``jax_tick`` vs ``jax_event`` rows for the JAX engine's
event-compressed ``lax.while_loop`` (``SimConfig.time_mode``,
DESIGN.md §7; full-State bit-parity re-verified in-run across the
deterministic policy registry), an ``n_jobs`` scaling axis (256 /
1024 / 4096) tracking the dense-scale reference-vs-``jax_event``
trajectory, and the FitGpp score-path comparison on the JAX engine:
jnp vs the fused Pallas ``schedule_step`` kernel backend
(``SimConfig.score_backend``, DESIGN.md §6), with parity re-verified
in-run. The scenario-suite rows also carry a ``speedup_vs_ref``
gate: ``--check-parity`` fails if any scenario's ``jax_event`` row
is slower than the reference event engine. Configs and sweeps go
through the ``repro.api`` facade; TIMED regions call the engines
directly so the rows measure the engine, not jobset construction or
result normalization, and stay comparable across PRs.

Telemetry (DESIGN.md §8): every scenario row carries ``utilization``
and ``preempt_rate`` columns replayed from a traced reference run
(outside the timed region), plus a ``jax_event_traced`` timing with
its ``trace_overhead`` ratio — the untraced rows have the in-jit
event ring compiled OUT, so tracing-off stays structurally
zero-cost; enabled, expect ~1-1.7x on CPU (per-op dispatch floor
of the per-event emission, DESIGN.md §8 — the untraced
event-compressed step is itself only microseconds long).
``--smoke`` round-trips a tiny trace through both export
formats (``--trace-out`` saves the Perfetto JSON artifact) and
re-verifies the streamed-vs-monolithic bit-parity window;
``--profile DIR`` captures a ``jax.profiler.trace`` of one jitted
engine run.

Streaming (DESIGN.md §10): the JSON artifact opens with a ``stream``
suite — a >=10^5-job synthetic trace through the bounded-memory
macro-round engine (``core/stream``) at fixed slot-pool capacity,
run before everything else so its per-row ``max_rss_mb``
(``resource.getrusage`` high-water mark, platform-aware units; every
suite records it) demonstrates memory scaling with capacity, not
trace length, and an in-run ``parity`` key for the
streamed-vs-monolithic bit-parity window that ``--check-parity``
requires — followed by a ``stream_closed_loop`` suite replaying the
paper's §4.2 load-2.0 closed-loop regime through the same pool
(``StreamEngine(admission=True)``), with its own required ``parity``
key (admit ticks and scheduler outcome bit-exact with the monolithic
``closed_loop_submit_times`` pipeline) and ``n_spilled`` per row.

Sweep fabric (DESIGN.md §11): the artifact closes with a
``sweep_throughput`` suite — a ragged 4-scenario x 4-seed trial table
through ``core/sweep_fabric`` in a SUBPROCESS forced to an 8-device
host runtime (``--xla_force_host_platform_device_count`` must precede
jax init), timing configs/sec on 1 device (plain vmap) vs all 8
(``shard_map`` over ``mesh_for_sweep``). ``--check-parity`` requires
its ``parity`` row (sharded bitwise-equal to single-device), its
``compile_reuse`` row (a seed-only re-run adds no jit-cache entry —
the per-call-jit recompile bug stays fixed) and ``scaling_x >= 1``
(the sharded fabric must not lose to the vmap; sharding wins even on
one core because each shard's lockstep while_loop only runs to its
own slowest lane).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import resource
import subprocess
import sys
import time
from typing import Dict, List

import numpy as np

from repro import api, scenarios
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, policy_registry, sim_jax, simulator, workload
from repro.core.policy_registry import RNG_ALWAYS
from repro.core.workload import sparse_long_horizon


def _rss_divisor(platform: str = None) -> int:
    """``ru_maxrss`` unit per platform: kilobytes everywhere except
    macOS, where getrusage reports BYTES (the BSD lineage). Returns
    the divisor that yields MB."""
    platform = sys.platform if platform is None else platform
    return (1 << 20) if platform == "darwin" else (1 << 10)


def _rss_mb() -> float:
    """Process peak RSS in MB (platform-aware ``ru_maxrss`` units, see
    :func:`_rss_divisor`). The counter is a high-water mark — per-row
    values are peaks SO FAR, so rows that must attribute memory (the
    stream suites) run first."""
    return (resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
            / _rss_divisor())


def bench_tick_vs_event(n_jobs: int = 512, policy: str = "fitgpp",
                        n_nodes: int = 8, seed: int = 0) -> dict:
    # Config through the facade; the TIMED region is the engine alone
    # (no jobset build, no result-table normalization), so these rows
    # stay comparable with the numbers from earlier PRs.
    cfg = api.make_config(policy, n_nodes=n_nodes, seed=seed)
    js = sparse_long_horizon(n_jobs, seed=seed)

    t0 = time.perf_counter()
    res_tick = simulator.simulate(cfg, js, mode="tick")
    s_tick = time.perf_counter() - t0

    t0 = time.perf_counter()
    res_event = simulator.simulate(cfg, js, mode="event")
    s_event = time.perf_counter() - t0

    metrics.assert_result_parity(res_tick, res_event)
    return {
        "workload": {"kind": "sparse_long_horizon", "n_jobs": n_jobs,
                     "n_nodes": n_nodes, "policy": policy, "seed": seed,
                     "makespan_ticks": int(res_tick.makespan)},
        "tick": {"seconds": s_tick,
                 "jobs_per_sec": metrics.sim_throughput(res_tick, s_tick)},
        "event": {"seconds": s_event,
                  "jobs_per_sec": metrics.sim_throughput(res_event,
                                                         s_event)},
        "speedup": s_tick / max(s_event, 1e-12),
        "max_rss_mb": _rss_mb(),
        "parity": True,      # assert_result_parity would have raised
    }


def bench_stream(n_jobs: int = 100_000, capacity: int = 2048,
                 n_nodes: int = 8, policy: str = "fitgpp", seed: int = 0,
                 load: float = 0.5, parity_jobs: int = 400) -> Dict:
    """Streaming macro-round engine rows (``core/stream``, DESIGN.md
    §10): a >=10^5-job synthetic trace replayed through the fixed slot
    pool, with ``max_rss_mb`` per row. The bounded-memory claim is the
    near-flat peak RSS between the quarter-length and full-length rows
    at the SAME capacity — memory scales with the pool, not the trace
    — which is why this suite runs before everything else inflates the
    process high-water mark. ``parity`` re-verifies the bit-parity
    window in-run: streamed per-job results / makespan / rng state on
    a prefix equal the monolithic engine exactly
    (``stream.verify_prefix_parity``). Arrivals use sub-critical
    ``load`` so the open-loop backlog stays bounded; the first row
    absorbs the round-kernel compile."""
    from repro.core import stream
    cfg = api.make_config(policy, n_jobs=n_jobs, n_nodes=n_nodes,
                          seed=seed)
    cfg = dataclasses.replace(
        cfg, workload=dataclasses.replace(cfg.workload, load=load))
    # window sized for 5 recycling rounds with the score policy's
    # random fallback never firing (pool-size dependent, so fallback
    # would leave the bit-parity domain — verify_prefix_parity raises)
    diff = stream.verify_prefix_parity(cfg, n_jobs=parity_jobs,
                                       capacity=96, chunk=64)
    if diff:
        raise AssertionError(
            f"stream-vs-monolithic parity violated: {diff}")
    out: Dict = {
        "workload": {"kind": "stream_chunks", "n_nodes": n_nodes,
                     "policy": policy, "seed": seed, "load": load},
        "capacity": capacity, "parity": True,
        "parity_window_jobs": parity_jobs,
    }
    for label, nj in (("quarter", n_jobs // 4), ("full", n_jobs)):
        src = stream.JobSource(
            workload.stream_chunks(cfg, nj, chunk=4096))
        t0 = time.perf_counter()
        res = stream.StreamEngine(cfg, src, capacity=capacity).run()
        s = time.perf_counter() - t0
        out[label] = {"n_jobs": nj, "seconds": s,
                      "jobs_per_sec": nj / max(s, 1e-12),
                      "rounds": res.rounds, "max_live": res.max_live,
                      "capacity": res.capacity,
                      "makespan_ticks": res.makespan,
                      "fallback_count": res.fallback_count,
                      "max_rss_mb": _rss_mb()}
    return out


def bench_stream_closed_loop(n_jobs: int = 100_000, capacity: int = 2048,
                             n_nodes: int = 8, policy: str = "fitgpp",
                             seed: int = 0, load: float = 2.0,
                             parity_jobs: int = 400) -> Dict:
    """Streamed closed-loop admission rows (paper §4.2, DESIGN.md
    §10): the load-2.0 saturated regime through the macro-round engine
    with ``admission=True`` — the arrival process the paper's headline
    tables use, previously monolithic-only. The bounded-memory claim
    is the near-flat ``max_rss_mb`` between the quarter and full rows:
    the closed loop bounds the FIFO backlog, so saturated load streams
    without starving the pool (``n_spilled`` stays 0). ``parity``
    re-verifies the whole streamed path in-run — admit ticks AND
    scheduler outcome bit-exact with the monolithic
    ``closed_loop_submit_times`` + ``run_jit`` pipeline
    (``stream.verify_closed_loop_parity``; lrtp — rank policies stay
    in the deterministic domain at saturation, where score policies'
    random fallback fires)."""
    from repro.core import stream
    pcfg = api.make_config("lrtp", n_jobs=parity_jobs, n_nodes=n_nodes,
                           seed=seed)
    pcfg = dataclasses.replace(
        pcfg, workload=dataclasses.replace(pcfg.workload, load=load))
    diff = stream.verify_closed_loop_parity(pcfg, n_jobs=parity_jobs,
                                            capacity=160, chunk=64)
    if diff:
        raise AssertionError(
            f"streamed closed-loop parity violated: {diff}")
    cfg = api.make_config(policy, n_jobs=n_jobs, n_nodes=n_nodes,
                          seed=seed)
    cfg = dataclasses.replace(
        cfg, workload=dataclasses.replace(cfg.workload, load=load))
    out: Dict = {
        "workload": {"kind": "stream_chunks+closed_loop",
                     "n_nodes": n_nodes, "policy": policy, "seed": seed,
                     "load": load},
        "capacity": capacity, "parity": True,
        "parity_window_jobs": parity_jobs,
    }
    for label, nj in (("quarter", n_jobs // 4), ("full", n_jobs)):
        src = stream.JobSource(
            workload.stream_chunks(cfg, nj, chunk=4096))
        t0 = time.perf_counter()
        res = stream.StreamEngine(cfg, src, capacity=capacity,
                                  admission=True).run()
        s = time.perf_counter() - t0
        out[label] = {"n_jobs": nj, "seconds": s,
                      "jobs_per_sec": nj / max(s, 1e-12),
                      "rounds": res.rounds, "max_live": res.max_live,
                      "capacity": res.capacity,
                      "makespan_ticks": res.makespan,
                      "fallback_count": res.fallback_count,
                      "n_spilled": res.n_spilled,
                      "spill_peak": res.spill_peak,
                      "max_rss_mb": _rss_mb()}
    return out


def _time_jax(cfg: SimConfig, jobs, seed: int, time_mode: str,
              trace: bool = False):
    """Seconds for one jitted run, compile excluded."""
    st = sim_jax.run_jit(cfg, jobs, seed, time_mode=time_mode,
                         trace=trace)                       # compile
    st.t.block_until_ready()
    t0 = time.perf_counter()
    st = sim_jax.run_jit(cfg, jobs, seed, time_mode=time_mode, trace=trace)
    st.t.block_until_ready()
    return time.perf_counter() - t0, st


def bench_jax_tick_vs_event(cfg: SimConfig, js, seed: int) -> Dict:
    """JAX-engine tick vs event-compressed rows for one jobset: timing
    under ``cfg.policy`` (compile excluded), full-State tick-vs-event
    bit-parity re-verified in-run for EVERY registered deterministic
    (non-rng-driven) dual-backend policy."""
    jobs = sim_jax.jobs_from_jobset(js)
    s_tick, st_tick = _time_jax(cfg, jobs, seed, "tick")
    s_event, st_event = _time_jax(cfg, jobs, seed, "event")
    parity = not sim_jax.state_diff_fields(st_tick, st_event)
    if not parity:
        raise AssertionError(
            f"jax tick-vs-event parity violated ({cfg.policy})")
    parity_policies = [sp.name for sp in policy_registry.all_policies()
                       if sp.dual_backend and sp.rng != RNG_ALWAYS]
    for name in parity_policies:
        if name == cfg.policy:
            continue
        pcfg = dataclasses.replace(cfg, policy=name)
        a = sim_jax.run_jit(pcfg, jobs, seed, time_mode="tick")
        b = sim_jax.run_jit(pcfg, jobs, seed, time_mode="event")
        parity = parity and not sim_jax.state_diff_fields(a, b)
        if not parity:
            raise AssertionError(
                f"jax tick-vs-event parity violated ({name})")
    # tracing cost: same jitted event run with the in-jit ring buffer
    # compiled IN (untraced rows above have it compiled OUT — tracing
    # off is structurally zero-cost, not just cheap)
    s_traced, st_traced = _time_jax(cfg, jobs, seed, "event", trace=True)
    return {
        "jax_tick": {"seconds": s_tick,
                     "jobs_per_sec": js.n / max(s_tick, 1e-12)},
        "jax_event": {"seconds": s_event,
                      "jobs_per_sec": js.n / max(s_event, 1e-12)},
        "jax_event_traced": {"seconds": s_traced,
                             "jobs_per_sec": js.n / max(s_traced, 1e-12)},
        "trace_overhead": s_traced / max(s_event, 1e-12),
        "fallback_count": int(st_event.fallback_count),
        "trace_overflow": int(sim_jax.trace_overflow(st_traced)),
        "jax_speedup": s_tick / max(s_event, 1e-12),
        "parity": parity,         # computed; False never reaches here
        "parity_policies": parity_policies,
    }


def bench_scenario_suite(n_jobs: int = 256, n_nodes: int = 8,
                         policy: str = "fitgpp", seed: int = 0) -> Dict:
    """Per-scenario engine rows for every registered scenario + trace
    adapter (trace fixtures keep their native job counts): the
    reference event engine, plus ``jax_tick`` vs ``jax_event`` rows
    (``SimConfig.time_mode``) with tick-vs-event bit-parity re-verified
    across the deterministic policy registry. Gang scenarios
    (gang-heavy, gang-trace-mix, the trace adapters) run the JAX
    engine like everything else. Jobset construction stays OUTSIDE
    the timed regions — these rows measure the engines.

    Each row also carries telemetry columns — time-weighted mean
    ``utilization`` and ``preempt_rate`` (signals per simulated
    minute), replayed from a traced reference run OUTSIDE the timed
    region — plus the tracing-cost columns from
    :func:`bench_jax_tick_vs_event` (``jax_event_traced``,
    ``trace_overhead``, ``fallback_count``, ``trace_overflow``)."""
    from repro.obs import timeseries
    from repro.core.policy_registry import get_policy
    cfg = api.make_config(policy, n_jobs=n_jobs, n_nodes=n_nodes,
                          seed=seed)
    out = {}
    for name in scenarios.scenario_names():
        js = scenarios.build(name, cfg)
        t0 = time.perf_counter()
        res = simulator.simulate(cfg, js, mode="event")
        s = time.perf_counter() - t0
        out[name] = {"n_jobs": js.n, "seconds": s,
                     "n_gangs": int((np.asarray(js.n_nodes) > 1).sum()),
                     "jobs_per_sec": metrics.sim_throughput(res, s),
                     "makespan_ticks": int(res.makespan)}
        tres = simulator.simulate(cfg, js, mode="event", trace=True)
        ts = timeseries.compute_timeseries(
            tres.trace, n_nodes=cfg.cluster.n_nodes, is_te=js.is_te,
            preemptive=get_policy(cfg.policy).preemptive)
        out[name]["utilization"] = ts.mean_utilization()
        out[name]["preempt_rate"] = ts.preempt_rate
        out[name].update(bench_jax_tick_vs_event(cfg, js, seed))
        out[name]["speedup_vs_ref"] = s / max(
            out[name]["jax_event"]["seconds"], 1e-12)
        out[name]["max_rss_mb"] = _rss_mb()
    return out


def bench_njobs_scaling(sizes=(256, 1024, 4096), n_nodes: int = 8,
                        policy: str = "fitgpp", seed: int = 0) -> Dict:
    """Dense-scale trajectory rows: reference event engine vs
    ``jax_event`` jobs/sec for every SIZED registered scenario at each
    ``n_jobs`` (trace fixtures keep their native job counts and are
    skipped here — their rows live in the scenario suite). These are
    the rows the ≥5x-at-1k+ target is defined on; on the CPU container
    they time interpret-mode kernels, so they record the honest CPU
    trajectory rather than the TPU target."""
    out: Dict = {}
    for n in sizes:
        cfg = api.make_config(policy, n_jobs=n, n_nodes=n_nodes, seed=seed)
        rows: Dict = {}
        for name in scenarios.scenario_names():
            js = scenarios.build(name, cfg)
            if js.n != n:              # trace fixture: native job count
                continue
            t0 = time.perf_counter()
            res = simulator.simulate(cfg, js, mode="event")
            s_ref = time.perf_counter() - t0
            jobs = sim_jax.jobs_from_jobset(js)
            s_jax, _ = _time_jax(cfg, jobs, seed, "event")
            rows[name] = {
                "ref_seconds": s_ref,
                "jax_event_seconds": s_jax,
                "ref_jobs_per_sec": metrics.sim_throughput(res, s_ref),
                "jax_jobs_per_sec": js.n / max(s_jax, 1e-12),
                "speedup_vs_ref": s_ref / max(s_jax, 1e-12),
            }
        out[str(n)] = rows
    return out


def bench_score_backend(n_jobs: int = 192, n_nodes: int = 84,
                        seed: int = 0) -> Dict:
    """JAX-engine FitGpp with the schedule pass on jnp vs on the fused
    Pallas ``schedule_step`` kernel (``SimConfig.score_backend``;
    interpret mode off-TPU), compile excluded, parity re-verified."""
    cfg = SimConfig(cluster=ClusterSpec(n_nodes=n_nodes),
                    workload=WorkloadSpec(n_jobs=n_jobs),
                    policy="fitgpp", seed=seed)
    js = workload.generate(cfg)
    jobs = sim_jax.jobs_from_jobset(js)
    out: Dict = {"workload": {"n_jobs": n_jobs, "n_nodes": n_nodes,
                              "seed": seed}}
    finishes = {}
    for backend in ("jnp", "pallas"):
        bcfg = dataclasses.replace(cfg, score_backend=backend)
        st = sim_jax.run_jit(bcfg, jobs, seed)     # compile
        st.t.block_until_ready()
        t0 = time.perf_counter()
        st = sim_jax.run_jit(bcfg, jobs, seed)
        st.t.block_until_ready()
        s = time.perf_counter() - t0
        finishes[backend] = np.asarray(st.finish)
        out[backend] = {"seconds": s, "jobs_per_sec": n_jobs / max(s, 1e-12)}
    parity = bool((finishes["jnp"] == finishes["pallas"]).all())
    if not parity:
        raise AssertionError("score-backend parity violated: jnp vs pallas")
    out["parity"] = parity
    out["max_rss_mb"] = _rss_mb()
    return out


SWEEP_DEVICES = 8        # forced host device count for the sweep suite
SWEEP_TRIALS = 16        # 4 scenarios x 4 seeds


def _sweep_child(n_devices: int) -> Dict:
    """Child-process body of :func:`bench_sweep_throughput` — runs
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
    flag must precede jax initialization, hence the subprocess). One
    ragged 4-scenario x 4-seed trial table through the sweep fabric on
    1 device (plain vmap) vs all N (``shard_map``), best-of-2 timed
    runs each, compile excluded: configs/sec per device count, bitwise
    parity, the >=1x scaling gate, and the compile-reuse lock (a
    seed-only re-run must not add a jit-cache entry — the old
    per-call-jit recompile bug)."""
    import jax

    from repro.core import sweep_fabric as fabric

    if len(jax.devices()) != n_devices:
        raise AssertionError(
            f"sweep child expected {n_devices} devices, found "
            f"{len(jax.devices())} — XLA_FLAGS not applied?")
    cfg = api.make_config("fitgpp", n_jobs=256, n_nodes=8, seed=0)
    names = ("te-flood", "long-tail-be", "burst-storm", "diurnal")
    n_seeds = SWEEP_TRIALS // len(names)
    jobsets = [scenarios.build(nm, dataclasses.replace(cfg, seed=sd))
               for nm in names for sd in range(n_seeds)]
    seeds = np.arange(SWEEP_TRIALS, dtype=np.uint32)
    table = fabric.build_table(jobsets, 4.0, 1, seeds)
    out: Dict = {
        "workload": {"scenarios": list(names), "n_seeds": n_seeds,
                     "n_jobs": 256, "n_nodes": 8, "policy": "fitgpp"},
        "n_trials": SWEEP_TRIALS, "n_devices": n_devices,
    }
    results, cps = {}, {}
    for d in (1, n_devices):
        # devices=1 resolves to the plain single-device vmap
        # (mesh_for_sweep returns None), devices=N to the shard_map
        # fabric — NOT mesh=None, which means "auto-pick all devices"
        res = fabric.run_table(cfg, table, devices=d,
                               donate=False)              # compile
        if res.n_devices != d:
            raise AssertionError(
                f"sweep child asked for {d} devices, fabric used "
                f"{res.n_devices}")
        best = 1e9
        for _ in range(2):
            t0 = time.perf_counter()
            res = fabric.run_table(cfg, table, devices=d, donate=False)
            best = min(best, time.perf_counter() - t0)
        results[d] = res
        cps[d] = SWEEP_TRIALS / best
        out[f"devices_{d}"] = {"seconds": best,
                               "configs_per_sec": cps[d],
                               "sharded": d > 1}
    diff = [k for k in results[1].stats
            if not np.array_equal(results[1].stats[k],
                                  results[n_devices].stats[k],
                                  equal_nan=True)]
    if diff:
        raise AssertionError(
            f"sweep sharded-vs-single parity violated: {diff}")
    out["parity"] = True
    out["scaling_x"] = cps[n_devices] / cps[1]
    # compile-reuse lock: fresh seed values, same shapes -> the cached
    # runner must serve the run without a new jit-cache entry
    before = fabric.compile_stats()
    table2 = fabric.build_table(jobsets, 4.0, 1, seeds + 1000)
    fabric.run_table(cfg, table2, devices=n_devices, donate=False)
    after = fabric.compile_stats()
    if after != before:
        raise AssertionError(
            f"sweep compile-reuse violated: {before} -> {after}")
    out["compile_reuse"] = True
    out["compile_stats"] = after
    out["max_rss_mb"] = _rss_mb()
    return out


def bench_sweep_throughput(n_devices: int = SWEEP_DEVICES) -> Dict:
    """Sweep-fabric throughput suite (configs/sec): spawns
    :func:`_sweep_child` in a subprocess with a FORCED ``n_devices``
    host-device count (``--xla_force_host_platform_device_count`` only
    takes effect before jax initializes, which has already happened in
    this process). The child's JSON row is returned verbatim; its
    in-run assertions (bitwise sharded-vs-single parity, compile
    reuse) surface here as a raised error with the child's stderr."""
    src = os.path.dirname(os.path.dirname(os.path.abspath(api.__file__)))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{n_devices}").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sim_engine_bench",
         "--sweep-child", str(n_devices)],
        capture_output=True, text=True, env=env, cwd=root, timeout=1200)
    if proc.returncode != 0:
        raise AssertionError(
            f"sweep_throughput child failed:\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.splitlines()[-1])


def _falsy_parity(obj, path: str = "") -> List[str]:
    bad = []
    if isinstance(obj, dict):
        for k, v in obj.items():
            here = f"{path}.{k}" if path else str(k)
            if k == "parity" and not v:
                bad.append(here)
            bad.extend(_falsy_parity(v, here))
    return bad


def check_parity_rows(out: dict) -> List[str]:
    """Problems with the artifact's parity rows: any falsy value, AND
    any row where the expected ``parity`` key is missing entirely.

    The benchmark raises in-run when a comparison fails, so a false
    value should never be emitted — the real hazard is a refactor that
    stops RUNNING a check and drops (or never writes) the key. The CI
    gate therefore requires the key to be present on the tick-vs-event
    row, on every scenario-suite row, and on the score-backend row."""
    bad = _falsy_parity(out)
    if "parity" not in out:
        bad.append("missing: parity (reference tick-vs-event)")
    if "parity" not in out.get("stream", {}):
        bad.append("missing: stream.parity (streamed-vs-monolithic "
                   "bit-parity window)")
    if "parity" not in out.get("stream_closed_loop", {}):
        bad.append("missing: stream_closed_loop.parity (streamed "
                   "closed-loop admission bit-parity window)")
    suite = out.get("scenario_suite")
    if not suite:
        bad.append("missing: scenario_suite")
    else:
        bad.extend(f"missing: scenario_suite.{name}.parity"
                   for name, row in suite.items() if "parity" not in row)
    if "parity" not in out.get("score_backend", {}):
        bad.append("missing: score_backend.parity")
    sweep_row = out.get("sweep_throughput", {})
    if "parity" not in sweep_row:
        bad.append("missing: sweep_throughput.parity (sharded vs "
                   "single-device bitwise)")
    if not sweep_row.get("compile_reuse"):
        bad.append("missing/false: sweep_throughput.compile_reuse "
                   "(seed-only re-run must not recompile)")
    return bad


SPEED_TOL = 1.0          # jax_event must not lose to the reference


def check_speed_rows(out: dict) -> List[str]:
    """Scenario-suite rows where ``jax_event`` is slower than the
    reference event engine: the JAX engine must not LOSE to numpy on
    any registered scenario at the suite size (this is the gate the
    diurnal / trace-proxy regressions used to fail). The scaling rows
    track the dense trajectory and are recorded, not gated — the
    interpret-mode CPU numbers at 4096 are not the TPU target."""
    bad = []
    for name, row in (out.get("scenario_suite") or {}).items():
        sp = row.get("speedup_vs_ref")
        if sp is None:
            bad.append(f"missing: scenario_suite.{name}.speedup_vs_ref")
        elif sp < SPEED_TOL:
            bad.append(f"slow: scenario_suite.{name} jax_event at "
                       f"{sp:.2f}x vs reference")
    if "njobs_scaling" not in out:
        bad.append("missing: njobs_scaling")
    sx = out.get("sweep_throughput", {}).get("scaling_x")
    if sx is None:
        bad.append("missing: sweep_throughput.scaling_x")
    elif sx < SPEED_TOL:
        bad.append(f"slow: sweep_throughput sharded fabric at "
                   f"{sx:.2f}x vs single-device vmap")
    return bad


def emit_json(path: str = "BENCH_sim_engine.json") -> dict:
    # the stream suites run FIRST: their max_rss_mb rows carry the
    # bounded-memory claim and ru_maxrss is a process-wide high-water
    # mark, so nothing may inflate the peak before them
    stream_rows = bench_stream()
    stream_cl_rows = bench_stream_closed_loop()
    out = bench_tick_vs_event()
    out["stream"] = stream_rows
    out["stream_closed_loop"] = stream_cl_rows
    out["scenario_suite"] = bench_scenario_suite()
    out["njobs_scaling"] = bench_njobs_scaling()
    out["score_backend"] = bench_score_backend()
    # subprocess (own forced-8-device jax runtime): parent RSS rows
    # stay unaffected
    out["sweep_throughput"] = bench_sweep_throughput()
    bad = check_parity_rows(out) + check_speed_rows(out)
    if bad:
        raise AssertionError(f"bench gates failed: {bad}")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def smoke(n_jobs: int = 64, seed: int = 0,
          trace_out: str = None) -> None:
    """CI fast-lane smoke: one tiny scenario through the reference
    engine and the JAX engine with the FUSED score backend
    (``score_backend="pallas"`` routes the whole schedule pass through
    the Pallas ``schedule_step`` kernel), asserting jnp-vs-pallas
    full-State parity, PLUS the trace round-trip: traced reference vs
    decoded JAX ring (exact event parity), schema validation, and both
    export formats re-read / re-replayed. ``trace_out`` writes the
    Perfetto JSON as a CI artifact. Seconds, not minutes: one compile
    each."""
    from repro.obs import export, schema, timeseries
    cfg = api.make_config("fitgpp", n_jobs=n_jobs, n_nodes=4, seed=seed)
    js = scenarios.build("paper-synthetic", cfg)
    jobs = sim_jax.jobs_from_jobset(js)
    st_j = sim_jax.run_jit(cfg, jobs, seed, time_mode="event")
    st_p = sim_jax.run_jit(dataclasses.replace(cfg, score_backend="pallas"),
                           jobs, seed, time_mode="event")
    diff = sim_jax.state_diff_fields(st_j, st_p)
    if diff:
        raise SystemExit(f"smoke: jnp-vs-pallas state diff in {diff}")
    # trace round-trip on a deterministic preemption-exercising config
    # (lrtp never takes the random fallback here — asserted, so the
    # cross-engine comparison is exact by contract, DESIGN.md §8)
    cfg = api.make_config("lrtp", n_jobs=n_jobs, n_nodes=6, seed=seed)
    js = scenarios.build("paper-synthetic", cfg)
    res = simulator.simulate(cfg, js, mode="event", trace=True)
    jobs = sim_jax.jobs_from_jobset(js)
    st_t = sim_jax.run_jit(cfg, jobs, seed, time_mode="event", trace=True)
    if int(st_t.fallback_count):
        raise SystemExit("smoke: fallback fired; trace parity not exact")
    events, overflow = sim_jax.decode_trace(st_t)
    if overflow:
        raise SystemExit(f"smoke: trace ring overflowed ({overflow} rows)")
    metrics.assert_trace_parity(res.trace, events)
    schema.validate_events(events, n_jobs=js.n,
                           n_nodes=cfg.cluster.n_nodes)
    # both export formats: CSV must round-trip losslessly, the
    # Perfetto JSON must re-replay into the same telemetry series
    if export.read_csv(export.to_csv(events)) != events:
        raise SystemExit("smoke: CSV trace round-trip diverged")
    ts = timeseries.compute_timeseries(events, cfg.cluster.n_nodes,
                                       is_te=js.is_te)
    pf = export.to_perfetto(events, n_nodes=cfg.cluster.n_nodes,
                            is_te=js.is_te)
    if not pf["traceEvents"]:
        raise SystemExit("smoke: empty Perfetto trace")
    if trace_out:
        export.write_trace(trace_out, events, fmt="perfetto",
                           n_nodes=cfg.cluster.n_nodes, is_te=js.is_te)
    # streamed-engine parity window (DESIGN.md §10): the same jobs
    # through the slot-recycling macro-round engine — with real
    # recycling (capacity < n_jobs) — must equal the monolithic
    # engine bit-exactly; sub-critical load keeps the open-loop
    # backlog inside the pool
    from repro.core import stream
    scfg = api.make_config("fitgpp", n_jobs=160, n_nodes=8, seed=seed)
    scfg = dataclasses.replace(
        scfg, workload=dataclasses.replace(scfg.workload, load=0.5))
    sdiff = stream.verify_prefix_parity(scfg, n_jobs=160, capacity=64,
                                        chunk=48)
    if sdiff:
        raise SystemExit(f"smoke: stream-vs-monolithic diff in {sdiff}")
    # one streamed closed-loop round (§4.2 at load 2.0): admit ticks
    # AND scheduler outcome bit-exact with the monolithic
    # closed_loop_submit_times + run_jit pipeline (rank policy — the
    # score fallback fires at saturation and leaves the parity domain)
    ccfg = api.make_config("lrtp", n_jobs=160, n_nodes=8, seed=seed)
    ccfg = dataclasses.replace(
        ccfg, workload=dataclasses.replace(ccfg.workload, load=2.0))
    cdiff = stream.verify_closed_loop_parity(ccfg, n_jobs=160,
                                             capacity=96, chunk=48)
    if cdiff:
        raise SystemExit(f"smoke: streamed closed-loop diff in {cdiff}")
    print(f"smoke ok: {n_jobs} jobs, fused-backend parity verified, "
          f"{len(events)} events trace-parity ok, "
          f"util {ts.mean_utilization():.2f}, streamed parity ok, "
          f"closed-loop parity ok"
          + (f", trace -> {trace_out}" if trace_out else ""))


def profile(outdir: str, n_jobs: int = 1024, n_nodes: int = 8,
            policy: str = "fitgpp", seed: int = 0) -> None:
    """Capture a ``jax.profiler.trace`` of one jitted engine run
    (compile excluded) into ``outdir`` — open with TensorBoard or
    ui.perfetto.dev. This profiles the ENGINE's XLA execution; the
    scheduler-event traces (``--smoke --trace-out`` / the scenarios
    CLI ``--trace``) profile the simulated cluster."""
    import jax
    cfg = api.make_config(policy, n_jobs=n_jobs, n_nodes=n_nodes,
                          seed=seed)
    js = scenarios.build("paper-synthetic", cfg)
    jobs = sim_jax.jobs_from_jobset(js)
    st = sim_jax.run_jit(cfg, jobs, seed)       # compile
    st.t.block_until_ready()
    with jax.profiler.trace(outdir):
        st = sim_jax.run_jit(cfg, jobs, seed)
        st.t.block_until_ready()
    print(f"profiled {n_jobs}-job run -> {outdir}")


def run_all() -> List[tuple]:
    rows = []
    n = 2048
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=n), policy="fitgpp")
    jobs = workload.generate(cfg)

    t0 = time.perf_counter()
    simulator.simulate(cfg, jobs, mode="tick")
    rows.append(("sim_reference_2k_tick", (time.perf_counter() - t0) * 1e6,
                 "numpy heaps, minute ticks"))

    t0 = time.perf_counter()
    simulator.simulate(cfg, jobs, mode="event")
    rows.append(("sim_reference_2k_event", (time.perf_counter() - t0) * 1e6,
                 "numpy heaps, event jumps"))

    ev = bench_tick_vs_event()
    rows.append(("sim_sparse_512_tick", ev["tick"]["seconds"] * 1e6,
                 f"{ev['tick']['jobs_per_sec']:.0f} jobs/s"))
    rows.append(("sim_sparse_512_event", ev["event"]["seconds"] * 1e6,
                 f"{ev['event']['jobs_per_sec']:.0f} jobs/s, "
                 f"{ev['speedup']:.1f}x"))

    jj = sim_jax.jobs_from_jobset(jobs)
    st = sim_jax.run_jit(cfg, jj, 0)           # compile
    st.t.block_until_ready()
    t0 = time.perf_counter()
    st = sim_jax.run_jit(cfg, jj, 0)
    st.t.block_until_ready()
    rows.append(("sim_jax_2k", (time.perf_counter() - t0) * 1e6,
                 "lax.while_loop"))

    t0 = time.perf_counter()
    out = api.sensitivity_grid(cfg, 512, s_vals=[0.0, 2.0, 4.0, 8.0],
                               seeds=[0, 1])
    rows.append(("sim_sweep_8trials", (time.perf_counter() - t0) * 1e6,
                 "vmap(8 sims)"))

    for name, r in bench_scenario_suite().items():
        rows.append((f"scenario_{name}", r["seconds"] * 1e6,
                     f"{r['n_jobs']} jobs, {r['makespan_ticks']} ticks, "
                     f"{r['jobs_per_sec']:.0f} jobs/s, "
                     f"util {r['utilization']:.2f}, "
                     f"{r['preempt_rate']:.3f} preempts/min"))
        if "jax_event" in r:
            rows.append((f"scenario_{name}_jax_event",
                         r["jax_event"]["seconds"] * 1e6,
                         f"{r['jax_event']['jobs_per_sec']:.0f} jobs/s, "
                         f"{r['jax_speedup']:.1f}x vs jax_tick, "
                         f"traced {r['trace_overhead']:.2f}x, "
                         f"fallback {r['fallback_count']}, parity ok"))

    sb = bench_score_backend()
    for backend in ("jnp", "pallas"):
        rows.append((f"sim_jax_score_{backend}",
                     sb[backend]["seconds"] * 1e6,
                     f"{sb[backend]['jobs_per_sec']:.0f} jobs/s, parity ok"))

    sr = bench_stream(n_jobs=8192, capacity=1024)
    rows.append(("sim_stream_8k", sr["full"]["seconds"] * 1e6,
                 f"{sr['full']['jobs_per_sec']:.0f} jobs/s, "
                 f"{sr['full']['rounds']} rounds, capacity 1024, "
                 f"rss {sr['full']['max_rss_mb']:.0f}MB, parity ok"))

    cl = bench_stream_closed_loop(n_jobs=8192, capacity=1024)
    rows.append(("sim_stream_closed_8k", cl["full"]["seconds"] * 1e6,
                 f"{cl['full']['jobs_per_sec']:.0f} jobs/s, load 2.0, "
                 f"{cl['full']['rounds']} rounds, capacity 1024, "
                 f"spilled {cl['full']['n_spilled']}, "
                 f"rss {cl['full']['max_rss_mb']:.0f}MB, parity ok"))

    t0 = time.perf_counter()
    api.scenario_sweep(
        SimConfig(cluster=ClusterSpec(n_nodes=8),
                  workload=WorkloadSpec(n_jobs=256), policy="fitgpp"),
        ["te-flood", "long-tail-be", "burst-storm"], seeds=[0, 1])
    rows.append(("scenario_sweep_ragged_6", (time.perf_counter() - t0) * 1e6,
                 "vmap(3 scenarios x 2 seeds, sentinel-padded)"))

    sw = bench_sweep_throughput()
    sharded = sw[f"devices_{sw['n_devices']}"]
    rows.append((f"sweep_fabric_{sw['n_trials']}trials",
                 sharded["seconds"] * 1e6,
                 f"{sharded['configs_per_sec']:.1f} configs/s on "
                 f"{sw['n_devices']} forced host devices, "
                 f"{sw['scaling_x']:.1f}x vs 1-device vmap, parity ok"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit BENCH_sim_engine.json (tick vs event)")
    ap.add_argument("--out", default="BENCH_sim_engine.json")
    ap.add_argument("--check-parity", metavar="PATH",
                    help="validate an existing BENCH json: exit nonzero "
                         "if any in-run parity row is false or any "
                         "scenario's jax_event row lost to the "
                         "reference engine (CI gate)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scenario fused-backend + trace round-trip "
                         "smoke (CI fast lane)")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="with --smoke: write the smoke run's Perfetto "
                         "trace to PATH (CI artifact)")
    ap.add_argument("--profile", metavar="DIR",
                    help="capture a jax.profiler.trace of one jitted "
                         "engine run into DIR and exit")
    ap.add_argument("--sweep-child", type=int, metavar="N",
                    help="internal: sweep_throughput child body under "
                         "a forced N-device host runtime (prints one "
                         "JSON row)")
    args = ap.parse_args(argv)
    if args.sweep_child:
        print(json.dumps(_sweep_child(args.sweep_child)))
        return
    if args.profile:
        profile(args.profile)
        return
    if args.check_parity:
        with open(args.check_parity) as f:
            data = json.load(f)
        bad = check_parity_rows(data) + check_speed_rows(data)
        if bad:
            raise SystemExit(f"bench gates failed in {args.check_parity}: "
                             f"{bad}")
        print(f"{args.check_parity}: all parity and speed rows pass")
        return
    if args.smoke:
        smoke(trace_out=args.trace_out)
        return
    if args.json:
        out = emit_json(args.out)
        print(json.dumps(out, indent=2))
        return
    print("name,us_per_call,derived")
    for name, us, derived in run_all():
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
