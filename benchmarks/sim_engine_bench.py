"""Simulator-engine benchmarks: reference (numpy) vs JAX engine, plus the
vmapped sweep throughput that the mesh distribution relies on."""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax

from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import sim_jax, simulator, sweep, workload


def run_all() -> List[tuple]:
    rows = []
    n = 2048
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=n), policy="fitgpp")
    jobs = workload.generate(cfg)

    t0 = time.perf_counter()
    simulator.simulate(cfg, jobs)
    rows.append(("sim_reference_2k", (time.perf_counter() - t0) * 1e6,
                 "numpy heaps"))

    jj = sim_jax.jobs_from_jobset(jobs)
    st = sim_jax.run_jit(cfg, jj, 0)           # compile
    st.t.block_until_ready()
    t0 = time.perf_counter()
    st = sim_jax.run_jit(cfg, jj, 0)
    st.t.block_until_ready()
    rows.append(("sim_jax_2k", (time.perf_counter() - t0) * 1e6,
                 "lax.while_loop"))

    t0 = time.perf_counter()
    out = sweep.sensitivity_grid(cfg, 512, s_vals=[0.0, 2.0, 4.0, 8.0],
                                 seeds=[0, 1])
    rows.append(("sim_sweep_8trials", (time.perf_counter() - t0) * 1e6,
                 "vmap(8 sims)"))
    return rows
