"""Summarize dry-run artifacts into the §Roofline / §Dry-run tables.

Usage:  PYTHONPATH=src python -m benchmarks.roofline_report \
            [--dir experiments/dryrun] [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str, mesh: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_seconds(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    return f"{x * 1e3:7.2f}ms"


def report(rows: List[Dict]) -> str:
    lines = []
    hdr = (f"{'arch':20s} {'shape':12s} | {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} | {'dominant':10s} {'useful':>6s} "
           f"{'peakGB':>7s} {'coll GB/dev':>11s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for r in rows:
        roof = r.get("roofline", {})
        mem = r.get("memory", {})
        coll = r.get("collectives", {})
        lines.append(
            f"{r['arch']:20s} {r['shape']:12s} | "
            f"{fmt_seconds(roof.get('compute_s', 0))} "
            f"{fmt_seconds(roof.get('memory_s', 0))} "
            f"{fmt_seconds(roof.get('collective_s', 0))} | "
            f"{roof.get('dominant', '-'):10s} "
            f"{roof.get('useful_ratio', 0):6.2f} "
            f"{mem.get('peak_gb', 0):7.1f} "
            f"{coll.get('total', 0) / 1e9:11.2f}")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args(argv)
    rows = load(args.dir, args.mesh)
    if not rows:
        print(f"no artifacts under {args.dir} for mesh={args.mesh}; "
              "run `python -m repro.launch.dryrun` first")
        return
    print(report(rows))


if __name__ == "__main__":
    main()
