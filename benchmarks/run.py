"""Benchmark entry point. One benchmark per paper table/figure plus
kernel and simulator-engine microbenches.

Prints ``name,us_per_call,derived`` CSV rows (stdout). Scale with
REPRO_BENCH_SCALE=full for paper-scale workloads (2^16 jobs × 8
workloads); default is a reduced CI-friendly scale.

Roofline terms come from the dry-run artifacts
(``python -m repro.launch.dryrun``), summarized by
``python -m benchmarks.roofline_report``.
"""
from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark-group filter "
                         "(paper,kernels,sim)")
    args = ap.parse_args(argv)
    groups = args.only.split(",") if args.only else ["paper", "kernels",
                                                     "sim"]
    rows = []
    if "paper" in groups:
        from benchmarks import paper_tables
        rows += paper_tables.run_all()
    if "kernels" in groups:
        from benchmarks import kernel_bench
        rows += kernel_bench.run_all()
    if "sim" in groups:
        from benchmarks import sim_engine_bench
        rows += sim_engine_bench.run_all()
    if "ext" in groups or "paper" in groups:
        from benchmarks import ext_backfill, ext_multinode
        rows += ext_backfill.run_all()
        rows += ext_multinode.run_all()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
