"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this CPU container the numbers time the *oracle* (XLA-compiled) and
the *interpret-mode* kernel (Python semantics — NOT representative of
TPU perf); the benchmark's role here is a regression harness for shapes
and a smoke check that the kernels dispatch. On a TPU host the same
entry points time the real Mosaic kernels.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref as kref


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_flash_attention() -> List[tuple]:
    rows = []
    B, S, H, KV, hd = 1, 512, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    oracle = jax.jit(lambda q, k, v: kref.flash_attention_ref(q, k, v))
    us_ref = _time(oracle, q, k, v)
    us_ker = _time(lambda q, k, v: ops.flash_attention(q, k, v), q, k, v)
    rows.append(("flash_attention_oracle_512", us_ref, f"S={S}"))
    rows.append(("flash_attention_kernel_512", us_ker, "interpret-mode"))
    return rows


def bench_lru_scan() -> List[tuple]:
    B, L, R = 2, 1024, 512
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, L, R)))
    b = jax.random.normal(ks[1], (B, L, R))
    oracle = jax.jit(lambda a, b: kref.lru_scan_ref(a, b))
    return [("lru_scan_oracle_1k", _time(oracle, a, b), f"L={L};R={R}"),
            ("lru_scan_kernel_1k", _time(
                lambda a, b: ops.lru_scan(a, b), a, b), "interpret-mode")]


def bench_schedule_step() -> List[tuple]:
    J, M = 4096, 84                    # jobs x nodes (paper cluster)
    ks = jax.random.split(jax.random.PRNGKey(2), 8)
    demand = jax.random.uniform(ks[0], (J, 3), minval=1.0, maxval=8.0)
    free = jax.random.uniform(ks[1], (M, 3), minval=0.0, maxval=8.0)
    pend = jax.random.uniform(ks[6], (M, 3), minval=0.0, maxval=4.0)
    gp = jax.random.uniform(ks[2], (J,), maxval=20.0)
    cand = jax.random.bernoulli(ks[3], 0.8, (J,))
    # mostly single-node candidates, some 2-node gangs
    node = jax.random.randint(ks[4], (J,), 0, M)
    gang = jax.random.bernoulli(ks[5], 0.15, (J,))
    assign = jax.nn.one_hot(node, M, dtype=bool) \
        | jax.nn.one_hot((node + 1) % M, M, dtype=bool) & gang[:, None]
    width = jnp.where(gang, 2, 1).astype(jnp.int32)
    key = jax.random.uniform(ks[7], (J,)) * 1e4
    under = jnp.ones((J,), bool)
    be_q = ~cand & jax.random.bernoulli(ks[6], 0.5, (J,))
    te = jnp.array([4.0, 16.0, 4.0])
    cap = jnp.array([32.0, 256.0, 8.0])
    max_sz = jnp.asarray(1.0)
    max_gp = jnp.asarray(20.0)

    def oracle(demand, gp, key, assign, free, pend, cand, under, be_q):
        return kref.schedule_step_ref(demand, gp, width, key, assign,
                                      free, pend, cand, under, be_q, te,
                                      cap, max_sz, max_gp, 4.0)

    j_oracle = jax.jit(oracle)
    args = (demand, gp, key, assign, free, pend, cand, under, be_q)
    return [
        ("schedule_step_oracle_4k", _time(j_oracle, *args), f"J={J};M={M}"),
        ("schedule_step_kernel_4k", _time(
            lambda d, g, k, a, f, p, c, u, b: ops.schedule_step(
                d, g, width, k, a, f, p, c, u, b, te, cap, s=4.0),
            *args), "interpret-mode"),
    ]


def bench_ssd_chunk() -> List[tuple]:
    B, L, H, P, N = 1, 512, 2, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    xdt = jax.random.normal(ks[0], (B, L, H, P)) * 0.3
    loga = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bm = jax.random.normal(ks[2], (B, L, H, N)) * 0.3
    Cm = jax.random.normal(ks[3], (B, L, H, N)) * 0.3

    def oracle(xdt, loga, Bm, Cm):
        Q = 256
        outs = [kref.ssd_chunk_ref(xdt[:, c * Q:(c + 1) * Q],
                                   loga[:, c * Q:(c + 1) * Q],
                                   Bm[:, c * Q:(c + 1) * Q],
                                   Cm[:, c * Q:(c + 1) * Q])
                for c in range(L // Q)]
        import jax.numpy as jnp
        return jnp.concatenate(outs, axis=1)

    j_oracle = jax.jit(oracle)
    return [("ssd_chunk_oracle_512", _time(j_oracle, xdt, loga, Bm, Cm),
             f"L={L};N={N}"),
            ("ssd_chunk_kernel_512", _time(
                lambda *a: ops.ssd_chunk(*a), xdt, loga, Bm, Cm),
             "interpret-mode")]


def run_all() -> List[tuple]:
    rows = []
    rows += bench_flash_attention()
    rows += bench_lru_scan()
    rows += bench_schedule_step()
    rows += bench_ssd_chunk()
    return rows
