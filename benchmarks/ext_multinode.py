"""BEYOND-PAPER: gang-scheduled (multi-node) distributed-DL jobs.

The paper's conclusion: "It is also worth modifying our algorithm so
that it can handle the multi-node jobs in distributed DL." Here 15% of
jobs are gangs of 2 or 4 nodes (per-node demand, all-or-nothing).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import List

import numpy as np

from benchmarks.paper_tables import OUT_DIR, _scale
from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import metrics, simulator, workload


def multinode_table() -> dict:
    sc = _scale()
    wl = WorkloadSpec(n_jobs=sc["n_jobs"], multi_node_frac=0.15)
    cfg = SimConfig(workload=wl, s=4.0, max_preemptions=1)
    jobsets = [workload.generate(cfg, seed=1000 * i)
               for i in range(sc["n_workloads"])]
    out = {}
    for pol in ("fifo", "lrtp", "rand", "fitgpp"):
        results = [simulator.simulate(
            dataclasses.replace(cfg, policy=pol), js) for js in jobsets]
        p = metrics.pooled_tables(metrics.merge_results(results))
        gang_te = np.concatenate(
            [r.slowdown[(js.n_nodes > 1) & js.is_te]
             for r, js in zip(results, jobsets)])
        p["gang_TE_p95"] = float(np.percentile(gang_te, 95))
        out[pol] = p
    return out


def run_all() -> List[tuple]:
    os.makedirs(OUT_DIR, exist_ok=True)
    t0 = time.time()
    res = multinode_table()
    with open(os.path.join(OUT_DIR, "ext_multinode.json"), "w") as f:
        json.dump(res, f, indent=1, default=float)
    return [("ext_multinode", (time.time() - t0) * 1e6,
             f"gangTE_p95_fifo={res['fifo']['gang_TE_p95']:.1f};"
             f"fitgpp={res['fitgpp']['gang_TE_p95']:.2f}")]
