"""BEYOND-PAPER: FitGpp in a non-FIFO (backfill) setting.

The paper's conclusion lists "extension of this work to non-FIFO based
setting" as future work. This benchmark relaxes strict head-of-line
blocking with bounded first-fit backfill (FIFO order remains the primary
key) and re-runs the Table-1 comparison.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import List

from benchmarks.paper_tables import OUT_DIR, _gen_workloads, _run_policy, _scale
from repro.configs.cluster import SimConfig, WorkloadSpec


def backfill_table() -> dict:
    sc = _scale()
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=sc["n_jobs"]),
                    s=4.0, max_preemptions=1)
    jobs = _gen_workloads(cfg, sc["n_workloads"])
    out = {}
    for pol in ("fifo", "fitgpp"):
        for bf in (False, True):
            c = dataclasses.replace(cfg, backfill=bf)
            name = pol + ("+backfill" if bf else "")
            out[name] = _run_policy(c, jobs, pol)
    return out


def run_all() -> List[tuple]:
    os.makedirs(OUT_DIR, exist_ok=True)
    t0 = time.time()
    res = backfill_table()
    with open(os.path.join(OUT_DIR, "ext_backfill.json"), "w") as f:
        json.dump(res, f, indent=1, default=float)
    be_gain = 1 - res["fitgpp+backfill"]["BE"]["p50"] / \
        res["fitgpp"]["BE"]["p50"]
    te95 = res["fitgpp+backfill"]["TE"]["p95"]
    return [("ext_backfill", (time.time() - t0) * 1e6,
             f"BE_p50_gain={be_gain * 100:.0f}%;TE_p95={te95:.2f}")]
