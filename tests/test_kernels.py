"""Pallas-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU), plus the registry-wired ``score_backend``
engine parity (which needs no dev extras and always runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                      # CI installs requirements-dev
    HAS_HYPOTHESIS = False

    def given(*a, **k):                  # placeholder decorators so the
        return lambda f: f               # classes below still parse

    settings = given

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

# The oracle sweeps were historically gated on the dev extras via a
# module-level importorskip; keep exactly that behavior per class so
# the score-backend suite below can run everywhere. The registered
# ``hypothesis`` marker (pytest.ini) makes the gated subset selectable.
_skip_without_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS,
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")


def needs_dev_deps(cls):
    return _skip_without_hypothesis(pytest.mark.hypothesis(cls))

from repro.kernels import ops
from repro.kernels import ref as kref


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@needs_dev_deps
class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Sq,Skv,H,KV,hd,causal,window,cap",
        [
            (2, 256, 256, 4, 2, 64, True, 0, 0.0),
            (1, 128, 256, 4, 1, 128, True, 0, 0.0),     # offset queries
            (2, 256, 256, 8, 8, 64, True, 64, 0.0),     # MHA + window
            (1, 256, 256, 2, 1, 64, False, 0, 0.0),     # bidirectional
            (1, 128, 128, 4, 2, 64, True, 0, 30.0),     # softcap
            (2, 300, 300, 4, 2, 64, True, 0, 0.0),      # padded
            (1, 100, 260, 4, 4, 32, True, 48, 0.0),     # padded + window
        ])
    def test_vs_oracle(self, B, Sq, Skv, H, KV, hd, causal, window, cap,
                       dtype):
        ks = jax.random.split(jax.random.PRNGKey(Sq + Skv + H), 3)
        q = rand(ks[0], (B, Sq, H, hd), dtype)
        k = rand(ks[1], (B, Skv, KV, hd), dtype)
        v = rand(ks[2], (B, Skv, KV, hd), dtype)
        out = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  softcap=cap)
        ref = kref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window, softcap=cap)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol)

    def test_matches_model_attention(self):
        """Kernel path == model jnp path through attention.attend."""
        from repro.models import attention, common
        B, S, H, KV, hd = 2, 128, 4, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (B, S, H, hd), jnp.float32)
        k = rand(ks[1], (B, S, KV, hd), jnp.float32)
        v = rand(ks[2], (B, S, KV, hd), jnp.float32)
        mask = common.causal_mask(S, S)
        jnp_out = attention.attend(q, k, v, mask=mask)
        ker_out = ops.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(jnp_out), np.asarray(ker_out),
                                   atol=2e-5, rtol=2e-5)


@needs_dev_deps
class TestLruScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,L,R,h0", [
        (2, 256, 512, False), (2, 300, 130, True), (1, 64, 1024, True),
        (3, 1024, 64, False),
    ])
    def test_vs_oracle(self, B, L, R, h0, dtype):
        ks = jax.random.split(jax.random.PRNGKey(L * R), 3)
        a = jax.nn.sigmoid(rand(ks[0], (B, L, R), jnp.float32)).astype(dtype)
        b = (rand(ks[1], (B, L, R), jnp.float32) * 0.5).astype(dtype)
        h = rand(ks[2], (B, R), dtype) if h0 else None
        out = ops.lru_scan(a, b, h)
        ref = kref.lru_scan_ref(a, b, h)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)

    def test_matches_hybrid_lru(self):
        from repro.models import hybrid
        B, L, R = 2, 64, 32
        ks = jax.random.split(jax.random.PRNGKey(7), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, L, R)))
        b = jax.random.normal(ks[1], (B, L, R))
        model_scan = hybrid.lru_scan(a, b)
        kernel = ops.lru_scan(a, b)
        np.testing.assert_allclose(np.asarray(model_scan),
                                   np.asarray(kernel), atol=1e-4)


from repro.core.engine.placement import FIT_EPS
from repro.kernels import schedule_step as kss


def _pass_all_backends(demand, gp, width, queue_key, assign, free,
                       pending_free, cand, under, be_q, te, cap,
                       s=4.0, block_j=16):
    """Run the fused schedule pass through all three backends: the
    jit'd ops wrapper (Pallas interpret, padded to the block multiple),
    the portable jnp twin, and the straight-line oracle. Normalizer
    computation mirrors the ops wrapper so the twin/oracle see the
    exact scalars the kernel sees."""
    sz = jnp.sqrt(jnp.sum(jnp.square(demand / cap), -1))
    max_sz = jnp.maximum(jnp.max(jnp.where(cand, sz, 0.0)), 1e-12)
    max_gp = jnp.maximum(jnp.max(jnp.where(cand, gp, 0.0)), 1e-12)
    pal = ops.schedule_step(demand, gp, width, queue_key, assign, free,
                            pending_free, cand, under, be_q, te, cap,
                            s=s, block_j=block_j)
    twin = kss.schedule_step_jnp(demand, gp, width, queue_key, assign,
                                 free, pending_free, cand, under, be_q,
                                 te, cap, max_sz, max_gp, s)
    oracle = kss.SchedulePass(*kref.schedule_step_ref(
        demand, gp, width, queue_key, assign, free, pending_free, cand,
        under, be_q, te, cap, max_sz, max_gp, s, eps=FIT_EPS))
    return pal, twin, oracle


def _assert_pass_equal(a, b):
    for name, x, y in zip(kss.SchedulePass._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


def _rand_instance(J, M, seed):
    """Random gang-shaped pass inputs: single-node and 2-node-gang
    assignments, mixed TE/BE masks, random queue keys."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 10)
    demand = jnp.stack([
        jax.random.randint(ks[0], (J,), 1, 33).astype(jnp.float32),
        jax.random.randint(ks[1], (J,), 1, 257).astype(jnp.float32),
        jax.random.randint(ks[2], (J,), 0, 9).astype(jnp.float32)], 1)
    free = jnp.stack([
        jax.random.randint(ks[3], (M,), 0, 16).astype(jnp.float32),
        jax.random.randint(ks[4], (M,), 0, 128).astype(jnp.float32),
        jax.random.randint(ks[5], (M,), 0, 5).astype(jnp.float32)], 1)
    pend = jnp.stack([
        jax.random.randint(ks[6], (M,), 0, 8).astype(jnp.float32),
        jax.random.randint(ks[7], (M,), 0, 64).astype(jnp.float32),
        jax.random.randint(ks[8], (M,), 0, 3).astype(jnp.float32)], 1)
    node = jax.random.randint(ks[5], (J,), 0, M)
    gang = jax.random.bernoulli(ks[3], 0.3, (J,))
    assign = (jax.nn.one_hot(node, M, dtype=bool)
              | (jax.nn.one_hot((node + 1) % M, M, dtype=bool)
                 & gang[:, None]))
    gp = jax.random.randint(ks[0], (J,), 0, 21).astype(jnp.float32)
    width = jnp.where(gang, 2, 1).astype(jnp.int32)
    queue_key = jax.random.uniform(ks[9], (J,)) * 100.0
    cand = jax.random.bernoulli(ks[1], 0.7, (J,))
    under = jax.random.bernoulli(ks[2], 0.9, (J,))
    be_q = jax.random.bernoulli(ks[4], 0.4, (J,))
    te = jnp.array([4.0, 16.0, 4.0])
    cap = jnp.array([32.0, 256.0, 8.0])
    return (demand, gp, width, queue_key, assign, free, pend, cand,
            under, be_q, te, cap)


@needs_dev_deps
class TestScheduleStepKernel:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(4, 600), st.integers(0, 10_000))
    def test_vs_twin_and_oracle_random(self, J, seed):
        """Random gang tiles with ragged J (padded to the 16-block
        multiple inside the ops wrapper): Pallas == jnp twin == oracle
        bit-for-bit on every SchedulePass field."""
        pal, twin, oracle = _pass_all_backends(*_rand_instance(J, 8, seed))
        _assert_pass_equal(pal, twin)
        _assert_pass_equal(pal, oracle)

    def test_matches_numpy_policy(self):
        """Fused-pass victim argmin == policies.FitGppPolicy main path
        (each candidate on its own node, Eq. 2 free vector taken from
        that node — exactly what the reference engine passes)."""
        from repro.core import policies as pol
        rng = np.random.default_rng(0)
        J, M = 64, 4
        demand = np.stack([rng.integers(1, 33, J), rng.integers(1, 257, J),
                           rng.integers(0, 9, J)], 1).astype(float)
        free = np.zeros((M, 3))
        cand_node = (np.arange(J) % M).astype(np.int64)
        assign = np.zeros((J, M), bool)
        assign[np.arange(J), cand_node] = True
        gp = rng.integers(0, 21, J).astype(float)
        te = np.array([4.0, 16.0, 2.0])
        cap = np.array([32.0, 256.0, 8.0])
        p = pol.FitGppPolicy(s=4.0)
        victims = p.select(
            rng=rng, te_demand=te, cand_ids=np.arange(J),
            cand_demand=demand, cand_node_free=free[cand_node], cand_gp=gp,
            cand_remaining=np.ones(J), under_cap=np.ones(J, bool),
            all_run_demand=demand, all_run_gp=gp, node_cap=cap,
            free_by_node=free, cand_node=cand_node)
        ps = ops.schedule_step(
            jnp.asarray(demand, jnp.float32), jnp.asarray(gp, jnp.float32),
            jnp.ones(J, jnp.int32), jnp.zeros(J, jnp.float32),
            jnp.asarray(assign), jnp.asarray(free, jnp.float32),
            jnp.zeros((M, 3), jnp.float32), jnp.ones(J, bool),
            jnp.ones(J, bool), jnp.zeros(J, bool),
            jnp.asarray(te, jnp.float32), jnp.asarray(cap, jnp.float32),
            s=4.0)
        elig = pol.eligible_eq2(te, demand, free[cand_node])
        if elig.any():
            assert victims == [int(ps.victim)]


class TestScheduleStepEdgeCases:
    """Deterministic fused-pass cases that run without dev extras."""

    def _trivial(self, **over):
        J, M = 5, 2
        base = dict(
            demand=jnp.tile(jnp.asarray([[4.0, 16.0, 1.0]]), (J, 1)),
            gp=jnp.arange(J, dtype=jnp.float32),
            width=jnp.ones(J, jnp.int32),
            queue_key=jnp.arange(J, dtype=jnp.float32),
            assign=jnp.zeros((J, M), bool).at[jnp.arange(J), 0].set(True),
            free=jnp.asarray([[32.0, 256.0, 8.0], [32.0, 256.0, 8.0]]),
            pending_free=jnp.zeros((M, 3)),
            cand=jnp.zeros(J, bool), under=jnp.ones(J, bool),
            be_q=jnp.zeros(J, bool),
            te=jnp.asarray([8.0, 32.0, 2.0]),
            cap=jnp.asarray([32.0, 256.0, 8.0]))
        base.update(over)
        return _pass_all_backends(*base.values(), block_j=4)

    def test_empty_queue_no_victim(self):
        """All masks empty: every scalar output is the -1/0 sentinel,
        on every backend (and the backends agree bit-for-bit)."""
        pal, twin, oracle = self._trivial()
        _assert_pass_equal(pal, twin)
        _assert_pass_equal(pal, oracle)
        assert int(pal.victim) == -1
        assert int(pal.be_head) == -1
        assert int(pal.be_pick) == -1
        assert int(pal.nskip) == 0

    def test_ragged_padding_sentinels(self):
        """J=5 padded to the block_j=4 multiple (8): the three pad rows
        carry zero demand (they'd fit everywhere) — the width/key/mask
        sentinels must keep them out of every reduction and count."""
        pal, twin, oracle = self._trivial(
            cand=jnp.ones(5, bool), be_q=jnp.ones(5, bool))
        _assert_pass_equal(pal, twin)
        _assert_pass_equal(pal, oracle)
        assert pal.fits.shape == (5, 2)
        assert int(pal.be_head) == 0          # key order, not pad rows
        assert int(pal.be_pick) == 0

    def test_gang_best_node_reduction(self):
        """A gang candidate is eligible iff its BEST node passes Eq. 2
        — one crowded node must not mask a slack node (and vice versa
        a single-node candidate on the crowded node stays ineligible)."""
        free = jnp.asarray([[0.0, 0.0, 0.0],      # node 0: crowded
                            [32.0, 256.0, 8.0]])  # node 1: wide open
        gang = jnp.asarray([[True, True],         # gang on both
                            [True, False]])       # single on node 0
        over = dict(
            demand=jnp.tile(jnp.asarray([[4.0, 16.0, 2.0]]), (5, 1)),
            free=free, cand=jnp.arange(5) < 2,
            assign=jnp.zeros((5, 2), bool).at[:2].set(gang))
        pal, twin, oracle = self._trivial(**over)
        _assert_pass_equal(pal, twin)
        _assert_pass_equal(pal, oracle)
        assert int(pal.victim) == 0           # gang eligible via node 1
        over["assign"] = jnp.zeros((5, 2), bool).at[:2, 0].set(True)
        pal2, _, _ = self._trivial(**over)
        assert int(pal2.victim) == -1         # both stuck on node 0

    def test_backfill_pick_and_skips(self):
        """be_pick is the min-key FITTING queued BE job; nskip counts
        the non-fitting queued jobs ahead of it in key order (the
        bounded-backfill depth the scan consumes before placing it)."""
        demand = jnp.asarray([[64.0, 16.0, 1.0],   # key 0: never fits
                              [64.0, 16.0, 1.0],   # key 1: never fits
                              [4.0, 16.0, 1.0],    # key 2: fits
                              [4.0, 16.0, 1.0],    # key 3: fits
                              [4.0, 16.0, 1.0]])   # not queued
        pal, twin, oracle = self._trivial(
            demand=demand, be_q=jnp.arange(5) < 4)
        _assert_pass_equal(pal, twin)
        _assert_pass_equal(pal, oracle)
        assert int(pal.be_head) == 0
        assert int(pal.be_pick) == 2
        assert int(pal.nskip) == 2
        np.testing.assert_array_equal(np.asarray(pal.fit_now),
                                      [0, 0, 2, 2, 2])


class TestRemovedFitgppShims:
    """The standalone fitgpp kernel entry points were subsumed by the
    fused pass; stale call sites must fail loudly at CALL time with a
    pointer to schedule_step."""

    def test_ops_fitgpp_select_raises(self):
        with pytest.raises(RuntimeError, match="schedule_step"):
            ops.fitgpp_select(None, None)

    def test_fitgpp_score_module_raises(self):
        from repro.kernels import fitgpp_score
        with pytest.raises(RuntimeError, match="schedule_step"):
            fitgpp_score.fitgpp_score()

class TestFitgppScoreBackend:
    """The registry-wired score-backend switch: a full JAX-engine run
    with ``SimConfig.score_backend="pallas"`` (Eq. 1-4 score + masked
    argmin on the Pallas kernel) is bit-identical to the jnp path."""

    def test_sim_parity_jnp_vs_pallas(self):
        import dataclasses
        from repro.configs.cluster import SimConfig, WorkloadSpec
        from repro.core import sim_jax, workload
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=160), policy="fitgpp",
                        seed=5)
        js = workload.generate(cfg)
        jobs = sim_jax.jobs_from_jobset(js)
        st_jnp = sim_jax.run_jit(cfg, jobs, 5)
        st_pal = sim_jax.run_jit(
            dataclasses.replace(cfg, score_backend="pallas"), jobs, 5)
        np.testing.assert_array_equal(np.asarray(st_pal.finish),
                                      np.asarray(st_jnp.finish))
        np.testing.assert_array_equal(np.asarray(st_pal.preempt_count),
                                      np.asarray(st_jnp.preempt_count))
        np.testing.assert_array_equal(np.asarray(st_pal.last_vacate),
                                      np.asarray(st_jnp.last_vacate))

    def test_traced_s_falls_back_to_jnp(self):
        """Vmapped s-sweeps cannot bake s into the kernel: the resolver
        silently falls back to the jnp path instead of tracing-erroring."""
        from repro.configs.cluster import SimConfig
        from repro.core import policy_registry, sim_jax
        cfg = SimConfig(policy="fitgpp", score_backend="pallas")
        spec = policy_registry.get_policy("fitgpp")
        assert sim_jax._resolve_score_backend(cfg, spec, 4.0) == "pallas"
        assert sim_jax._resolve_score_backend(cfg, spec, 4) == "pallas"
        assert sim_jax._resolve_score_backend(
            cfg, spec, jnp.asarray(4.0)) == "jnp"


@needs_dev_deps
class TestSsdChunkKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,L,H,P,N", [
        (2, 256, 2, 64, 32), (1, 512, 4, 64, 128), (2, 128, 2, 32, 16),
    ])
    def test_vs_oracle(self, B, L, H, P, N, dtype):
        ks = jax.random.split(jax.random.PRNGKey(L + H), 4)
        xdt = (rand(ks[0], (B, L, H, P), jnp.float32) * 0.3).astype(dtype)
        loga = -jax.nn.softplus(rand(ks[1], (B, L, H), jnp.float32))
        loga = loga.astype(dtype)
        Bm = (rand(ks[2], (B, L, H, N), jnp.float32) * 0.3).astype(dtype)
        Cm = (rand(ks[3], (B, L, H, N), jnp.float32) * 0.3).astype(dtype)
        out = ops.ssd_chunk(xdt, loga, Bm, Cm)
        # oracle operates per chunk of 256 (matches kernel Q)
        Q = min(256, L)
        outs = []
        for c in range(L // Q):
            sl = slice(c * Q, (c + 1) * Q)
            outs.append(kref.ssd_chunk_ref(xdt[:, sl], loga[:, sl],
                                           Bm[:, sl], Cm[:, sl]))
        ref = jnp.concatenate(outs, axis=1)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)

    def test_matches_model_ssd_scan(self):
        """kernel == models.ssm.ssd_scan y_diag path (zero init, 1 chunk)."""
        from repro.models import ssm
        B, Q, H, P, N = 1, 64, 2, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(9), 4)
        xdt = jax.random.normal(ks[0], (B, Q, H, P)) * 0.3
        loga = -jax.nn.softplus(jax.random.normal(ks[1], (B, Q, H)))
        Bm = jax.random.normal(ks[2], (B, Q, H, N)) * 0.3
        Cm = jax.random.normal(ks[3], (B, Q, H, N)) * 0.3
        y_scan, _ = ssm.ssd_scan(xdt, loga, Bm, Cm, chunk=Q)
        y_ker = ops.ssd_chunk(xdt, loga, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_scan),
                                   atol=1e-4)
