"""Engine-refactor contracts: (1) event-driven time advancement is
bit-exact with tick stepping for every policy — on the reference
engine AND on the JAX engine (``SimConfig.time_mode``, under jit,
vmap and ragged sentinel padding); (2) the simulator and the
controller really share one state machine — a minimal
controller-style driver over ``SchedulerCore`` reproduces the
simulator's results exactly; (3) the reference-vs-JAX parity matrix.

The policy lists are GENERATED from the policy registry: registering a
new dual-backend policy automatically enrolls it in both event-vs-tick
suites and (unless it is rng-driven) in the reference-vs-JAX matrix —
this file never needs editing for a new policy.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, policy_registry, simulator, workload
from repro.core import policies as pol
from repro.core.engine import ClusterState, CoreHooks, FIT_EPS, SchedulerCore
from repro.core.policy_registry import RNG_ALWAYS
from repro.core.types import JobSet
from repro.core.workload import sparse_long_horizon

POLICIES = policy_registry.policy_names()
# Reference-vs-JAX exact parity: every dual-backend policy whose
# victim selection is not rng-driven (RAND draws every invocation and
# is property-tested statistically instead; the score policies' random
# fallback does not fire on these generated workloads — asserted
# exactly, so a silently-firing fallback would be caught as a parity
# break, not masked).
JAX_EXACT = [s.name for s in policy_registry.all_policies()
             if s.dual_backend and s.rng != RNG_ALWAYS]
# JAX tick-vs-event parity covers EVERY dual-backend policy, rng-driven
# ones included: the event jump executes every tick on which the policy
# would be invoked, so the rng stream itself is mode-invariant.
JAX_ALL = [s.name for s in policy_registry.all_policies()
           if s.dual_backend]


def sparse_jobset(n=96, seed=0, gap=60.0):
    """Long-horizon trickle workload: most ticks are no-ops, so event
    mode actually exercises the fast-forward path (same generator the
    engine benchmark measures)."""
    return sparse_long_horizon(n, seed=seed, gap_mean=gap)


class TestEventTickParity:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_generated_workload(self, policy):
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=4), policy=policy,
                        workload=WorkloadSpec(n_jobs=192), seed=11)
        js = workload.generate(cfg)
        metrics.assert_result_parity(
            simulator.simulate(cfg, js, mode="tick"),
            simulator.simulate(cfg, js, mode="event"))

    @pytest.mark.parametrize("policy", POLICIES)
    def test_sparse_long_horizon(self, policy):
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=4), policy=policy)
        js = sparse_jobset(seed=3)
        metrics.assert_result_parity(
            simulator.simulate(cfg, js, mode="tick"),
            simulator.simulate(cfg, js, mode="event"))

    def test_gang_workload(self):
        cfg = SimConfig(
            cluster=ClusterSpec(n_nodes=6), policy="fitgpp", seed=2,
            workload=WorkloadSpec(n_jobs=160, multi_node_frac=0.25))
        js = workload.generate(cfg)
        metrics.assert_result_parity(
            simulator.simulate(cfg, js, mode="tick"),
            simulator.simulate(cfg, js, mode="event"))

    def test_backfill_workload(self):
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=4), policy="fitgpp",
                        workload=WorkloadSpec(n_jobs=160), seed=9,
                        backfill=True)
        js = workload.generate(cfg)
        metrics.assert_result_parity(
            simulator.simulate(cfg, js, mode="tick"),
            simulator.simulate(cfg, js, mode="event"))

    def test_closed_loop_admission(self):
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=4), policy="fifo",
                        workload=WorkloadSpec(n_jobs=160), seed=4)
        js = workload.generate(cfg)
        runs = []
        for mode in ("tick", "event"):
            sim = simulator.Simulator(cfg, js, admission_target=2.0)
            runs.append((sim.run(mode=mode), sim.admit_time.copy()))
        metrics.assert_result_parity(runs[0][0], runs[1][0])
        np.testing.assert_array_equal(runs[0][1], runs[1][1])

    def test_event_mode_is_default(self):
        """simulate() defaults to event mode and stays tick-exact."""
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=2), policy="fitgpp",
                        workload=WorkloadSpec(n_jobs=96), seed=6)
        js = workload.generate(cfg)
        metrics.assert_result_parity(
            simulator.simulate(cfg, js),
            simulator.simulate(cfg, js, mode="tick"))


class TestReferenceVsJaxMatrix:
    """Auto-generated from the registry: any newly registered
    dual-backend policy is parity-tested against the JAX engine in
    BOTH reference time-advancement modes without touching this file
    (the paper-default 84-node cluster keeps the score policies on
    their deterministic main path)."""

    @pytest.mark.parametrize("mode", ["tick", "event"])
    @pytest.mark.parametrize("policy", JAX_EXACT)
    def test_generated_workload(self, policy, mode):
        from repro.core import sim_jax
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=192), policy=policy,
                        seed=17)
        js = workload.generate(cfg)
        ref = simulator.simulate(cfg, js, mode=mode)
        st = sim_jax.run_jit(cfg, sim_jax.jobs_from_jobset(js), 17)
        np.testing.assert_array_equal(np.asarray(st.finish), ref.finish)
        np.testing.assert_array_equal(np.asarray(st.preempt_count),
                                      ref.preempt_count)

    def test_matrix_covers_new_policies(self):
        """Both beyond-paper policies are dual-backend registered and
        therefore enrolled in the matrix above."""
        assert {"srtp", "minsize"} <= set(JAX_EXACT)
        assert set(POLICIES) >= {"fifo", "fitgpp", "lrtp", "rand",
                                 "srtp", "minsize"}


def _assert_states_equal(a, b, context=""):
    """Full-State bit equality (one shared contract:
    ``sim_jax.state_diff_fields``)."""
    from repro.core import sim_jax
    diff = sim_jax.state_diff_fields(a, b)
    assert not diff, f"{context}: State differs in {diff}"


class TestJaxTickVsEventParity:
    """The JAX engine's tick-vs-event axis of the matrix, generated
    from the registry: every dual-backend policy — rand and the
    fallback paths INCLUDED, because the event jump never skips a tick
    on which the policy (and thus the PRNG) would be invoked — must
    produce a bit-identical final State in both time modes, under jit,
    under vmap, and under ragged sentinel padding."""

    @pytest.mark.parametrize("policy", JAX_ALL)
    def test_generated_workload(self, policy):
        """Closed-loop-derived workload (submit times recorded by the
        FIFO admission pass): full-State parity under jit."""
        from repro.core import sim_jax
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=4), policy=policy,
                        workload=WorkloadSpec(n_jobs=128), seed=23)
        jobs = sim_jax.jobs_from_jobset(workload.generate(cfg))
        a = sim_jax.run_jit(cfg, jobs, 23, time_mode="tick")
        b = sim_jax.run_jit(cfg, jobs, 23, time_mode="event")
        _assert_states_equal(a, b, f"jax tick/event {policy}")

    @pytest.mark.parametrize("policy", JAX_ALL)
    def test_sparse_long_horizon(self, policy):
        """The regime the event jump exists for: almost every tick is
        dead time."""
        from repro.core import sim_jax
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=4), policy=policy)
        jobs = sim_jax.jobs_from_jobset(sparse_jobset(n=96, seed=31))
        a = sim_jax.run_jit(cfg, jobs, 31, time_mode="tick")
        b = sim_jax.run_jit(cfg, jobs, 31, time_mode="event")
        _assert_states_equal(a, b, f"jax sparse tick/event {policy}")

    def test_vmapped_ragged_sweep(self):
        """Per-lane event jumps under vmap: a ragged (sentinel-padded)
        multi-workload sweep with heterogeneous horizons must match
        tick mode bitwise in every pooled statistic."""
        from repro.core import sweep
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=4), policy="fitgpp",
                        workload=WorkloadSpec(n_jobs=64))
        jobsets = [workload.generate(dataclasses.replace(
            cfg, workload=WorkloadSpec(n_jobs=n), seed=sd))
            for n, sd in ((40, 0), (64, 1), (52, 2))]
        stacked = sweep.stack_jobsets(jobsets)
        s_vals, p_vals, seeds = np.full(3, 4.0), np.full(3, 1), range(3)
        out = {tm: sweep.run_sweep(cfg, stacked, s_vals, p_vals, seeds,
                                   time_mode=tm)
               for tm in ("tick", "event")}
        for key in out["tick"]:
            assert np.array_equal(out["tick"][key], out["event"][key],
                                  equal_nan=True), key

    def test_default_time_mode_is_event(self):
        """SimConfig defaults to event mode on the JAX engine too, and
        the mode threads through run_experiment for both engines."""
        from repro import api
        assert SimConfig().time_mode == "event"
        r_ev = api.run_experiment(policy="fitgpp", engine="jax",
                                  n_jobs=64, n_nodes=4, mode="event")
        r_tk = api.run_experiment(policy="fitgpp", engine="jax",
                                  n_jobs=64, n_nodes=4, mode="tick")
        assert r_ev.table == r_tk.table
        assert r_ev.makespan == r_tk.makespan

    def test_rng_paths_statistical(self):
        """Distribution-level lock for the rng-driven paths (RAND's
        per-selection draws; fitgpp's fallback, forced here with P=0 so
        every selection falls back): pooled over DISJOINT PRNG seed
        sets — where runs are not pairwise comparable — the two time
        modes must still agree on the aggregate picture. Catches any
        future change that makes rng consumption tick-dependent."""
        from repro.core import sim_jax
        for policy, P in (("rand", 1), ("fitgpp", 0)):
            cfg = SimConfig(cluster=ClusterSpec(n_nodes=4), policy=policy,
                            workload=WorkloadSpec(n_jobs=128), seed=3,
                            max_preemptions=P)
            jobs = sim_jax.jobs_from_jobset(workload.generate(cfg))
            pooled = {}
            for tm, seed0 in (("tick", 0), ("event", 100)):
                sds, pre = [], []
                for k in range(4):
                    st = sim_jax.run_jit(cfg, jobs, seed0 + k,
                                         time_mode=tm)
                    sds.append(np.asarray(
                        sim_jax.slowdown(jobs, st)).mean())
                    pre.append(int(st.fallback_count) if P == 0
                               else np.asarray(st.preempt_count).sum())
                pooled[tm] = (np.mean(sds), np.mean(pre))
            sd_ratio = pooled["event"][0] / pooled["tick"][0]
            assert 0.8 < sd_ratio < 1.25, (policy, pooled)
            if pooled["tick"][1] or pooled["event"][1]:
                ct_ratio = (pooled["event"][1] + 1) / (pooled["tick"][1] + 1)
                assert 0.5 < ct_ratio < 2.0, (policy, pooled)


# Score-backend axis, generated from the registry: every dual-backend
# policy that registers the accelerated ("pallas") score path — which
# now routes the WHOLE schedule pass through the fused schedule_step
# kernel — must be full-State bit-exact with the jnp path.
JAX_ACCEL = [s.name for s in policy_registry.all_policies()
             if s.dual_backend and "pallas" in s.score_backends]


class TestScoreBackendMatrix:
    """jnp vs fused-Pallas schedule pass (``SimConfig.score_backend``),
    generated from the registry: registering a new accelerated policy
    enrolls it here without touching this file. Both time modes, plus
    a gang scenario so the kernel's all-or-nothing gang-fit tile and
    victim reduction face multi-node jobs."""

    def test_axis_nonempty(self):
        assert "fitgpp" in JAX_ACCEL

    @pytest.mark.parametrize("mode", ["tick", "event"])
    @pytest.mark.parametrize("policy", JAX_ACCEL)
    def test_generated_workload(self, policy, mode):
        from repro.core import sim_jax
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=8), policy=policy,
                        workload=WorkloadSpec(n_jobs=128), seed=11)
        jobs = sim_jax.jobs_from_jobset(workload.generate(cfg))
        a = sim_jax.run_jit(cfg, jobs, 11, time_mode=mode)
        b = sim_jax.run_jit(dataclasses.replace(cfg, score_backend="pallas"),
                            jobs, 11, time_mode=mode)
        _assert_states_equal(a, b, f"score backend {policy}/{mode}")

    @pytest.mark.parametrize("policy", JAX_ACCEL)
    def test_gang_scenario(self, policy):
        from repro import scenarios
        from repro.core import sim_jax
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=8), policy=policy,
                        workload=WorkloadSpec(n_jobs=96), seed=7)
        js = scenarios.build("gang-heavy", cfg)
        jobs = sim_jax.jobs_from_jobset(js)
        a = sim_jax.run_jit(cfg, jobs, 7)
        b = sim_jax.run_jit(dataclasses.replace(cfg, score_backend="pallas"),
                            jobs, 7)
        _assert_states_equal(a, b, f"score backend gang {policy}")


GANG_SCENARIOS = ("gang-heavy", "gang-trace-mix",
                  "philly-sample", "pai-sample")


class TestGangScenarioJaxMatrix:
    """Acceptance for the gang-capable JAX engine: the gang scenarios
    — gang-heavy, gang-trace-mix and BOTH trace adapters (whose gang
    widths come from GPU counts / inst_num) — run ``engine="jax"`` in
    both time modes with (1) reference-vs-JAX result parity for every
    deterministic registered policy and (2) full-State tick-vs-event
    bit-parity for every dual-backend policy, rng-driven ones
    included. Policy lists are generated from the registry; the
    paper-default 84-node cluster keeps the score policies on their
    deterministic main path (asserted via ``fallback_count``)."""

    _jobsets = {}

    @classmethod
    def _jobset(cls, scenario):
        from repro import scenarios
        if scenario not in cls._jobsets:
            cls._jobsets[scenario] = scenarios.build(scenario, cls._cfg())
        return cls._jobsets[scenario]

    @staticmethod
    def _cfg(policy="fitgpp"):
        return SimConfig(workload=WorkloadSpec(n_jobs=96), policy=policy,
                         seed=0)

    @pytest.mark.parametrize("mode", ["tick", "event"])
    @pytest.mark.parametrize("scenario", GANG_SCENARIOS)
    @pytest.mark.parametrize("policy", JAX_EXACT)
    def test_reference_vs_jax(self, scenario, policy, mode):
        from repro import api
        js = self._jobset(scenario)
        cfg = self._cfg(policy)
        ref = api.run_experiment(scenario, policy, "reference", cfg=cfg,
                                 jobs=js, mode=mode)
        jx = api.run_experiment(scenario, policy, "jax", cfg=cfg,
                                jobs=js, mode=mode)
        _, st = jx.raw
        spec = policy_registry.get_policy(policy)
        if spec.jax_kind == "score":
            assert int(st.fallback_count) == 0, \
                "random fallback fired; pick a quieter config"
        np.testing.assert_array_equal(np.asarray(st.finish),
                                      ref.raw.finish)
        np.testing.assert_array_equal(np.asarray(st.preempt_count),
                                      ref.raw.preempt_count)

    @pytest.mark.parametrize("scenario", GANG_SCENARIOS)
    @pytest.mark.parametrize("policy", JAX_ALL)
    def test_jax_tick_vs_event(self, scenario, policy):
        from repro.core import sim_jax
        js = self._jobset(scenario)
        jobs = sim_jax.jobs_from_jobset(js)
        cfg = self._cfg(policy)
        a = sim_jax.run_jit(cfg, jobs, 0, time_mode="tick")
        b = sim_jax.run_jit(cfg, jobs, 0, time_mode="event")
        _assert_states_equal(a, b, f"jax gang {scenario} {policy}")

    def test_gang_backfill_both_axes(self):
        """backfill x gangs: dual-engine result parity AND tick/event
        full-State parity (srtp: deterministic even past the P cap)."""
        from repro.core import sim_jax
        cfg = dataclasses.replace(self._cfg("srtp"), backfill=True)
        js = self._jobset("gang-heavy")
        jobs = sim_jax.jobs_from_jobset(js)
        ref = simulator.simulate(cfg, js, mode="tick")
        a = sim_jax.run_jit(cfg, jobs, 0, time_mode="tick")
        b = sim_jax.run_jit(cfg, jobs, 0, time_mode="event")
        _assert_states_equal(a, b, "gang backfill tick/event")
        np.testing.assert_array_equal(np.asarray(a.finish), ref.finish)
        np.testing.assert_array_equal(np.asarray(a.preempt_count),
                                      ref.preempt_count)


class MinimalDriver:
    """Controller-shaped driver over the shared core: arrivals by
    submit tick, 'work' is decrementing a per-job step budget — no
    training, no checkpoints. If this reproduces the simulator
    bit-for-bit, the scheduling semantics live in the core, not in
    either driver."""

    def __init__(self, cfg: SimConfig, js: JobSet):
        self.js = js
        self.remaining = js.exec_total.astype(np.int64).copy()
        self.finish = np.full(js.n, -1, np.int64)
        policy = policy_registry.make(cfg.policy, s=cfg.s)
        self.core = SchedulerCore(
            cluster=ClusterState(cfg.cluster.n_nodes,
                                 cfg.cluster.node.as_tuple()),
            policy=policy, max_preemptions=cfg.max_preemptions,
            rng=np.random.default_rng(cfg.seed + 104729),
            gp_of=lambda ids: js.gp[ids],
            remaining_of=lambda ids: self.remaining[ids],
            hooks=CoreHooks(on_finish=self._on_finish))
        for j in range(js.n):
            self.core.add_job(js.demand[j], bool(js.is_te[j]),
                              int(js.n_nodes[j]))

    def _on_finish(self, j, t):
        self.finish[j] = t

    def run(self, max_ticks=100_000):
        core, js = self.core, self.js
        arrived = 0
        order = np.argsort(js.submit, kind="stable")
        t = 0
        while core.n_done < js.n:
            while arrived < js.n and js.submit[order[arrived]] <= t:
                core.enqueue(int(order[arrived]))
                arrived += 1
            core.expire_grace(t)
            core.schedule(t)
            for j in sorted(core.running):
                self.remaining[j] -= 1
                if self.remaining[j] <= 0:
                    core.finish(j, t + 1)
            core.tick_clocks()
            t += 1
            assert t < max_ticks, "driver did not converge"
        return self.finish


class TestSharedCoreSemantics:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_minimal_driver_matches_simulator(self, policy):
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=3), policy=policy,
                        seed=13)
        js = sparse_jobset(n=64, seed=21, gap=8.0)
        ref = simulator.simulate(cfg, js, mode="tick")
        drv = MinimalDriver(cfg, js)
        finish = drv.run()
        np.testing.assert_array_equal(finish, ref.finish)
        np.testing.assert_array_equal(drv.core.preempt_count,
                                      ref.preempt_count)

    def test_controller_uses_shared_core(self):
        """The live-training controller must not duplicate the queue /
        preemption machinery — its scheduling state IS a SchedulerCore."""
        controller = pytest.importorskip("repro.core.controller")
        src_attrs = dir(controller.Controller)
        for dup in ("_first_fit", "_try_preempt", "_queued", "_signal",
                    "_vacate", "_start"):
            assert dup not in src_attrs, \
                f"controller re-implements {dup}; use the engine core"
        import inspect
        src = inspect.getsource(controller)
        assert "SchedulerCore" in src


class TestFitEps:
    def test_single_epsilon_everywhere(self):
        from repro.core import sim_jax
        from repro.core.engine import placement
        assert sim_jax._EPS == FIT_EPS == placement.FIT_EPS

    def test_exact_fit_eligible(self):
        """Eq. 2 and _preempt_until_fits agree on exact fits (no more
        tolerance drift between the fit paths)."""
        te = np.array([8.0, 32.0, 4.0])
        elig = pol.eligible_eq2(te, np.array([[8.0, 32.0, 4.0]]),
                                np.zeros((1, 3)))
        assert elig.tolist() == [True]
        victims = pol._preempt_until_fits(
            order=np.array([0]), te_demand=te,
            cand_ids=np.array([0]), cand_demand=np.array([[8., 32., 4.]]),
            cand_node=np.array([0]), under_cap=np.array([True]),
            free_by_node=np.zeros((1, 3)), rng=np.random.default_rng(0))
        assert victims == [0]
