"""Per-arch smoke tests: reduced same-family config, one forward + one
train step on CPU, asserting output shapes and no NaNs; plus a decode
consistency check per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models, trainer
from repro.configs import get_config, get_smoke_config, list_archs
from repro.data import make_batch
from repro.optim import AdamWConfig

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.n_layers <= 4
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = models.init(cfg, jax.random.key(0))
    batch = make_batch(cfg, 2, 32, seed=0, step=0)
    logits = models.forward(cfg, params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = trainer.init_train_state(cfg, ocfg, jax.random.key(0))
    step = jax.jit(trainer.make_train_step(cfg, ocfg))
    losses = []
    for i in range(3):
        state, m = step(state, make_batch(cfg, 2, 32, seed=0, step=i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] + 0.5      # not diverging


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_decode_consistency(arch):
    """Incremental decode must match the full forward pass."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = models.init(cfg, jax.random.key(0))
    T = 12
    batch = make_batch(cfg, 2, 64, seed=0, step=0)
    batch["tokens"] = batch["tokens"][:, :T]
    ref = models.forward(cfg, params, batch)

    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :4]
    nv = cfg.vlm.n_visual_tokens if cfg.family == "vlm" else 0
    lg, cache = models.prefill(cfg, params, prompt, pad_to=nv + T)
    outs = [lg]
    step = jax.jit(lambda p, c, t: models.serve_step(cfg, p, c, t))
    for i in range(4, T):
        lg, cache = step(params, cache, batch["tokens"][:, i:i + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = jnp.abs(dec - ref[:, nv + 3:nv + T]).max()
    assert err < 5e-5, f"{arch}: decode err {err}"


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256_000),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92_553),
        "mamba2-1.3b": (48, 2048, 64, 64, 0, 50_280),
        "command-r-35b": (40, 8192, 64, 8, 22_528, 256_000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51_866),
        "stablelm-12b": (40, 5120, 32, 8, 13_824, 100_352),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151_936),
        "nemotron-4-340b": (96, 18_432, 96, 8, 73_728, 256_000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16_384, 32_768),
        "mistral-large-123b": (88, 12_288, 96, 8, 28_672, 32_768),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected
    if arch == "qwen3-moe-30b-a3b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 8
    if arch == "mixtral-8x22b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
        assert cfg.window > 0                      # native SWA
    if arch == "mamba2-1.3b":
        assert cfg.ssm.d_state == 128
    if arch == "nemotron-4-340b":
        assert cfg.activation == "sq_relu" and not cfg.gated_mlp


def test_param_counts_sane():
    expected_b = {
        "recurrentgemma-9b": (7.5, 10.5), "internvl2-2b": (1.6, 2.4),
        "mamba2-1.3b": (1.1, 1.6), "command-r-35b": (28, 37),
        "whisper-large-v3": (1.2, 1.9), "stablelm-12b": (11, 13.5),
        "qwen3-moe-30b-a3b": (28, 33), "nemotron-4-340b": (330, 350),
        "mixtral-8x22b": (135, 146), "mistral-large-123b": (118, 128),
    }
    for arch, (lo, hi) in expected_b.items():
        n = models.count_params(get_config(arch)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.1f}B not in [{lo}, {hi}]"
