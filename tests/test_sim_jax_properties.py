"""Engine-wide invariants of the JAX engine, observed at every event
boundary in BOTH time modes (``SimConfig.time_mode``, DESIGN.md §7).

The step function from ``sim_jax.make_tick`` is iterated from Python
(one jitted call per executed tick / event jump), so every intermediate
``State`` is inspectable:

  * resource safety — ``free >= -FIT_EPS`` on every node, never above
    capacity, and conservation: free + demand of RUNNING/GRACE jobs on
    a node == capacity;
  * the paper's P cap — ``sum(max(preempt_count - P, 0))`` never
    exceeds ``State.fallback_count`` (the count of selections that fell
    back past the main masked path), so with no fallback firings
    ``preempt_count <= P`` exactly;
  * TE jobs never enter GRACE (only BE jobs are preempted);
  * ``n_done`` is monotone, always equals the DONE count, and
    terminally covers every valid job;
  * queue keys respect the requeue-on-top rule: victims re-enter with
    negative (strictly decreasing) keys that sort before every arrival
    key, arrivals keep their submission index;
  * sentinel padding stays inert (born DONE, never placed).

A seeded-random class runs everywhere; a hypothesis class (skipped
cleanly without the dev extras, like the other property suites) drives
the same checker over drawn jobsets padded to a fixed shape — which
also exercises the sentinel-padding contract.

Cross-mode: every event-mode boundary State must equal the tick-mode
State at the same ``t``, bit for bit — the "same State at every event
boundary" guarantee that makes ``"event"`` a pure wall-clock change.
"""
import numpy as np
import pytest

import jax

from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import policy_registry, sim_jax, sweep
from repro.core.engine.placement import FIT_EPS
from repro.core.sim_jax import DONE, GRACE, QUEUED, RUNNING
from repro.core.types import JobSet

MODES = ("tick", "event")
JAX_POLICIES = [s.name for s in policy_registry.all_policies()
                if s.dual_backend]


def random_jobset(seed: int, n: int = 32, gang_frac: float = 0.0,
                  max_width: int = 2) -> JobSet:
    """Adversarially small cluster-sized random workload: whole-node
    demands appear, so preemption, the P cap and the random fallback
    all fire. ``gang_frac`` > 0 mixes in multi-node gangs (widths
    2..max_width) to drive the gang placement/selection paths."""
    rng = np.random.default_rng(seed)
    submit = np.cumsum(rng.integers(0, 4, n))
    is_te = rng.random(n) < 0.4
    exec_total = rng.integers(1, 25, n)
    demand = np.stack([
        rng.integers(1, 33, n).astype(float),
        rng.integers(1, 257, n).astype(float),
        rng.choice([0.0, 1.0, 2.0, 4.0, 8.0], n)], axis=1)
    gp = rng.integers(0, 6, n)
    n_nodes = None
    if gang_frac > 0:
        n_nodes = np.where(rng.random(n) < gang_frac,
                           rng.integers(2, max_width + 1, n),
                           1).astype(np.int64)
    return JobSet(submit=submit.astype(np.int64),
                  exec_total=exec_total.astype(np.int64),
                  demand=demand, is_te=is_te,
                  gp=gp.astype(np.int64), n_nodes=n_nodes)


def iterate_states(cfg, jobs: sim_jax.Jobs, seed: int, time_mode: str,
                   max_steps: int = 50_000):
    """Run step-by-step; returns every State from init to terminal."""
    n_nodes = cfg.cluster.n_nodes
    step = jax.jit(sim_jax.make_tick(cfg, jobs, n_nodes,
                                     time_mode=time_mode))
    st = sim_jax.init_state(jobs, n_nodes, cfg.cluster.node.as_tuple(),
                            seed)
    N = jobs.submit.shape[0]
    states = [st]
    while int(st.n_done) < N and int(st.t) < (1 << 22):
        st = step(st)
        states.append(st)
        assert len(states) < max_steps, "simulation did not converge"
    return states


def check_invariants(cfg, jobs: sim_jax.Jobs, states) -> None:
    cap = np.asarray(cfg.cluster.node.as_tuple())
    P = cfg.max_preemptions
    valid = np.asarray(jobs.valid)
    is_te = np.asarray(jobs.is_te)
    demand = np.asarray(jobs.demand)
    width = np.asarray(jobs.width)
    prev_done = -1
    for st in states:
        t = int(st.t)
        state = np.asarray(st.state)
        free = np.asarray(st.free)
        assign = np.asarray(st.assign)
        pc = np.asarray(st.preempt_count)
        qk = np.asarray(st.queue_key)

        # resource safety + conservation over the assignment mask
        assert (free >= -FIT_EPS).all(), f"over-allocated at t={t}"
        assert (free <= cap[None] + FIT_EPS).all(), \
            f"free above capacity at t={t}"
        occupies = (state == RUNNING) | (state == GRACE)
        used = np.einsum("nm,nr->mr", (assign & occupies[:, None]),
                         demand)
        assert np.allclose(used + free, cap[None]), \
            f"conservation violated at t={t}"
        # assignment-mask shape: occupying jobs hold exactly their gang
        # width; everyone else holds nothing
        held = assign.sum(axis=1)
        assert (held[occupies] == width[occupies]).all(), \
            f"gang width violated at t={t}"
        assert (held[~occupies] == 0).all(), \
            f"non-occupying job holds nodes at t={t}"

        # the P cap, exact modulo counted fallback firings
        fallback = int(st.fallback_count)
        over = np.maximum(pc - P, 0).sum()
        assert over <= fallback, \
            f"P cap broken beyond fallback allowance at t={t}: " \
            f"{over} > {fallback}"
        if fallback == 0:
            assert (pc <= P).all(), f"P cap exceeded at t={t}"

        # TE jobs are never preempted into GRACE
        assert not (is_te & (state == GRACE)).any(), f"TE in GRACE at t={t}"
        assert (pc[is_te] == 0).all(), f"TE preempted at t={t}"

        # grace clocks never go negative at a boundary
        assert (np.asarray(st.grace_left)[state == GRACE] >= 0).all()

        # n_done: monotone, equals the DONE count
        n_done = int(st.n_done)
        assert n_done >= prev_done, f"n_done regressed at t={t}"
        assert n_done == (state == DONE).sum(), f"n_done drifted at t={t}"
        prev_done = n_done

        # queue keys: arrivals keep their submission index; victims
        # requeue on TOP with negative keys (strictly before arrivals)
        n_idx = np.arange(len(valid))
        queued = state == QUEUED
        fresh = queued & (pc == 0)
        assert (qk[fresh] == n_idx[fresh]).all(), \
            f"arrival key drifted at t={t}"
        requeued = queued & (pc > 0)
        assert (qk[requeued] < 0).all(), f"victim not on top at t={t}"
        assert len(set(qk[requeued])) == requeued.sum(), \
            f"duplicate requeue keys at t={t}"

        # sentinel padding stays inert
        assert (state[~valid] == DONE).all(), f"sentinel woke up at t={t}"
        assert not assign[~valid].any(), f"sentinel placed at t={t}"

    # terminal: every valid job is done exactly once, after its arrival
    last = states[-1]
    state = np.asarray(last.state)
    finish = np.asarray(last.finish)
    assert int(last.n_done) == len(valid)
    assert (state[valid] == DONE).all()
    submit = np.asarray(jobs.submit)
    exec_total = np.asarray(jobs.exec_total)
    assert (finish[valid] >= submit[valid] + exec_total[valid]).all()


def run_and_check(cfg, js: JobSet, seed: int = 0, pad_to: int = 0):
    jobs = sim_jax.jobs_from_jobset(js)
    if pad_to:
        jobs = sweep.pad_jobs(jobs, pad_to)
    per_t = {}
    for mode in MODES:
        states = iterate_states(cfg, jobs, seed, mode)
        check_invariants(cfg, jobs, states)
        per_t[mode] = {int(st.t): st for st in states}
    # every event boundary matches the tick-mode State bit-for-bit
    missing = set(per_t["event"]) - set(per_t["tick"])
    assert not missing, f"event boundaries unknown to tick mode: {missing}"
    for t, st_e in per_t["event"].items():
        diff = sim_jax.state_diff_fields(per_t["tick"][t], st_e)
        assert not diff, f"tick/event State diverges at t={t} in {diff}"


def small_cfg(policy: str, n_nodes: int = 2, P: int = 1) -> SimConfig:
    return SimConfig(cluster=ClusterSpec(n_nodes=n_nodes), policy=policy,
                     workload=WorkloadSpec(n_jobs=32), max_preemptions=P)


class TestInvariantsSeeded:
    """Seeded-random invariant matrix (runs without dev extras)."""

    @pytest.mark.parametrize("policy", JAX_POLICIES)
    def test_policy_matrix(self, policy):
        run_and_check(small_cfg(policy), random_jobset(seed=1), seed=1)

    @pytest.mark.parametrize("seed", [2, 3, 4])
    def test_fitgpp_seeds(self, seed):
        run_and_check(small_cfg("fitgpp", P=seed % 3 + 1),
                      random_jobset(seed=seed), seed=seed)

    def test_ragged_padding(self):
        """Same invariants with sentinel rows appended (the vmapped
        ragged-sweep shape)."""
        run_and_check(small_cfg("fitgpp"), random_jobset(seed=5, n=24),
                      seed=5, pad_to=32)

    @pytest.mark.parametrize("policy", JAX_POLICIES)
    def test_gang_policy_matrix(self, policy):
        """The same engine-wide invariants with multi-node gangs in the
        mix (all-or-nothing placement, gang vacates, gang victim
        selection) — conservation now sums over the assignment mask."""
        run_and_check(small_cfg(policy, n_nodes=3),
                      random_jobset(seed=7, gang_frac=0.3, max_width=3),
                      seed=7)

    def test_gang_ragged_padding(self):
        """Gang widths ride through sentinel padding; sentinels stay
        width-1 and never hold nodes."""
        run_and_check(small_cfg("fitgpp", n_nodes=3),
                      random_jobset(seed=8, n=24, gang_frac=0.3,
                                    max_width=3),
                      seed=8, pad_to=32)

    def test_gang_backfill(self):
        """Backfill x gangs on the JAX engine: the bounded scan keeps
        every invariant (and tick/event parity, via run_and_check)."""
        import dataclasses
        cfg = dataclasses.replace(small_cfg("fitgpp", n_nodes=3),
                                  backfill=True, backfill_depth=4)
        run_and_check(cfg, random_jobset(seed=9, gang_frac=0.3,
                                         max_width=3), seed=9)

    @pytest.mark.parametrize("name", ["te-flood", "sparse-long-horizon"])
    def test_registered_scenarios(self, name):
        from repro import scenarios
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=3), policy="fitgpp",
                        workload=WorkloadSpec(n_jobs=48), seed=6)
        run_and_check(cfg, scenarios.build(name, cfg), seed=6)


class TestInvariantsHypothesis:
    """Hypothesis-driven jobsets, padded to one fixed shape so the
    engine compiles once per (policy, mode)."""

    @classmethod
    def setup_class(cls):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (pip install -r "
                   "requirements-dev.txt)")

    pytestmark = pytest.mark.hypothesis

    def test_drawn_jobsets(self):
        from hypothesis import HealthCheck, given, settings, strategies as st

        @st.composite
        def jobsets(draw, max_jobs=28):
            n = draw(st.integers(4, max_jobs))
            submit = np.cumsum(draw(st.lists(
                st.integers(0, 3), min_size=n, max_size=n)))
            execs = draw(st.lists(st.integers(1, 15), min_size=n,
                                  max_size=n))
            cpus = draw(st.lists(st.integers(1, 32), min_size=n,
                                 max_size=n))
            rams = draw(st.lists(st.integers(1, 256), min_size=n,
                                 max_size=n))
            gpus = draw(st.lists(st.sampled_from([0, 1, 2, 4, 8]),
                                 min_size=n, max_size=n))
            te = draw(st.lists(st.booleans(), min_size=n, max_size=n))
            gp = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
            return JobSet(
                submit=np.asarray(submit, np.int64),
                exec_total=np.asarray(execs, np.int64),
                demand=np.stack([np.asarray(cpus, float),
                                 np.asarray(rams, float),
                                 np.asarray(gpus, float)], 1),
                is_te=np.asarray(te, bool),
                gp=np.asarray(gp, np.int64))

        @settings(max_examples=12, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        @given(jobsets(), st.sampled_from(["fifo", "lrtp", "fitgpp"]),
               st.integers(1, 3))
        def inner(js, policy, P):
            run_and_check(small_cfg(policy, P=P), js, seed=0, pad_to=28)

        inner()


class TestSlowdownDecomposition:
    """The slowdown decomposition identity (DESIGN.md §8):

        finish - submit == initial_wait + grace_stall + requeue_wait
                           + service

    must hold EXACTLY for every finished job, on traces from BOTH
    engines, with gangs and backfill in the mix. ``service`` must
    equal the job's execution time — remaining only counts down while
    RUNNING, so any drift here means an engine ran (or stalled) a job
    outside its recorded placement spans."""

    # (scenario, policy, n_jobs, n_nodes, backfill): saturated
    # clusters so preemption, grace stalls and requeue waits all
    # contribute nonzero terms; the last config adds the random
    # fallback path (identity is per-trace, not cross-engine).
    CONFIGS = (
        ("gang-heavy", "lrtp", 96, 16, False),
        ("gang-heavy", "lrtp", 96, 16, True),
        ("te-flood", "fitgpp", 96, 8, False),
    )

    @pytest.mark.parametrize("engine", ["reference", "jax"])
    @pytest.mark.parametrize("scen,policy,n_jobs,n_nodes,backfill",
                             CONFIGS)
    def test_identity_every_job(self, scen, policy, n_jobs, n_nodes,
                                backfill, engine):
        import dataclasses

        from repro import scenarios
        from repro.core import simulator
        from repro.obs import timeseries
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=n_nodes),
                        policy=policy,
                        workload=WorkloadSpec(n_jobs=n_jobs), seed=3,
                        backfill=backfill)
        js = scenarios.build(scen, cfg)
        if engine == "reference":
            res = simulator.simulate(cfg, js, trace=True)
            events, finish = res.trace, res.finish
        else:
            jobs = sim_jax.jobs_from_jobset(js)
            st = sim_jax.run_jit(cfg, jobs, cfg.seed, trace=True)
            events, overflow = sim_jax.decode_trace(st)
            assert overflow == 0
            finish = np.asarray(st.finish)
        dec = timeseries.slowdown_decomposition(events)
        assert set(dec) == set(range(js.n))
        n_preempted = 0
        for j, d in dec.items():
            assert d.finish == finish[j], (engine, j)
            assert d.identity_holds(), (engine, j, d)
            assert d.service == int(js.exec_total[j]), (engine, j, d)
            n_preempted += d.grace_stall > 0 or d.requeue_wait > 0
        assert n_preempted > 0, "config exercised no preemption terms"
