"""One Policy API contracts (DESIGN.md §6): every registered policy —
the 4 paper policies plus the beyond-paper ``srtp``/``minsize`` — runs
on BOTH engines through ``repro.api.run_experiment`` with zero engine
edits; config validation fails fast with the registered names; the
deprecated ``make_policy`` shim still works."""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import policy_registry as preg
from repro.core import policies as pol

ALL_POLICIES = preg.policy_names()


class TestRunExperimentMatrix:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_reference_engine(self, policy):
        r = api.run_experiment("te-flood", policy, "reference",
                               n_jobs=64, n_nodes=4, seed=3)
        assert r.engine == "reference" and r.policy == policy
        assert r.makespan > 0
        assert np.isfinite(r.table["TE"]["p95"])
        assert (r.raw.finish > 0).all()

    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_jax_engine(self, policy):
        spec = preg.get_policy(policy)
        assert spec.dual_backend, \
            f"{policy} registered without a JAX declaration"
        r = api.run_experiment("te-flood", policy, "jax",
                               n_jobs=64, n_nodes=4, seed=3)
        assert r.engine == "jax" and r.makespan > 0
        assert np.isfinite(r.table["BE"]["p50"])
        _, st = r.raw
        assert (np.asarray(st.finish) > 0).all()

    def test_shared_jobs_across_policies(self):
        """compare_policies runs the non-preemptive baseline and a
        preemptive policy on ONE jobset; preemption must help TE."""
        out = api.compare_policies(("fifo", "fitgpp"), n_jobs=128,
                                   n_nodes=8, seed=1)
        assert out["fitgpp"].table["TE"]["p95"] \
            < out["fifo"].table["TE"]["p95"]

    def test_unknown_engine_and_scenario(self):
        with pytest.raises(ValueError, match="unknown engine"):
            api.run_experiment(policy="fifo", engine="verilog")
        with pytest.raises(KeyError, match="registered"):
            api.run_experiment("no-such-scenario", "fifo",
                               n_jobs=8, n_nodes=2)

    def test_base_cfg_policy_is_preserved(self):
        """A caller-configured base cfg is never silently re-pointed to
        the default policy."""
        base = SimConfig(cluster=ClusterSpec(n_nodes=4),
                         workload=WorkloadSpec(n_jobs=48), policy="srtp")
        assert api.make_config(base=base).policy == "srtp"
        r = api.run_experiment("te-flood", cfg=base)
        assert r.policy == "srtp" and r.cfg.policy == "srtp"
        assert api.make_config("lrtp", base=base).policy == "lrtp"

    def test_mode_passthrough_bit_exact(self):
        a = api.run_experiment(policy="srtp", n_jobs=96, n_nodes=4,
                               seed=2, mode="tick")
        b = api.run_experiment(policy="srtp", n_jobs=96, n_nodes=4,
                               seed=2, mode="event")
        np.testing.assert_array_equal(a.raw.finish, b.raw.finish)


class TestConfigValidation:
    def test_unknown_policy_names_registry(self):
        with pytest.raises(ValueError, match="known policies: .*fitgpp"):
            SimConfig(policy="fitgp")          # typo'd name, caught early

    def test_bad_s_and_p(self):
        with pytest.raises(ValueError, match="Eq. 3"):
            SimConfig(s=float("inf"))
        with pytest.raises(ValueError, match="Eq. 3"):
            SimConfig(s=-1.0)
        with pytest.raises(ValueError, match="max_preemptions"):
            SimConfig(max_preemptions=-2)
        with pytest.raises(ValueError, match="max_preemptions"):
            SimConfig(max_preemptions=1.5)

    def test_score_backend_names(self):
        SimConfig(policy="fitgpp", score_backend="pallas")   # registered
        # inert on non-score policies (configs are re-pointed across
        # policies via dataclasses.replace; the engine falls back to jnp)
        SimConfig(policy="lrtp", score_backend="pallas")
        SimConfig(policy="fifo", score_backend="pallas")
        with pytest.raises(ValueError, match="unknown score backend"):
            SimConfig(policy="fitgpp", score_backend="cuda")

    def test_replace_revalidates(self):
        cfg = SimConfig()
        with pytest.raises(ValueError, match="known policies"):
            dataclasses.replace(cfg, policy="bogus")


class TestRegistry:
    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            preg.register_policy("fitgpp")(pol.FitGppPolicy)

    def test_specs_carry_backend_declarations(self):
        fit = preg.get_policy("fitgpp")
        assert fit.jax_kind == "score" and "pallas" in fit.score_backends
        assert preg.get_policy("lrtp").jax_kind == "rank"
        assert preg.get_policy("fifo").preemptive is False
        for spec in preg.all_policies():
            assert "jnp" in spec.score_backends
            assert spec.description

    def test_make_applies_s(self):
        p = preg.make("fitgpp", s=7.5)
        assert isinstance(p, pol.FitGppPolicy) and p.s == 7.5
        from repro.configs.base import PAPER_S
        assert preg.make("fitgpp").s == PAPER_S

    def test_deprecated_make_policy_shim(self):
        with pytest.warns(DeprecationWarning, match="policy_registry"):
            p = pol.make_policy("lrtp")
        assert isinstance(p, pol.LrtpPolicy)

    def test_gang_selection_uses_argmin_trait(self):
        """preemption.gang_select dispatches on the registered
        argmin_select trait, not on a policy-name string."""
        import inspect
        from repro.core.engine import preemption
        src = inspect.getsource(preemption)
        assert '== "fitgpp"' not in src
        assert pol.MinSizePolicy.argmin_select \
            and pol.FitGppPolicy.argmin_select

    def test_no_string_dispatch_left_in_engines(self):
        """Acceptance: no policy-name branching in sim_jax/simulator."""
        import inspect
        from repro.core import sim_jax, simulator
        for mod in (sim_jax, simulator):
            src = inspect.getsource(mod)
            for name in ALL_POLICIES:
                assert f'== "{name}"' not in src, (mod.__name__, name)
