"""Unit tests for the paper's equations and queue semantics."""
import numpy as np
import pytest

from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import policies as pol
from repro.core import simulator, workload
from repro.core.types import DONE, GRACE, QUEUED, RUNNING, JobSet

NODE_CAP = np.array([32.0, 256.0, 8.0])


def make_jobs(rows):
    """rows: (submit, exec, cpu, ram, gpu, is_te, gp)"""
    r = np.asarray(rows, dtype=float)
    return JobSet(
        submit=r[:, 0].astype(np.int64),
        exec_total=r[:, 1].astype(np.int64),
        demand=r[:, 2:5],
        is_te=r[:, 5].astype(bool),
        gp=r[:, 6].astype(np.int64),
    )


def small_cfg(policy="fitgpp", n_nodes=2, s=4.0, P=1):
    from repro.configs.cluster import ClusterSpec
    return SimConfig(cluster=ClusterSpec(n_nodes=n_nodes),
                     policy=policy, s=s, max_preemptions=P)


class TestEq1Size:
    def test_scale_invariance(self):
        """Eq. 1 must be invariant under the measurement scale."""
        d = np.array([[4.0, 64.0, 2.0]])
        s1 = pol.size_eq1(d, NODE_CAP)
        # re-measure RAM in MB: demand and capacity both x1024
        d2 = d.copy()
        d2[:, 1] *= 1024
        cap2 = NODE_CAP.copy()
        cap2[1] *= 1024
        assert np.allclose(s1, pol.size_eq1(d2, cap2))

    def test_monotone(self):
        small = pol.size_eq1(np.array([[1.0, 1.0, 1.0]]), NODE_CAP)
        big = pol.size_eq1(np.array([[16.0, 128.0, 8.0]]), NODE_CAP)
        assert big > small

    def test_formula(self):
        d = np.array([[16.0, 128.0, 4.0]])
        expect = np.sqrt((16 / 32) ** 2 + (128 / 256) ** 2 + (4 / 8) ** 2)
        assert np.allclose(pol.size_eq1(d, NODE_CAP), expect)


class TestEq2Eligibility:
    def test_elementwise(self):
        te = np.array([8.0, 32.0, 4.0])
        demand = np.array([[8.0, 32.0, 4.0],    # exactly sufficient
                           [8.0, 32.0, 3.0]])   # gpu short by 1
        free = np.zeros((2, 3))
        elig = pol.eligible_eq2(te, demand, free)
        assert elig.tolist() == [True, False]

    def test_free_counts(self):
        te = np.array([8.0, 32.0, 4.0])
        demand = np.array([[8.0, 32.0, 3.0]])
        free = np.array([[0.0, 0.0, 1.0]])      # free GPU closes the gap
        assert pol.eligible_eq2(te, demand, free).tolist() == [True]


class TestEq3Score:
    def test_gp_weight(self):
        demand = np.array([[4.0, 16.0, 2.0], [4.0, 16.0, 2.0]])
        gp = np.array([0.0, 10.0])
        s0 = pol.fitgpp_scores(demand, gp, NODE_CAP, s=0.0)
        s4 = pol.fitgpp_scores(demand, gp, NODE_CAP, s=4.0)
        assert np.allclose(s0[0], s0[1])        # s=0: GP ignored
        assert s4[1] > s4[0]                    # s>0: long GP penalized

    def test_normalized_by_max(self):
        demand = np.array([[4.0, 16.0, 2.0], [8.0, 32.0, 4.0]])
        gp = np.array([5.0, 5.0])
        sc = pol.fitgpp_scores(demand, gp, NODE_CAP, s=1.0)
        assert np.isclose(sc[1], 1.0 + 1.0)     # max size, max gp -> 1+s


class TestEq4Selection:
    def _select(self, policy, te_demand, cand_demand, free, gps,
                remaining=None, under=None, nodes=None):
        n = len(cand_demand)
        rng = np.random.default_rng(0)
        nodes = np.zeros(n, np.int64) if nodes is None else nodes
        return policy.select(
            rng=rng, te_demand=np.asarray(te_demand),
            cand_ids=np.arange(n),
            cand_demand=np.asarray(cand_demand, float),
            cand_node_free=np.asarray(free, float),
            cand_gp=np.asarray(gps, float),
            cand_remaining=np.asarray(remaining if remaining is not None
                                      else np.ones(n), float),
            under_cap=np.asarray(under if under is not None
                                 else np.ones(n, bool)),
            all_run_demand=np.asarray(cand_demand, float),
            all_run_gp=np.asarray(gps, float),
            node_cap=NODE_CAP,
            free_by_node=np.zeros((4, 3)),
            cand_node=nodes)

    def test_fitgpp_prefers_small_sufficient(self):
        p = pol.FitGppPolicy(s=0.0)
        te = [4.0, 16.0, 2.0]
        cands = [[16.0, 128.0, 8.0],    # big, sufficient
                 [4.0, 16.0, 2.0],      # small, sufficient  <- winner
                 [2.0, 8.0, 1.0]]       # smaller but NOT sufficient
        free = [[0, 0, 0], [0, 0, 0], [0, 0, 0]]
        v = self._select(p, te, cands, free, [1, 1, 1])
        assert v == [1]

    def test_fitgpp_prefers_short_gp(self):
        p = pol.FitGppPolicy(s=4.0)
        te = [4.0, 16.0, 2.0]
        cands = [[4.0, 16.0, 2.0], [4.0, 16.0, 2.0]]
        free = [[0, 0, 0], [0, 0, 0]]
        v = self._select(p, te, cands, free, gps=[10, 1])
        assert v == [1]

    def test_fitgpp_respects_p_cap(self):
        p = pol.FitGppPolicy(s=0.0)
        te = [4.0, 16.0, 2.0]
        cands = [[4.0, 16.0, 2.0], [8.0, 32.0, 4.0]]
        free = [[0, 0, 0], [0, 0, 0]]
        v = self._select(p, te, cands, free, [1, 1],
                         under=[False, True])   # first is at the cap
        assert v == [1]

    def test_fitgpp_single_victim(self):
        p = pol.FitGppPolicy()
        te = [4.0, 16.0, 2.0]
        cands = [[8.0, 64.0, 4.0]] * 5
        free = [[0, 0, 0]] * 5
        assert len(self._select(p, te, cands, free, np.ones(5))) == 1

    def test_lrtp_picks_longest(self):
        p = pol.LrtpPolicy()
        te = [4.0, 16.0, 2.0]
        cands = [[8.0, 64.0, 4.0], [8.0, 64.0, 4.0]]
        free = [[0, 0, 0], [0, 0, 0]]
        v = self._select(p, te, cands, free, [1, 1], remaining=[10, 99])
        assert v[0] == 1

    def test_lrtp_until_fits(self):
        """LRTP accumulates victims until the TE fits on one node."""
        p = pol.LrtpPolicy()
        te = [8.0, 64.0, 8.0]
        cands = [[4.0, 32.0, 4.0], [4.0, 32.0, 4.0]]   # both on node 0
        free = [[0, 0, 0], [0, 0, 0]]
        v = self._select(p, te, cands, free, [1, 1], remaining=[5, 9],
                         nodes=np.zeros(2, np.int64))
        assert sorted(v) == [0, 1]


class TestSimulatorSemantics:
    def test_fifo_head_of_line(self):
        """A big head BE blocks later (fitting) BE jobs: strict FIFO."""
        jobs = make_jobs([
            (0, 5, 32, 256, 8, 0, 0),     # fills node 0 entirely
            (0, 5, 32, 256, 8, 0, 0),     # fills node 1 entirely
            (1, 5, 32, 256, 8, 0, 0),     # head of queue, can't fit
            (1, 1, 1, 1, 0, 0, 0),        # small; must WAIT behind head
        ])
        cfg = small_cfg("fifo", n_nodes=2)
        res = simulator.simulate(cfg, jobs)
        # job 3 (1 min) must not finish before job 2 starts at t=5
        assert res.finish[3] > 5

    def test_te_triggers_preemption(self):
        jobs = make_jobs([
            (0, 30, 32, 256, 8, 0, 2),     # BE fills node 0
            (0, 30, 32, 256, 8, 0, 2),     # BE fills node 1
            (1, 3, 16, 128, 4, 1, 0),      # TE arrives: must preempt
        ])
        cfg = small_cfg("fitgpp", n_nodes=2)
        res = simulator.simulate(cfg, jobs)
        assert res.preempt_count.sum() == 1
        te_sd = res.slowdown[2]
        assert te_sd < 3.0                 # scheduled after ~GP ticks

    def test_grace_period_delays_te(self):
        base = [(0, 30, 32, 256, 8, 0, 0), (0, 30, 32, 256, 8, 0, 0),
                (1, 5, 16, 128, 4, 1, 0)]
        cfg = small_cfg("fitgpp", n_nodes=2)
        fast = simulator.simulate(cfg, make_jobs(base))
        slow_rows = [r[:6] + (10,) if not r[5] else r for r in base]
        slow = simulator.simulate(cfg, make_jobs(slow_rows))
        assert slow.finish[2] > fast.finish[2]

    def test_victim_requeued_on_top(self):
        """Preempted BE resumes before queued BEs that arrived earlier."""
        jobs = make_jobs([
            (0, 30, 32, 256, 8, 0, 1),    # BE a (victim) on node 0
            (0, 30, 32, 256, 8, 0, 1),    # BE b on node 1
            (0, 30, 32, 256, 8, 0, 1),    # BE c queued (head-of-line)
            (1, 2, 32, 256, 8, 1, 0),     # TE preempts a
        ])
        cfg = small_cfg("fitgpp", n_nodes=2)
        sim = simulator.Simulator(cfg, jobs)
        res = sim.run()
        assert res.preempt_count[:3].sum() == 1
        victim = int(np.argmax(res.preempt_count[:3]))
        others = [j for j in range(3) if j != victim and jobs.submit[j] == 0]
        # victim (requeued on top) must resume before BE c starts
        assert res.finish[victim] < res.finish[2] or victim == 2

    def test_preemption_cap(self):
        cfg = small_cfg("fitgpp", n_nodes=1, P=1)
        jobs = make_jobs([
            (0, 60, 32, 256, 8, 0, 0),
            (1, 2, 32, 256, 8, 1, 0),
            (8, 2, 32, 256, 8, 1, 0),
            (16, 2, 32, 256, 8, 1, 0),
        ])
        res = simulator.simulate(cfg, jobs)
        assert res.preempt_count[0] <= 1 + 2   # cap 1 + random fallbacks
        # with a single BE and P=1, fallback preempts it at most... allow
        # the paper's random fallback to fire; count must stay bounded.

    def test_slowdown_formula(self):
        jobs = make_jobs([(0, 10, 1, 1, 1, 0, 0)])
        cfg = small_cfg("fifo", n_nodes=1)
        res = simulator.simulate(cfg, jobs)
        assert np.isclose(res.slowdown[0], 1.0)   # no waiting


class TestWorkload:
    def test_closed_loop_load(self):
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=1024))
        js = workload.generate(cfg)
        assert js.n == 1024
        js.validate(NODE_CAP)
        assert (np.diff(js.submit) >= 0).all()

    def test_determinism(self):
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=256))
        a = workload.generate(cfg)
        b = workload.generate(cfg)
        assert np.array_equal(a.submit, b.submit)
        assert np.array_equal(a.demand, b.demand)

    def test_unadmitted_job_raises_with_index(self, monkeypatch):
        """If the FIFO admission sim ends early, closed_loop_submit_times
        must raise a ValueError naming the first offending job — not a
        bare assert (stripped under ``python -O``) or silent -1 submit
        times corrupting every downstream ordering."""
        from repro.core import simulator
        monkeypatch.setattr(simulator.Simulator, "run",
                            lambda self, *a, **k: None)
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=32))
        with pytest.raises(ValueError, match=r"job 0"):
            workload.generate(cfg)

    def test_te_fraction(self):
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=4096, te_fraction=0.3))
        js = workload.generate(cfg)
        assert abs(js.is_te.mean() - 0.3) < 0.05

    def test_gp_scaling(self):
        c1 = SimConfig(workload=WorkloadSpec(n_jobs=2048, gp_scale=1.0))
        c4 = SimConfig(workload=WorkloadSpec(n_jobs=2048, gp_scale=4.0))
        g1 = workload.generate(c1).gp.mean()
        g4 = workload.generate(c4).gp.mean()
        assert g4 > 2 * g1

    def test_exec_time_paper_bounds(self):
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=4096))
        js = workload.generate(cfg)
        te, be = js.exec_total[js.is_te], js.exec_total[~js.is_te]
        assert te.max() <= 30 and be.max() <= 1440     # paper truncations
