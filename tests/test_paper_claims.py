"""Golden lock on the paper's headline claim (abstract / Table 1).

The abstract: FitGpp cuts the 95th-percentile TE slowdown of FIFO by
96.6% while compromising the BE median by only 18.0% and the BE 95th
percentile by only 23.9%. The reproduction targets the RELATIVE claim
(DESIGN.md §3 — the paper's demand distributions are private), so this
test pins the direction and magnitude with slack, pooled over >= 5
seeded workloads (the paper pools 8), through ``repro.api`` on BOTH
engines:

  * TE p95 slowdown: fitgpp reduces FIFO's by at least 80%;
  * BE median slowdown: fitgpp worsens FIFO's by at most 35%;
  * BE p95 slowdown: worsens by at most 50%.

Any scheduling regression that meaningfully erodes the paper's result
— TE latency no longer protected, or BE jobs starved to pay for it —
trips one of these bounds. Deterministic: fixed seeds, and both
engines are seeded (the JAX engine bit-exactly so).
"""
import numpy as np
import pytest

from repro import api, scenarios

SEEDS = range(5)
N_JOBS = 256
N_NODES = 8
SCENARIO = "paper-synthetic"


def pooled_slowdowns(engine: str, policy: str):
    """Per-job slowdowns + TE mask pooled over the seeded workloads,
    sharing one engine config (and thus, for JAX, one compilation)."""
    cfg = api.make_config(policy, n_nodes=N_NODES, n_jobs=N_JOBS)
    sd_all, te_all = [], []
    for seed in SEEDS:
        js = scenarios.build(SCENARIO, api.make_config(
            policy, n_nodes=N_NODES, n_jobs=N_JOBS, seed=seed))
        r = api.run_experiment(SCENARIO, policy, engine, cfg=cfg, jobs=js)
        if engine == "reference":
            sd_all.append(r.raw.slowdown)
            te_all.append(r.raw.is_te)
        else:
            from repro.core import sim_jax
            jobs, st = r.raw
            sd_all.append(np.asarray(sim_jax.slowdown(jobs, st)))
            te_all.append(np.asarray(jobs.is_te))
    sd = np.concatenate(sd_all)
    te = np.concatenate(te_all)
    return sd, te


@pytest.mark.slow
@pytest.mark.parametrize("engine", api.ENGINES)
def test_fitgpp_vs_fifo_headline(engine):
    fifo_sd, fifo_te = pooled_slowdowns(engine, "fifo")
    fit_sd, fit_te = pooled_slowdowns(engine, "fitgpp")

    fifo_te_p95 = np.percentile(fifo_sd[fifo_te], 95)
    fit_te_p95 = np.percentile(fit_sd[fit_te], 95)
    fifo_be_p50 = np.median(fifo_sd[~fifo_te])
    fit_be_p50 = np.median(fit_sd[~fit_te])
    fifo_be_p95 = np.percentile(fifo_sd[~fifo_te], 95)
    fit_be_p95 = np.percentile(fit_sd[~fit_te], 95)

    # the workload must be contended enough for the claim to be
    # non-vacuous: FIFO's TE tail has to actually suffer
    assert fifo_te_p95 > 5.0, \
        f"paper-synthetic lost its contention ({fifo_te_p95=:.2f})"

    reduction = 1.0 - fit_te_p95 / fifo_te_p95
    assert reduction >= 0.80, \
        f"[{engine}] TE p95 reduction {reduction:.1%} < 80% " \
        f"(fifo {fifo_te_p95:.2f} -> fitgpp {fit_te_p95:.2f}; " \
        "paper: 96.6%)"

    be_p50_worsening = fit_be_p50 / fifo_be_p50 - 1.0
    assert be_p50_worsening <= 0.35, \
        f"[{engine}] BE median worsened {be_p50_worsening:.1%} > 35% " \
        f"(fifo {fifo_be_p50:.2f} -> fitgpp {fit_be_p50:.2f}; " \
        "paper: 18.0%)"

    be_p95_worsening = fit_be_p95 / fifo_be_p95 - 1.0
    assert be_p95_worsening <= 0.50, \
        f"[{engine}] BE p95 worsened {be_p95_worsening:.1%} > 50% " \
        f"(paper: 23.9%)"
