"""Unit tests for the roofline HLO-collective parser — it multiplies
loop bodies by known_trip_count and bf16-adjusts f32 upcasts, so it must
be right for §Roofline to mean anything."""
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import roofline as rf

HLO = """\
HloModule jit_train_step

%body.1 (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p = parameter(0)
  %ar = f32[4,8]{1,0} all-reduce(%something), replica_groups={}
  ROOT %t = tuple(%x)
}

%cond.1 (p: (s32[], f32[4,8])) -> pred[] {
  %p = parameter(0)
  ROOT %lt = compare(%i, %n)
}

ENTRY %main.42 (a: f32[16,16]) -> f32[16,16] {
  %a = parameter(0)
  %ag = bf16[16,16]{1,0} all-gather(%a), replica_groups={}
  %w = (s32[], f32[4,8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"},"known_init_step":{"init":"0","step":"1"}}
  ROOT %r = f32[16,16] add(%x, %y)
}
"""


class TestCollectiveParser:
    def test_trip_count_multiplies_body(self):
        out = rf.collective_bytes(HLO)
        # body all-reduce: 4*8*4 bytes * factor 2 * 10 trips = 2560
        assert out["all-reduce"] == pytest.approx(4 * 8 * 4 * 2 * 10)

    def test_entry_counted_once(self):
        out = rf.collective_bytes(HLO)
        # entry all-gather: bf16 16*16*2 bytes * factor 1
        assert out["all-gather"] == pytest.approx(16 * 16 * 2)

    def test_total_and_details(self):
        out = rf.collective_bytes(HLO)
        assert out["total"] == out["all-reduce"] + out["all-gather"]
        kinds = [d[1] for d in out["_details"]]
        assert set(kinds) == {"all-reduce", "all-gather"}

    def test_bf16_adjustment_on_converted_f32(self):
        hlo = HLO.replace("all-reduce(%something)",
                          "all-reduce(%convert_fusion.3)")
        out = rf.collective_bytes(hlo)
        # f32 collective fed by a convert -> halved (CPU upcast artifact)
        assert out["all-reduce"] == pytest.approx(4 * 8 * 4 * 2 * 10 / 2)
        assert out["total_raw_f32"] == pytest.approx(
            4 * 8 * 4 * 2 * 10 + 16 * 16 * 2)

    def test_shape_bytes_dtypes(self):
        assert rf._shape_bytes("bf16[2,3]") == 12
        assert rf._shape_bytes("f32[10]") == 40
        assert rf._shape_bytes("pred[7]") == 7
        assert rf._shape_bytes("(f32[2], bf16[4])") == 16


class TestAnalyticCosts:
    def test_train_flops_scale_with_params(self):
        small = rf.analytic_costs(get_config("mamba2-1.3b"),
                                  INPUT_SHAPES["train_4k"])
        big = rf.analytic_costs(get_config("nemotron-4-340b"),
                                INPUT_SHAPES["train_4k"])
        assert big["flops"] > 100 * small["flops"]

    def test_model_flops_is_6nd(self):
        cfg = get_config("stablelm-12b")
        a = rf.analytic_costs(cfg, INPUT_SHAPES["train_4k"])
        tokens = 256 * 4096
        # 6·N_active·D within 20% (N_active excludes embed, adds tied head)
        assert a["model_flops"] == pytest.approx(6 * 12.1e9 * tokens,
                                                 rel=0.2)

    def test_moe_counts_active_params_only(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        a = rf.analytic_costs(cfg, INPUT_SHAPES["train_4k"])
        dense_equiv = 6 * 30.5e9 * 256 * 4096
        assert a["model_flops"] < 0.25 * dense_equiv    # top-8 of 128

    def test_decode_window_caps_attention(self):
        cfg = get_config("mixtral-8x22b")              # SWA 4096
        d = rf.analytic_costs(cfg, INPUT_SHAPES["decode_32k"])
        full = rf.analytic_costs(cfg.replace(window=0),
                                 INPUT_SHAPES["decode_32k"])
        assert d["flops"] < full["flops"]

    def test_roofline_dominant(self):
        cfg = get_config("stablelm-12b")
        r = rf.roofline(cfg, INPUT_SHAPES["train_4k"], 256,
                        coll_bytes_per_device=1e9, hlo_flops_raw=1e12)
        assert r.dominant == "compute"
        r2 = rf.roofline(cfg, INPUT_SHAPES["train_4k"], 256,
                         coll_bytes_per_device=1e15, hlo_flops_raw=1e12)
        assert r2.dominant == "collective"
