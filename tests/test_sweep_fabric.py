"""Sweep-fabric contracts (DESIGN.md §11).

In-process tests run on the one CPU device the suite sees (the fabric
then takes its single-device vmap path — bit-identical to the sharded
one by construction); actual multi-device sharding parity runs in
SUBPROCESSES under ``XLA_FLAGS=--xla_force_host_platform_device_count``
via the module's selftest CLI, because the flag must be set before jax
initializes. Covered here:

- sentinel-TRIAL padding: a table padded to a device multiple returns
  bit-identical real rows, and the sentinel rows never leak into
  ``SweepResult`` or ``pooled_tables``;
- the ``run_sweep`` wrapper is the fabric (same arrays, classic keys);
- ``pooled_tables`` matches ``metrics.pooled_tables`` on the reference
  engine for a deterministic policy (slowdowns to f32, preemption
  accounting exactly; resched intervals excluded — the fabric pools
  the JAX State's last signal→resume gap per job, the reference
  engine every gap);
- ``mesh_for_sweep`` fallback behavior is loud, never silent;
- the compile-once contract: seed/s/P re-runs add no compilations
  (the old per-call ``run_sweep`` recompile bug);
- donation is alias-safe and a no-op on CPU.
"""
import dataclasses
import os
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro import scenarios
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, sim_jax, simulator, sweep_fabric
from repro.core import sweep
from repro.launch.mesh import mesh_for_sweep

pytestmark = pytest.mark.filterwarnings(
    "ignore:.*shard_map.*:DeprecationWarning")


def _cfg(policy="fitgpp", n_jobs=64, nodes=8, **kw):
    return SimConfig(cluster=ClusterSpec(n_nodes=nodes),
                     workload=WorkloadSpec(n_jobs=n_jobs),
                     policy=policy, **kw)


def _table(n_seeds=3, n_jobs=64, scenario="burst-storm", s=4.0, P=1):
    base = _cfg(n_jobs=n_jobs)
    jobsets = [scenarios.build(scenario, dataclasses.replace(base, seed=k))
               for k in range(n_seeds)]
    return base, sweep_fabric.build_table(
        jobsets, s, P, np.arange(n_seeds, dtype=np.uint32))


def _assert_stats_equal(a, b):
    assert a.keys() == b.keys()
    for k in a:
        assert np.array_equal(a[k], b[k], equal_nan=True), k


class TestSentinelPadding:
    def test_padded_rows_are_dropped_and_bit_exact(self):
        cfg, table = _table(n_seeds=3)
        plain = sweep_fabric.run_table(cfg, table, devices=1,
                                       donate=False)
        padded = sweep_fabric.pad_table(table, 4)
        assert int(padded.s.shape[0]) == 4
        res = sweep_fabric.run_table(cfg, padded, devices=1, donate=False)
        # run_table drops nothing here (the pre-padded table IS the
        # table), so slice the sentinel row off before comparing
        assert res.n_trials == 4
        _assert_stats_equal(plain.stats,
                            {k: v[:3] for k, v in res.stats.items()})

    def test_sentinel_trial_is_born_done(self):
        cfg, table = _table(n_seeds=2)
        padded = sweep_fabric.pad_table(table, 3)
        res = sweep_fabric.run_table(cfg, padded, devices=1, donate=False)
        # the sentinel trial never runs a job: zero makespan, all-nan
        # summaries (every percentile mask is empty)
        assert res.stats["makespan"][2] == 0
        assert np.isnan(res.stats["te_slowdown"][2]).all()
        assert np.isnan(res.stats["preempted_frac"][2])

    def test_sentinel_rows_masked_from_pooled_tables(self):
        cfg, table = _table(n_seeds=2)
        padded = sweep_fabric.pad_table(table, 3)
        ref = sweep_fabric.run_table(cfg, table, devices=1,
                                     out="per_job", donate=False)
        res = sweep_fabric.run_table(cfg, padded, devices=1,
                                     out="per_job", donate=False)
        # pooling all 3 rows of the padded run == pooling the 2 real
        # ones: sentinel jobs are masked via the valid output column
        assert (sweep_fabric.pooled_tables(res)
                == sweep_fabric.pooled_tables(ref))

    def test_pad_table_noop_when_even(self):
        _, table = _table(n_seeds=4)
        assert sweep_fabric.pad_table(table, 2) is table

    def test_build_table_validation(self):
        with pytest.raises(ValueError, match="empty"):
            sweep_fabric.build_table([], 4.0, 1, 0)
        _, table = _table(n_seeds=2)
        with pytest.raises(ValueError, match="shape"):
            sweep_fabric.table_from_stacked(
                table.jobs, np.zeros(3, np.float32), 1, 0)


class TestRunSweepWrapper:
    def test_wrapper_is_the_fabric(self):
        cfg, table = _table(n_seeds=3)
        res = sweep_fabric.run_table(cfg, table, devices=1, donate=False)
        via_wrapper = sweep.run_sweep(
            cfg, table.jobs, table.s, table.P, table.seed, devices=1)
        _assert_stats_equal(res.stats, via_wrapper)

    def test_classic_keys(self):
        cfg, table = _table(n_seeds=2)
        res = sweep_fabric.run_table(cfg, table, devices=1, donate=False)
        assert set(res.stats) == {
            "te_slowdown", "be_slowdown", "intervals", "preempted_frac",
            "preempt_1", "preempt_2", "preempt_3plus", "makespan"}
        assert res.stats["te_slowdown"].shape == (2, 3)
        assert res.stats["intervals"].shape == (2, 4)

    def test_pooled_tables_needs_per_job(self):
        cfg, table = _table(n_seeds=2)
        res = sweep_fabric.run_table(cfg, table, devices=1, donate=False)
        with pytest.raises(ValueError, match="per_job"):
            sweep_fabric.pooled_tables(res)


class TestPooledParity:
    def test_pooled_matches_reference_engine(self):
        """Fabric pooling == metrics.pooled_tables on the reference
        engine for a deterministic preemptive policy: slowdown
        percentiles to f32 precision, preemption accounting exactly.
        Resched intervals are excluded by design (last-gap vs
        every-gap; the engines agree on preempt_count, asserted
        below)."""
        n_seeds = 3
        base = _cfg(policy="lrtp", n_jobs=96)
        jobsets = [scenarios.build("burst-storm",
                                   dataclasses.replace(base, seed=k))
                   for k in range(n_seeds)]
        ref = metrics.pooled_tables(metrics.merge_results(
            [simulator.simulate(dataclasses.replace(base, seed=k), js)
             for k, js in enumerate(jobsets)]))
        table = sweep_fabric.build_table(
            jobsets, 4.0, 1, np.arange(n_seeds, dtype=np.uint32))
        res = sweep_fabric.run_table(base, table, devices=1,
                                     out="per_job", donate=False)
        fab = sweep_fabric.pooled_tables(res)
        for cls in ("TE", "BE"):
            for p, v in ref[cls].items():
                np.testing.assert_allclose(fab[cls][p], v, rtol=1e-6,
                                           err_msg=f"{cls}/{p}")
        assert fab["preempted_frac"] == pytest.approx(
            ref["preempted_frac"], abs=1e-12)
        for k in ("1", "2", ">=3"):
            assert fab["preempt_counts"][k] == pytest.approx(
                ref["preempt_counts"][k], abs=1e-12), k

    def test_cell_subsetting(self):
        # lrtp: exactly dual-backend (fitgpp's rng fallback can pick
        # different victims than the reference engine)
        base = _cfg(policy="lrtp")
        jobsets = [scenarios.build("burst-storm",
                                   dataclasses.replace(base, seed=k))
                   for k in range(3)]
        table = sweep_fabric.build_table(
            jobsets, 4.0, 1, np.arange(3, dtype=np.uint32))
        res = sweep_fabric.run_table(base, table, devices=1,
                                     out="per_job", donate=False)
        one = sweep_fabric.pooled_tables(res, trials=[1])
        cfg1 = dataclasses.replace(base, seed=1)
        ref = metrics.pooled_tables(metrics.merge_results(
            [simulator.simulate(cfg1, jobsets[1])]))
        np.testing.assert_allclose(one["BE"]["p50"], ref["BE"]["p50"],
                                   rtol=1e-6)


class TestMeshForSweep:
    def test_single_device_returns_none(self):
        if len(jax.devices()) != 1:
            pytest.skip("suite runs on one CPU device")
        assert mesh_for_sweep(8) is None

    def test_over_request_warns(self):
        avail = len(jax.devices())
        with pytest.warns(UserWarning, match="requested"):
            mesh_for_sweep(64, devices=avail + 7)

    def test_capped_by_n_trials(self):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            assert mesh_for_sweep(1, devices=8) is None

    def test_run_table_auto_mesh_single_device(self):
        if len(jax.devices()) != 1:
            pytest.skip("suite runs on one CPU device")
        cfg, table = _table(n_seeds=2)
        res = sweep_fabric.run_table(cfg, table, donate=False)
        assert res.n_devices == 1 and res.n_padded == 0


class TestCompileOnce:
    def test_seed_only_rerun_compiles_nothing(self):
        """The old run_sweep rebuilt its jitted trial fn per call, so
        sweeping seeds recompiled every time. The fabric caches one
        runner per (cfg, mode, out, mesh, donate): re-running with new
        seeds/s/P must not add runners or jit-cache entries."""
        cfg, table = _table(n_seeds=3)
        sweep_fabric.run_table(cfg, table, devices=1, donate=False)
        before = sweep_fabric.compile_stats()
        reseeded = table._replace(seed=table.seed + 1000,
                                  s=table.s + 1.0)
        sweep_fabric.run_table(cfg, reseeded, devices=1, donate=False)
        assert sweep_fabric.compile_stats() == before

    def test_new_policy_adds_one_runner(self):
        cfg, table = _table(n_seeds=2)
        sweep_fabric.run_table(cfg, table, devices=1, donate=False)
        before = sweep_fabric.compile_stats()["runners"]
        cfg2 = dataclasses.replace(cfg, policy="lrtp")
        sweep_fabric.run_table(cfg2, table, devices=1, donate=False)
        assert sweep_fabric.compile_stats()["runners"] == before + 1


class TestDonation:
    def test_donate_true_bit_exact_on_cpu(self):
        """XLA's CPU backend ignores donation, so donate=True must be
        a pure no-op there (and donation_supported() says so)."""
        assert sim_jax.donation_supported() == (
            jax.default_backend() in ("gpu", "tpu"))
        cfg, table = _table(n_seeds=2)
        base = sweep_fabric.run_table(cfg, table, devices=1,
                                      donate=False)
        donated = sweep_fabric.run_table(cfg, table, devices=1,
                                         donate=True)
        _assert_stats_equal(base.stats, donated.stats)

    def test_run_jit_donated_variant(self):
        cfg = _cfg(n_jobs=48)
        js = scenarios.build("burst-storm", cfg)
        jobs = sim_jax.jobs_from_jobset(js)
        st = sim_jax.run_jit(cfg, jobs)
        std = sim_jax.run_jit(cfg, jobs, donate=True)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(std)):
            if jax.dtypes.issubdtype(a.dtype, jax.dtypes.prng_key):
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            a, b = np.asarray(a), np.asarray(b)
            eq_nan = np.issubdtype(a.dtype, np.inexact)
            assert np.array_equal(a, b, equal_nan=eq_nan)


def _run_selftest(extra, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.core.sweep_fabric"] + extra,
        capture_output=True, text=True, env=env, timeout=timeout)


class TestShardedParitySubprocess:
    """The real multi-device runs: forced 8-device host mesh in a
    subprocess (XLA_FLAGS must precede jax init)."""

    def test_selftest_smoke(self):
        r = _run_selftest(["--policies", "fitgpp", "--modes", "event"])
        assert r.returncode == 0, r.stderr[-2000:]
        assert "bit-exact" in r.stdout and "selftest ok" in r.stdout

    @pytest.mark.slow
    def test_selftest_full_matrix(self):
        """Every deterministic dual-backend policy × both time modes,
        preemption-heavy scenario, uneven grid (sentinel trials), all
        sharded-vs-single bit-exact."""
        r = _run_selftest(["--policies", "deterministic",
                           "--modes", "event,tick"], timeout=1800)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "selftest ok" in r.stdout
        assert r.stdout.count("bit-exact") >= 2
