"""Gang (multi-node) scheduling — the paper's stated future work."""
import dataclasses

import numpy as np
import pytest

from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, simulator, workload
from repro.core.types import JobSet


def make_jobs(rows):
    """rows: (submit, exec, cpu, ram, gpu, is_te, gp, n_nodes)"""
    r = np.asarray(rows, dtype=float)
    return JobSet(submit=r[:, 0].astype(np.int64),
                  exec_total=r[:, 1].astype(np.int64),
                  demand=r[:, 2:5], is_te=r[:, 5].astype(bool),
                  gp=r[:, 6].astype(np.int64),
                  n_nodes=r[:, 7].astype(np.int64))


def cfg(policy="fitgpp", n_nodes=4):
    return SimConfig(cluster=ClusterSpec(n_nodes=n_nodes), policy=policy)


class TestGangScheduling:
    def test_all_or_nothing_placement(self):
        """A 3-node gang must wait until 3 nodes are simultaneously free."""
        jobs = make_jobs([
            (0, 10, 32, 256, 8, 0, 0, 1),   # fills node
            (0, 10, 32, 256, 8, 0, 0, 1),   # fills node
            (0, 5, 16, 128, 4, 0, 0, 3),    # 3-node gang: only 2 free
        ])
        res = simulator.simulate(cfg("fifo"), jobs)
        assert res.finish[2] >= 10 + 5      # waited for completions

    def test_gang_occupies_all_nodes(self):
        jobs = make_jobs([(0, 5, 16, 128, 4, 0, 0, 4)])
        sim = simulator.Simulator(cfg("fifo"), jobs)
        sim.step(0)
        assert len(sim.job_nodes[0]) == 4
        assert np.allclose(sim.free[:, 2], 8 - 4)

    def test_gang_te_triggers_multi_victim_preemption(self):
        jobs = make_jobs([
            (0, 30, 32, 256, 8, 0, 1, 1),
            (0, 30, 32, 256, 8, 0, 1, 1),
            (0, 30, 32, 256, 8, 0, 1, 1),
            (0, 30, 32, 256, 8, 0, 1, 1),
            (1, 3, 16, 128, 4, 1, 0, 2),    # 2-node TE gang
        ])
        res = simulator.simulate(cfg("fitgpp"), jobs)
        assert res.preempt_count[:4].sum() == 2      # exactly 2 victims
        assert res.slowdown[4] < 3.0

    def test_gang_victim_frees_all_nodes(self):
        jobs = make_jobs([
            (0, 30, 32, 256, 8, 0, 1, 2),   # 2-node BE gang
            (0, 30, 32, 256, 8, 0, 1, 1),
            (0, 30, 32, 256, 8, 0, 1, 1),
            (1, 3, 32, 256, 8, 1, 0, 2),    # 2-node TE: evicting the
        ])                                   # gang frees both its nodes
        res = simulator.simulate(cfg("fitgpp"), jobs)
        assert res.preempt_count[0] == 1
        assert res.preempt_count[1:3].sum() == 0

    def test_mixed_workload_end_to_end(self):
        wl = WorkloadSpec(n_jobs=1024, multi_node_frac=0.2)
        c = SimConfig(workload=wl, seed=1)
        jobs = workload.generate(c)
        assert (jobs.n_nodes > 1).any()
        for pol in ("fifo", "fitgpp"):
            res = simulator.simulate(dataclasses.replace(c, policy=pol), jobs)
            assert (res.finish > 0).all()
            assert (res.slowdown >= 1 - 1e-9).all()

    def test_jax_engine_accepts_gangs(self):
        """The JAX engine runs gang jobsets (widths land in
        Jobs.width; the old NotImplementedError guard is gone)."""
        from repro.core import sim_jax
        jobs = make_jobs([(0, 5, 16, 128, 4, 0, 0, 2)])
        jx = sim_jax.jobs_from_jobset(jobs)
        assert np.asarray(jx.width).tolist() == [2]
        st = sim_jax.run_jit(cfg("fifo"), jx, 0)
        assert int(st.finish[0]) == 5


class TestGangSchedulingJax:
    """The same gang semantics on the JAX engine, bit-exact vs the
    reference (micro jobsets keep fitgpp on its deterministic path)."""

    def _both(self, c, jobs, mode="event"):
        from repro.core import sim_jax
        res = simulator.simulate(c, jobs, mode=mode)
        st = sim_jax.run_jit(c, sim_jax.jobs_from_jobset(jobs), c.seed,
                             time_mode=mode)
        np.testing.assert_array_equal(np.asarray(st.finish), res.finish)
        np.testing.assert_array_equal(np.asarray(st.preempt_count),
                                      res.preempt_count)
        return res, st

    def test_all_or_nothing_placement(self):
        jobs = make_jobs([
            (0, 10, 32, 256, 8, 0, 0, 1),
            (0, 10, 32, 256, 8, 0, 0, 1),
            (0, 5, 16, 128, 4, 0, 0, 3),
        ])
        res, st = self._both(cfg("fifo"), jobs)
        assert res.finish[2] >= 10 + 5

    def test_gang_te_triggers_multi_victim_preemption(self):
        jobs = make_jobs([
            (0, 30, 32, 256, 8, 0, 1, 1),
            (0, 30, 32, 256, 8, 0, 1, 1),
            (0, 30, 32, 256, 8, 0, 1, 1),
            (0, 30, 32, 256, 8, 0, 1, 1),
            (1, 3, 16, 128, 4, 1, 0, 2),    # 2-node TE gang
        ])
        res, st = self._both(cfg("fitgpp"), jobs)
        assert res.preempt_count[:4].sum() == 2      # exactly 2 victims

    def test_gang_victim_frees_all_nodes(self):
        jobs = make_jobs([
            (0, 30, 32, 256, 8, 0, 1, 2),   # 2-node BE gang
            (0, 30, 32, 256, 8, 0, 1, 1),
            (0, 30, 32, 256, 8, 0, 1, 1),
            (1, 3, 32, 256, 8, 1, 0, 2),    # 2-node TE: one victim
        ])
        res, st = self._both(cfg("fitgpp"), jobs)
        assert res.preempt_count[0] == 1
        assert res.preempt_count[1:3].sum() == 0

    def test_insufficient_gang_signals_nothing(self):
        """gang_select signals NOTHING when even evicting every
        candidate cannot free enough nodes (no wasted preemptions) —
        on both engines."""
        jobs = make_jobs([
            (0, 30, 32, 256, 8, 0, 1, 1),   # one BE on one node
            (1, 3, 16, 128, 4, 1, 0, 4),    # 4-node TE on a 2-node
        ])                                   # cluster: can never fit
        c = cfg("fitgpp", n_nodes=2)
        from repro.core import sim_jax
        jx = sim_jax.jobs_from_jobset(jobs)
        st = sim_jax.run(c, jx, seed=0, max_ticks=64)
        assert int(st.preempt_count[0]) == 0
        assert int(st.fallback_count) == 0

    @pytest.mark.parametrize("mode", ["tick", "event"])
    @pytest.mark.parametrize("policy", ["fifo", "fitgpp", "lrtp", "srtp",
                                        "minsize"])
    def test_mixed_workload_parity(self, policy, mode):
        """Generated gang workload, paper-default cluster (fitgpp's
        fallback stays quiet): reference-vs-JAX bit parity."""
        wl = WorkloadSpec(n_jobs=160, multi_node_frac=0.25)
        c = SimConfig(workload=wl, policy=policy, seed=1)
        jobs = workload.generate(c)
        assert (jobs.n_nodes > 1).any()
        self._both(c, jobs, mode=mode)
