"""Workload-statistics coverage: closed-loop admission load, truncated
normals, the trace-proxy gang fix, and scenario JobSet validity."""
import numpy as np
import pytest

from repro import scenarios
from repro.configs.cluster import ClusterSpec, SimConfig, TruncNormal, \
    WorkloadSpec
from repro.core import simulator, workload


class TestTruncNormal:
    @pytest.mark.parametrize("d", [
        TruncNormal(3.0, 3.0, 0.0, 20.0),      # the paper's GP dist
        TruncNormal(30.0, 30.0, 3.0, 1440.0),  # BE exec
        TruncNormal(5.0, 2.5, 0.0, 8.0),       # GPU demand
    ])
    def test_respects_bounds(self, d):
        rng = np.random.default_rng(0)
        x = workload.sample_trunc_normal(rng, d, 20_000)
        assert x.min() >= d.lo and x.max() <= d.hi
        # resampling keeps the bulk near the untruncated mean
        lo_tail = max(d.lo, d.mean - 2 * d.std)
        hi_tail = min(d.hi, d.mean + 2 * d.std)
        assert ((x >= lo_tail) & (x <= hi_tail)).mean() > 0.8

    def test_degenerate_interval(self):
        rng = np.random.default_rng(1)
        x = workload.sample_trunc_normal(
            rng, TruncNormal(100.0, 1.0, 0.0, 5.0), 1000)
        assert x.min() >= 0.0 and x.max() <= 5.0


class TestClosedLoopAdmission:
    @pytest.mark.parametrize("seed", [0, 3])
    def test_backlog_holds_target(self, seed):
        """§4.2 contract: under FIFO, the cluster-normalized backlog of
        admitted, unfinished jobs stays pinned at cfg.workload.load."""
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=16),
                        workload=WorkloadSpec(n_jobs=384),
                        policy="fifo", seed=seed)
        js = workload.generate(cfg)
        res = simulator.simulate(cfg, js)
        cap = np.asarray(cfg.cluster.node.as_tuple()) * cfg.cluster.n_nodes
        frac = workload.cluster_fraction(js.demand, cap) * js.n_nodes
        # backlog while admission is still active (before job exhaustion)
        ts = np.arange(0, int(js.submit.max()))
        load = np.array([frac[(js.submit <= t) & (res.finish > t)].sum()
                         for t in ts])
        target = cfg.workload.load
        assert abs(np.median(load) - target) < 0.15 * target
        assert np.percentile(load, 90) < 1.5 * target


class TestTraceProxyGangs:
    def test_multi_node_frac_honored(self):
        """Regression: generate_trace_proxy silently ignored
        multi_node_frac; it must sample gang widths like generate."""
        wl = WorkloadSpec(n_jobs=2048, multi_node_frac=0.25,
                          multi_node_widths=(2, 4))
        cfg = SimConfig(workload=wl, seed=0)
        js = workload.generate_trace_proxy(cfg)
        gang = js.n_nodes > 1
        assert gang.any()
        assert abs(gang.mean() - 0.25) < 0.05
        assert set(np.unique(js.n_nodes)) <= {1, 2, 4}

    def test_single_node_default_unchanged(self):
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=256), seed=0)
        js = workload.generate_trace_proxy(cfg)
        assert (js.n_nodes == 1).all()

    def test_gang_proxy_simulates(self):
        wl = WorkloadSpec(n_jobs=160, multi_node_frac=0.25)
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=8),
                        workload=wl, policy="fitgpp", seed=2)
        js = workload.generate_trace_proxy(cfg)
        res = simulator.simulate(cfg, js)
        assert (res.finish > 0).all()


class TestScenarioJobsets:
    @pytest.mark.parametrize("name", scenarios.scenario_names())
    def test_validates_against_cluster(self, name):
        """Satellite: every registered scenario produces a JobSet that
        passes validate() (build() re-validates against the node)."""
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=8),
                        workload=WorkloadSpec(n_jobs=64), seed=0)
        js = scenarios.build(name, cfg)
        assert js.n > 0
        assert (js.exec_total >= 1).all()
        assert (np.diff(js.submit) >= 0).all()
        assert (js.n_nodes >= 1).all()
        assert (js.n_nodes <= cfg.cluster.n_nodes).all()

    def test_scenario_class_mixes_differ(self):
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=8),
                        workload=WorkloadSpec(n_jobs=256), seed=0)
        flood = scenarios.build("te-flood", cfg).is_te.mean()
        base = scenarios.build("paper-synthetic", cfg).is_te.mean()
        assert flood > 0.6 > base

    def test_heterogeneous_gp_bimodal(self):
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=8),
                        workload=WorkloadSpec(n_jobs=512), seed=0)
        js = scenarios.build("heterogeneous-gp", cfg)
        zero = (js.gp == 0).mean()
        assert 0.3 < zero < 0.7
        assert js.gp.max() >= 5

    def test_burst_storm_full_burst_fraction(self):
        """burst_frac=1.0 keeps one background job as the time anchor
        instead of crashing on an empty partition."""
        from repro.scenarios.library import burst_storm
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=8),
                        workload=WorkloadSpec(n_jobs=64), seed=0)
        js = burst_storm(cfg, burst_frac=1.0)
        js.validate(np.asarray(cfg.cluster.node.as_tuple()))
        assert js.n == 64

    def test_maintenance_drain_gap(self):
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=8),
                        workload=WorkloadSpec(n_jobs=512), seed=0)
        js = scenarios.build("maintenance-drain", cfg)
        gaps = np.diff(np.unique(js.submit))
        assert gaps.max() >= 200           # the drain window (240 min)
        counts = np.bincount(js.submit - js.submit.min())
        assert counts.max() >= 10          # the reopen flood
