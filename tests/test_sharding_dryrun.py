"""Sharding-plan rules + a real (subprocess) dry-run lowering check."""
import json
import os
import subprocess
import sys

import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.configs.base import InputShape
from repro.sharding.plans import Plan, spec_from_logical

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH = FakeMesh({"data": 16, "model": 16})
PLAN = Plan(rules={"heads": ("model",), "kv": ("model",),
                   "mlp": ("model",), "vocab": ("model",),
                   "embed": ("data",), "experts": ("model",)},
            batch_axes=("data",))


class TestSpecRules:
    def test_divisible_dims_shard(self):
        spec = spec_from_logical(("embed", "heads", None), (512, 64, 128),
                                 PLAN, MESH)
        assert tuple(spec) == ("data", "model")

    def test_indivisible_dim_replicates(self):
        # kv=8 does not divide model=16 -> replicated
        spec = spec_from_logical(("embed", "kv", None), (512, 8, 128),
                                 PLAN, MESH)
        assert tuple(spec) == ("data",)

    def test_no_mesh_axis_reuse(self):
        # both dims want "model"; only the first gets it
        spec = spec_from_logical(("heads", "mlp"), (64, 512), PLAN, MESH)
        assert tuple(spec) == ("model", None) or tuple(spec) == ("model",)

    def test_unknown_logical_replicates(self):
        spec = spec_from_logical(("nonexistent", None), (64, 64), PLAN, MESH)
        assert tuple(spec) == ()


@pytest.mark.slow
class TestDryRunSubprocess:
    """Spawns the real dryrun module (which forces 512 host devices) for
    one cheap (arch × shape) per kind; proves the launcher end-to-end."""

    @pytest.mark.parametrize("arch,shape", [
        ("mamba2-1.3b", "decode_32k"),
        ("internvl2-2b", "prefill_32k"),
    ])
    def test_lower_and_compile(self, arch, shape, tmp_path):
        out = tmp_path / "dryrun"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", arch, "--shape", shape, "--mesh", "single",
             "--out", str(out)],
            env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
            capture_output=True, text=True, timeout=900)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        arts = list(out.glob("*.json"))
        assert len(arts) == 1
        res = json.loads(arts[0].read_text())
        assert res["n_chips"] == 256
        assert res["roofline"]["dominant"] in ("compute", "memory",
                                               "collective")
        assert res["cost_analysis"]["flops"] > 0


class TestPlans:
    def test_big_archs_get_fsdp_and_microbatching(self):
        import jax
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.sharding.plans import arch_plan
        cfg = get_config("nemotron-4-340b")
        plan = arch_plan(cfg, INPUT_SHAPES["train_4k"], mesh)
        assert plan.microbatches > 1
        assert plan.opt_dtype == "bfloat16"

    def test_long500k_variant_applied_by_launcher(self):
        from repro.launch.dryrun import variant_config
        cfg = variant_config("command-r-35b", "long_500k")
        assert cfg.window == 4096          # SWA decode variant
        cfg2 = variant_config("command-r-35b", "decode_32k")
        assert cfg2.window == 0            # full attention preserved
        cfg3 = variant_config("mixtral-8x22b", "long_500k")
        assert cfg3.window == 4096         # native SWA untouched
