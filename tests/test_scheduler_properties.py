"""Property-based tests (hypothesis) for scheduler invariants."""
import dataclasses

import numpy as np
import pytest

pytestmark = pytest.mark.hypothesis

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import simulator, workload
from repro.core.types import DONE, JobSet

NODE_CAP = np.array([32.0, 256.0, 8.0])


@st.composite
def jobsets(draw, max_jobs=40):
    n = draw(st.integers(3, max_jobs))
    submit = np.cumsum(draw(st.lists(
        st.integers(0, 3), min_size=n, max_size=n)))
    execs = draw(st.lists(st.integers(1, 20), min_size=n, max_size=n))
    cpus = draw(st.lists(st.integers(1, 32), min_size=n, max_size=n))
    rams = draw(st.lists(st.integers(1, 256), min_size=n, max_size=n))
    gpus = draw(st.lists(st.sampled_from([0, 1, 2, 4, 8]),
                         min_size=n, max_size=n))
    te = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    gp = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    return JobSet(
        submit=np.asarray(submit, np.int64),
        exec_total=np.asarray(execs, np.int64),
        demand=np.stack([np.asarray(cpus, float), np.asarray(rams, float),
                         np.asarray(gpus, float)], 1),
        is_te=np.asarray(te, bool),
        gp=np.asarray(gp, np.int64),
    )


def cfg_for(policy, P=1, s=4.0, n_nodes=2):
    return SimConfig(cluster=ClusterSpec(n_nodes=n_nodes),
                     policy=policy, s=s, max_preemptions=P)


class CapacityCheckedSim(simulator.Simulator):
    """Simulator that asserts resource conservation every tick."""

    def step(self, t):
        super().step(t)
        # free never negative, never above capacity
        assert (self.free >= -1e-9).all(), f"over-allocated at t={t}"
        assert (self.free <= self.node_cap[None] + 1e-9).all(), \
            f"free above capacity at t={t}"
        # running jobs' demand + free == capacity per node
        used = np.zeros_like(self.free)
        for j in self.running | self.grace:
            used[int(self.node[j])] += self.jobs.demand[j]
        assert np.allclose(used + self.free, self.node_cap[None]), \
            f"conservation violated at t={t}"


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(jobsets(), st.sampled_from(["fifo", "lrtp", "rand", "fitgpp"]))
def test_capacity_conservation_and_completion(js, policy):
    cfg = cfg_for(policy)
    sim = CapacityCheckedSim(cfg, js)
    res = sim.run(max_ticks=100_000)
    # every job completes exactly once
    assert (res.finish > 0).all()
    # slowdown >= 1 for all jobs
    assert (res.slowdown >= 1.0 - 1e-9).all()


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(jobsets(), st.sampled_from(["lrtp", "rand", "fitgpp"]),
       st.integers(1, 3))
def test_te_never_preempted_and_p_cap_under_normal_path(js, policy, P):
    cfg = cfg_for(policy, P=P)
    res = simulator.simulate(cfg, js)
    # TE jobs are never preempted
    assert (res.preempt_count[js.is_te] == 0).all()


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(jobsets())
def test_fifo_order_no_preemption(js):
    """Under vanilla FIFO: no preemption, and start order follows
    submission order (strict head-of-line)."""
    cfg = cfg_for("fifo")
    res = simulator.simulate(cfg, js)
    assert res.preempt_count.sum() == 0


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(jobsets(max_jobs=25), st.integers(0, 3))
def test_jax_engine_parity(js, seed):
    """JAX engine reproduces the reference tick-for-tick (deterministic
    policies)."""
    from repro.core import sim_jax
    for policy in ("fifo", "lrtp"):
        cfg = cfg_for(policy)
        ref = simulator.simulate(cfg, js)
        st_ = sim_jax.run_jit(cfg, sim_jax.jobs_from_jobset(js), seed)
        assert (np.asarray(st_.finish) == ref.finish).all(), policy
        assert (np.asarray(st_.preempt_count) == ref.preempt_count).all()


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(0, 1000))
def test_fitgpp_parity_generated_workloads(seed):
    """FitGpp parity on realistic generated workloads."""
    from repro.core import sim_jax
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=192), policy="fitgpp",
                    seed=seed)
    js = workload.generate(cfg)
    ref = simulator.simulate(cfg, js)
    st_ = sim_jax.run_jit(cfg, sim_jax.jobs_from_jobset(js), seed)
    assert (np.asarray(st_.finish) == ref.finish).all()
    assert (np.asarray(st_.preempt_count) == ref.preempt_count).all()
