import os

# Tests must see the REAL device count (1 CPU device). Only the dry-run
# script forces 512 placeholder devices.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
