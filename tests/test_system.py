"""End-to-end behaviour: the paper's headline claims hold on a reduced
synthetic workload (the full-scale numbers live in EXPERIMENTS.md)."""
import dataclasses

import numpy as np

from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import metrics, simulator, workload


def _run(policy, jobs, cfg):
    res = simulator.simulate(dataclasses.replace(cfg, policy=policy), jobs)
    return metrics.pooled_tables(metrics.merge_results([res]))


def test_paper_headline_claims_reduced_scale():
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=2 ** 12), s=4.0,
                    max_preemptions=1, seed=0)
    jobs = workload.generate(cfg)
    fifo = _run("fifo", jobs, cfg)
    lrtp = _run("lrtp", jobs, cfg)
    fit = _run("fitgpp", jobs, cfg)

    # claim 1: FitGpp slashes the TE p95 slowdown vs FIFO (paper: -96.6%)
    assert fit["TE"]["p95"] < 0.10 * fifo["TE"]["p95"]
    # claim 2: BE jobs are not greatly elongated (paper: +18% median)
    assert fit["BE"]["p50"] < 1.35 * fifo["BE"]["p50"]
    # claim 3: FitGpp preempts far fewer jobs than LRTP (paper: ~15x)
    assert fit["preempted_frac"] < 0.6 * lrtp["preempted_frac"]
    # claim 4: FitGpp's preemption->reschedule intervals are shorter
    assert fit["intervals"]["p50"] <= lrtp["intervals"]["p50"]
    # claim 5: preemptive TE latencies are near-1 (paper: p50 = 1.00)
    assert fit["TE"]["p50"] <= 1.05


def test_fig5_p_independence_reduced():
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=2 ** 11), s=4.0, seed=1)
    jobs = workload.generate(cfg)
    p95 = []
    for P in (1, 1_000_000):
        c = dataclasses.replace(cfg, max_preemptions=P)
        p95.append(_run("fitgpp", jobs, c)["TE"]["p95"])
    assert abs(p95[0] - p95[1]) < 0.4      # paper Fig. 5: ~independent


def test_beyond_paper_backfill_extension():
    """Non-FIFO extension (paper's future work): bounded backfill keeps
    FitGpp's TE latency while strongly improving BE slowdowns."""
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=2 ** 11), s=4.0,
                    max_preemptions=1, seed=3)
    jobs = workload.generate(cfg)
    plain = _run("fitgpp", jobs, cfg)
    cfg_bf = dataclasses.replace(cfg, backfill=True)
    bf = _run("fitgpp", jobs, cfg_bf)
    assert bf["BE"]["p50"] < plain["BE"]["p50"]        # BE improves
    assert bf["TE"]["p95"] < 2.0                        # TE stays near-1


def test_sim_kernel_path_parity():
    """SimConfig.score_backend="pallas" routes Eq. 1-4 through the
    Pallas kernel with identical outcomes."""
    import numpy as np
    from repro.core import sim_jax
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=192), policy="fitgpp",
                    seed=11, score_backend="pallas")
    jobs = workload.generate(cfg)
    ref = simulator.simulate(cfg, jobs)
    st = sim_jax.run(cfg, sim_jax.jobs_from_jobset(jobs), 11)
    assert (np.asarray(st.finish) == ref.finish).all()


def test_sim_kernel_env_override_removed():
    """The deprecated REPRO_SIM_KERNEL env switch now fails loudly,
    pointing at SimConfig.score_backend (any value, "0" included —
    the variable is dead, not just off by default)."""
    import os
    import pytest
    from repro.core import sim_jax
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=8), policy="fitgpp")
    jobs = sim_jax.jobs_from_jobset(workload.generate(cfg))
    for value in ("1", "0"):
        os.environ["REPRO_SIM_KERNEL"] = value
        try:
            with pytest.raises(RuntimeError, match="score_backend"):
                sim_jax.make_tick(cfg, jobs, cfg.cluster.n_nodes)
        finally:
            os.environ.pop("REPRO_SIM_KERNEL", None)
