"""Streaming macro-round engine (core/stream, DESIGN.md §10).

Locked down here:

* the bit-parity window: streamed per-job results / makespan / final
  rng state on a prefix equal the monolithic ``sim_jax`` run exactly,
  across policies and both time modes, WITH real slot recycling
  (capacity << n_jobs);
* slot-recycling invariants: no global job id lost or double-assigned
  across rounds (capacity changes the recycling pattern but not one
  output bit), and the pool starving loudly instead of deadlocking;
* the per-round event drain: gid-remapped streams are schema-valid,
  satisfy the §8 slowdown-decomposition identity, never overflow the
  default per-round ring, and round-trip through the incremental CSV
  writer;
* the source layer: ordering contract enforcement, chunked synthetic
  determinism, streaming trace readers vs the monolithic loaders, and
  the tiled-fixture long trace;
* the facade: ``api.run_stream`` + the scenarios CLI ``--stream`` /
  streamed ``describe``.

Engine configs use sub-critical load (0.5): arrivals are open-loop, so
near-saturation load grows the arrived-unfinished backlog past any
fixed pool (that is the starvation test).
"""
import dataclasses

import numpy as np
import pytest

from repro import api, scenarios
from repro.core import stream, workload
from repro.core.types import JobSet
from repro.obs import export, ring, schema, timeseries
from repro.scenarios import traces


def _cfg(policy="fitgpp", n_jobs=400, n_nodes=8, seed=0, load=0.5):
    cfg = api.make_config(policy, n_jobs=n_jobs, n_nodes=n_nodes,
                          seed=seed)
    return dataclasses.replace(
        cfg, workload=dataclasses.replace(cfg.workload, load=load))


def _mk_chunk(submits, exec_total=5):
    n = len(submits)
    return JobSet(submit=np.asarray(submits, np.int64),
                  exec_total=np.full(n, exec_total, np.int64),
                  demand=np.tile([1.0, 1.0, 1.0], (n, 1)),
                  is_te=np.zeros(n, bool),
                  gp=np.zeros(n, np.int64),
                  n_nodes=np.ones(n, np.int64))


# ---------------------------------------------------------------- parity

@pytest.mark.parametrize("policy,mode", [("fitgpp", "event"),
                                         ("lrtp", "tick")])
def test_parity_window(policy, mode):
    """Streamed == monolithic, bit-exact, with 5 recycling rounds."""
    diff = stream.verify_prefix_parity(_cfg(policy), n_jobs=400,
                                       capacity=96, chunk=64,
                                       time_mode=mode)
    assert diff == []


@pytest.mark.slow
@pytest.mark.parametrize("policy,mode", [("fitgpp", "tick"),
                                         ("lrtp", "event"),
                                         ("srtp", "event"),
                                         ("fifo", "event")])
def test_parity_window_full_matrix(policy, mode):
    diff = stream.verify_prefix_parity(_cfg(policy), n_jobs=400,
                                       capacity=96, chunk=64,
                                       time_mode=mode)
    assert diff == []


# ------------------------------------------------- recycling invariants

def test_no_gid_lost_or_duplicated_across_capacities():
    """Different capacities = different recycling patterns; the
    per-gid results must not change by one bit, and _finalize's
    completeness check (every gid exactly once) must hold."""
    cfg = _cfg(n_jobs=300)
    results = {}
    for cap in (96, 160):
        src = stream.JobSource(workload.stream_chunks(cfg, 300, chunk=64))
        results[cap] = stream.StreamEngine(cfg, src, capacity=cap).run()
    a, b = results[96], results[160]
    assert a.n_jobs == b.n_jobs == 300
    assert a.rounds > 1 and b.rounds > 1    # recycling actually happened
    assert a.max_live <= 96 and b.max_live <= 160
    for f in ("submit", "exec_total", "is_te", "finish", "preempt_count",
              "last_signal", "last_vacate", "last_resume"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), f)
    assert (a.finish >= a.submit).all()


def test_pool_starvation_spills_in_order():
    """Saturating load overflows any fixed pool: overdue arrivals move
    to the host spill queue (order preserved, loudly counted) and the
    run completes instead of deadlocking or aborting. Every gid's job
    data must still match the materialized stream exactly — spilling
    delays packing, never reorders or drops."""
    cfg = _cfg(n_jobs=200, load=2.0)
    src = stream.JobSource(workload.stream_chunks(cfg, 200, chunk=64))
    res = stream.StreamEngine(cfg, src, capacity=16).run()
    assert res.n_jobs == 200
    assert res.n_spilled > 0 and res.spill_peak > 0
    assert res.max_live <= 16
    data = stream.materialize(
        stream.JobSource(workload.stream_chunks(cfg, 200, chunk=64)))
    np.testing.assert_array_equal(res.submit, data.submit)
    np.testing.assert_array_equal(res.exec_total, data.exec_total)
    assert (res.finish >= res.submit + res.exec_total).all()
    assert res.summary()["n_spilled"] == res.n_spilled


def test_spilled_run_rejected_by_parity_window():
    """Spilling leaves the bit-parity domain (the scheduler saw spilled
    jobs late): verify_prefix_parity must refuse the run loudly, not
    return a field diff."""
    cfg = _cfg(n_jobs=200, load=2.0)
    with pytest.raises(ValueError, match="spill"):
        stream.verify_prefix_parity(cfg, n_jobs=200, capacity=16,
                                    chunk=64)


def test_akey_gid_limit_guard():
    """gids ride in float32 ``akey``; past 2^24 consecutive integers
    collide and global arrival order silently breaks. The pack loop
    must refuse loudly at the boundary — forged here via ``_reset`` so
    the test doesn't stream 16M jobs."""
    cfg = _cfg(n_jobs=64)

    class Forged(stream.StreamEngine):
        def _reset(self):
            super()._reset()
            self._n_seen = stream.AKEY_GID_LIMIT - 8

    src = stream.JobSource(workload.stream_chunks(cfg, 64, chunk=32))
    with pytest.raises(RuntimeError, match=r"2\^24"):
        Forged(cfg, src, capacity=96).run()


# ------------------------------------------------- closed-loop admission

def test_admission_admit_times_bit_exact():
    """Tentpole contract: ClosedLoopAdmission's admit ticks equal the
    monolithic closed_loop_submit_times bit for bit, and the job data
    passes through untouched. The admission sim is FIFO regardless of
    cfg.policy, so one policy per mode covers the controller; the
    policy axis is exercised by the engine matrix below."""
    for mode in ("tick", "event"):
        cfg = dataclasses.replace(_cfg(load=2.0), time_mode=mode)
        diff = stream.verify_admission_parity(cfg, n_jobs=400, chunk=64)
        assert diff == [], f"time_mode={mode}: {diff}"


def test_admission_chunk_invariant():
    """The pending-buffer size is an implementation knob: admit ticks
    must not depend on it (the monolithic sim admits across chunk
    boundaries within one tick — refill-and-continue must reproduce
    that)."""
    cfg = _cfg(load=2.0)
    outs = []
    for chunk in (16, 64, 512):
        src = stream.JobSource(workload.stream_chunks(cfg, 300, chunk=64))
        outs.append(stream.materialize(stream.JobSource(
            stream.ClosedLoopAdmission(cfg, src, chunk=chunk))))
    for js in outs[1:]:
        np.testing.assert_array_equal(js.submit, outs[0].submit)


@pytest.mark.parametrize("policy,mode", [("fifo", "event"),
                                         ("lrtp", "tick")])
def test_closed_loop_engine_parity(policy, mode):
    """Whole streamed closed-loop path — admission controller AND
    macro-round engine — bit-exact with the monolithic pipeline.
    Rank/non-preemptive policies only: score policies' random fallback
    fires at saturation and is pool-size dependent (the documented
    parity exclusion, see _diff_vs_monolithic)."""
    cfg = _cfg(policy, load=2.0)
    diff = stream.verify_closed_loop_parity(cfg, n_jobs=400,
                                            capacity=160, chunk=64,
                                            time_mode=mode)
    assert diff == []


@pytest.mark.slow
@pytest.mark.parametrize("policy,mode", [("fifo", "tick"),
                                         ("lrtp", "event"),
                                         ("srtp", "tick"),
                                         ("srtp", "event")])
def test_closed_loop_engine_parity_full_matrix(policy, mode):
    diff = stream.verify_closed_loop_parity(_cfg(policy, load=2.0),
                                            n_jobs=400, capacity=160,
                                            chunk=64, time_mode=mode)
    assert diff == []


@pytest.mark.slow
def test_closed_loop_golden_load2():
    """§4.2 at load 2.0, streamed end to end: FitGpp's TE tail must
    collapse relative to FIFO (the paper's headline claim) with BE
    medians staying bounded — same thresholds as the monolithic golden
    checks, reproduced through the streamed admission + engine path."""
    res = {}
    for policy in ("fifo", "fitgpp"):
        cfg = _cfg(policy, n_jobs=2000, load=2.0)
        src = stream.JobSource(workload.stream_chunks(cfg, 2000,
                                                      chunk=256))
        r = stream.StreamEngine(cfg, src, capacity=1024,
                                admission=True).run()
        assert r.n_spilled == 0      # the closed loop bounds the backlog
        res[policy] = r.summary()
    fifo_te95 = res["fifo"]["TE"]["p95"]
    fit_te95 = res["fitgpp"]["TE"]["p95"]
    assert fifo_te95 > 5.0
    assert fit_te95 < 0.2 * fifo_te95       # >= 80% reduction
    assert res["fitgpp"]["BE"]["p50"] <= 1.35 * res["fifo"]["BE"]["p50"]


# ------------------------------------------------------ per-round drain

def test_streamed_trace_decomposition_and_drain(tmp_path):
    """One traced streamed run: schema-valid gid-remapped events, §8
    decomposition identity on every job, no overflow at the default
    per-round ring size, and the incremental CSV writer reproducing
    the in-memory stream byte for byte."""
    cfg = _cfg(n_jobs=300)
    src = stream.JobSource(workload.stream_chunks(cfg, 300, chunk=64))
    res = stream.StreamEngine(cfg, src, capacity=96, trace=True).run()
    assert res.trace_overflow == 0
    schema.validate_events(res.events, n_jobs=res.n_jobs,
                           n_nodes=cfg.cluster.n_nodes)
    dec = timeseries.slowdown_decomposition(res.events)
    assert len(dec) == res.n_jobs
    for gid, d in dec.items():
        assert d.identity_holds(), f"identity broken for gid {gid}"
        assert d.finish == int(res.finish[gid])
        assert d.submit == int(res.submit[gid])
    # event_sink path: per-round CSV append == the in-memory stream
    src2 = stream.JobSource(workload.stream_chunks(cfg, 300, chunk=64))
    path = tmp_path / "trace.csv"
    with export.CsvTraceWriter(str(path)) as w:
        res2 = stream.StreamEngine(cfg, src2, capacity=96, trace=True,
                                   event_sink=w.write).run()
    assert res2.events is None              # sink consumed them
    assert w.n_written == len(res.events)
    assert export.read_csv(path.read_text()) == res.events


def test_round_capacity_sizes_off_slots():
    assert ring.round_capacity(128, 2) == ring.default_capacity(128, 2)
    # the whole point: a streamed ring is sized by the POOL, not the trace
    assert ring.round_capacity(256, 1) < ring.default_capacity(100_000, 1)


# ------------------------------------------------------------- sources

def test_jobsource_ordering_contract():
    with pytest.raises(ValueError, match="not submit-sorted"):
        stream.JobSource([_mk_chunk([5, 3])]).take(2)
    with pytest.raises(ValueError, match="decrease across chunks"):
        src = stream.JobSource([_mk_chunk([0, 10]), _mk_chunk([4, 20])])
        src.take(4)


def test_jobsource_take_and_scan():
    src = stream.JobSource([_mk_chunk([0, 1, 2]), _mk_chunk([3, 4])])
    js = src.take(4)
    assert js.n == 4 and src.take(10).n == 1 and src.take(1) is None
    info = stream.scan(stream.JobSource([_mk_chunk([0, 1, 2]),
                                         _mk_chunk([3, 4])]))
    assert info.n_jobs == 5 and info.first_submit == 0
    assert info.last_submit == 4 and info.n_be == 5


def test_stream_chunks_deterministic():
    cfg = _cfg(n_jobs=256)
    a = stream.materialize(
        stream.JobSource(workload.stream_chunks(cfg, 256, chunk=64)))
    b = stream.materialize(
        stream.JobSource(workload.stream_chunks(cfg, 256, chunk=64)))
    for f in ("submit", "exec_total", "demand", "is_te", "gp", "n_nodes"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), f)
    assert a.n == 256 and (np.diff(a.submit) >= 0).all()


# -------------------------------------------------- streaming readers

@pytest.mark.parametrize("path,dialect,loader", [
    (traces.PHILLY_SAMPLE, "philly", traces.load_philly_csv),
    (traces.PAI_SAMPLE, "pai", traces.load_pai_csv)])
def test_trace_reader_matches_monolithic(path, dialect, loader):
    """Same rows, same normalization, same drop accounting as the
    monolithic loader in one streaming pass (gp excluded: the stream
    draws per chunk by contract)."""
    cfg = _cfg()
    mono, mstats = loader(path, cfg, return_stats=True)
    src = traces.trace_source(path, cfg, dialect, chunk=7)
    got = stream.materialize(src)
    for f in ("submit", "exec_total", "demand", "is_te", "n_nodes"):
        np.testing.assert_array_equal(getattr(got, f), getattr(mono, f), f)
    assert src.stats == mstats


def test_trace_reader_unsorted_raises(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("jobid,vc,submit_time,start_time,end_time,gpus,status\n"
                 "a,vc1,7200,7300,12000,1,Pass\n"
                 "b,vc1,600,700,6000,1,Pass\n")
    with pytest.raises(ValueError, match="not submit-ordered"):
        list(traces.iter_trace_csv(str(p), _cfg(), "philly"))


def test_tiled_fixture_stream():
    cfg = _cfg(n_jobs=120)
    js = stream.materialize(
        traces.tiled_source(traces.PHILLY_SAMPLE, cfg, "philly",
                            repeats=5))
    assert js.n == 5 * 26 and (np.diff(js.submit) >= 0).all()
    # registry entry honors workload.n_jobs through the repeat count
    built = scenarios.build("philly-tiled", cfg)
    assert built.n >= 120 and built.n == -(-120 // 26) * 26


def test_get_source_fallback_matches_build():
    """Scenarios without a registered source stream the exact jobset
    the monolithic build produces."""
    cfg = _cfg(n_jobs=64)
    js = scenarios.build("burst-storm", cfg)
    got = stream.materialize(scenarios.get_source("burst-storm", cfg))
    for f in ("submit", "exec_total", "demand", "is_te", "gp", "n_nodes"):
        np.testing.assert_array_equal(getattr(got, f), getattr(js, f), f)


# --------------------------------------------------------------- facade

def test_run_stream_api_and_cli(capsys):
    r = api.run_stream("philly-tiled", "fitgpp", n_jobs=120, n_nodes=8)
    assert r.engine == "stream"
    assert r.raw.n_jobs == len(r.raw.finish) == 130
    assert set(r.table) == {"TE", "BE"} and r.makespan > 0
    from repro.scenarios.__main__ import main
    main(["run", "philly-tiled", "--stream", "--n-jobs", "120",
          "--nodes", "8"])
    out = capsys.readouterr().out
    assert "engine=stream" in out and "slowdown percentiles" in out
    main(["describe", "philly-sample"])
    out = capsys.readouterr().out
    assert "stream (one pass" in out and "kept 26/28 rows" in out
