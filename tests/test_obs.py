"""Unit coverage for the telemetry subsystem (``repro.obs``,
DESIGN.md §8): schema lifecycle validation, the export round-trips
(CSV lossless, Perfetto structurally sound), the replayed time-series
metrics, and the schema-rendered parity diagnostics."""
import json

import numpy as np
import pytest

from repro import scenarios
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, simulator
from repro.core.types import PreemptionEvent, SimResult
from repro.obs import export, ring, schema, timeseries
from repro.obs.schema import Event


def _traced(policy="lrtp", n_nodes=16, n_jobs=96, seed=3, **kw):
    """One preemption-heavy traced reference run (shared fixture)."""
    cfg = SimConfig(cluster=ClusterSpec(n_nodes=n_nodes), policy=policy,
                    workload=WorkloadSpec(n_jobs=n_jobs), seed=seed, **kw)
    js = scenarios.build("gang-heavy", cfg)
    res = simulator.simulate(cfg, js, trace=True)
    return cfg, js, res


@pytest.fixture(scope="module")
def traced():
    return _traced()


class TestSchema:
    def test_render_names_fields(self):
        ev = Event(t=5, code=schema.PREEMPT_SIGNAL, job=3, aux=7)
        assert ev.render() == "PREEMPT_SIGNAL t=5 job=3 te=7"
        ev = Event(t=2, code=schema.START, job=1, nodes=(0, 4))
        assert ev.render() == "START t=2 job=1 nodes=0+4"
        ev = Event(t=9, code=schema.BACKFILL, job=2, aux=3)
        assert "skipped=3" in ev.render()

    def test_validate_accepts_real_trace(self, traced):
        cfg, js, res = traced
        schema.validate_events(res.trace, n_jobs=js.n,
                               n_nodes=cfg.cluster.n_nodes)

    @pytest.mark.parametrize("events,msg", [
        ([Event(0, schema.START, 0, nodes=(0,))], "before SUBMIT"),
        ([Event(0, schema.SUBMIT, 0), Event(0, schema.SUBMIT, 0)],
         "second SUBMIT"),
        ([Event(0, schema.SUBMIT, 0), Event(0, schema.START, 0)],
         "without a node-set"),
        ([Event(1, schema.SUBMIT, 0), Event(0, schema.SUBMIT, 1)],
         "timestamp decreases"),
        ([Event(0, schema.SUBMIT, 0),
          Event(0, schema.RESUME, 0, nodes=(0,))], "RESUME before"),
        ([Event(0, schema.SUBMIT, 0), Event(0, schema.VACATE, 0)],
         "without a pending signal"),
        ([Event(0, schema.SUBMIT, 0), Event(0, schema.START, 0,
                                            nodes=(0,)),
          Event(1, schema.FINISH, 0), Event(2, schema.REQUEUE, 0)],
         "after FINISH"),
        ([Event(0, 99, 0)], "unknown code"),
    ])
    def test_validate_rejects(self, events, msg):
        with pytest.raises(ValueError, match=msg):
            schema.validate_events(events)

    def test_validate_names_offending_index(self):
        events = [Event(0, schema.SUBMIT, 0),
                  Event(0, schema.START, 0, nodes=(0,)),
                  Event(3, schema.VACATE, 0)]
        with pytest.raises(ValueError, match=r"event 2 \[VACATE"):
            schema.validate_events(events)


class TestExports:
    def test_csv_round_trip_lossless(self, traced):
        _, _, res = traced
        assert export.read_csv(export.to_csv(res.trace)) == res.trace

    def test_csv_rejects_foreign_header(self):
        with pytest.raises(ValueError, match="not a trace CSV"):
            export.read_csv("a,b,c\n1,2,3\n")

    def test_perfetto_structure(self, traced):
        cfg, js, res = traced
        doc = export.to_perfetto(res.trace, n_nodes=cfg.cluster.n_nodes,
                                 is_te=js.is_te)
        json.dumps(doc)                       # serializable
        tr = doc["traceEvents"]
        names = {e["name"] for e in tr if e["ph"] == "M"}
        assert {"process_name", "thread_name"} <= names
        node_meta = [e for e in tr if e["ph"] == "M"
                     and e["name"] == "thread_name"]
        assert len(node_meta) == cfg.cluster.n_nodes
        # one complete occupancy slice per (placement, node) pair
        slices = [e for e in tr if e["ph"] == "X"]
        placements = sum(len(e.nodes) for e in res.trace
                         if e.code in schema.PLACEMENT_CODES)
        assert len(slices) == placements
        assert all(s["dur"] >= 0 for s in slices)
        # signal instants and the three counter tracks
        assert any(e["ph"] == "i" for e in tr)
        counters = {e["name"] for e in tr if e["ph"] == "C"}
        assert counters == {"queue depth", "in grace", "busy nodes"}

    def test_write_trace_rejects_unknown_format(self, tmp_path, traced):
        with pytest.raises(ValueError, match="unknown trace format"):
            export.write_trace(str(tmp_path / "x"), traced[2].trace,
                               fmt="pdf")

    def test_write_trace_formats(self, tmp_path, traced):
        cfg, js, res = traced
        p = tmp_path / "t.perfetto.json"
        export.write_trace(str(p), res.trace, fmt="perfetto",
                           n_nodes=cfg.cluster.n_nodes, is_te=js.is_te)
        assert json.loads(p.read_text())["traceEvents"]
        c = tmp_path / "t.csv"
        export.write_trace(str(c), res.trace, fmt="csv")
        assert export.read_csv(c.read_text()) == res.trace


class TestTimeSeries:
    def test_replay_sanity(self, traced):
        cfg, js, res = traced
        ts = timeseries.compute_timeseries(
            res.trace, n_nodes=cfg.cluster.n_nodes, is_te=js.is_te)
        assert (np.diff(ts.t) > 0).all()
        assert (ts.busy_nodes >= 0).all()
        assert (ts.busy_nodes <= cfg.cluster.n_nodes).all()
        assert 0.0 < ts.mean_utilization() <= 1.0
        # every job finished: queues drain, occupancy empties
        assert ts.queue_depth_te[-1] == ts.queue_depth_be[-1] == 0
        assert ts.busy_nodes[-1] == 0 and ts.in_grace[-1] == 0
        n_signals = sum(e.code == schema.PREEMPT_SIGNAL
                        for e in res.trace)
        assert int(ts.cum_preemptions[-1]) == n_signals > 0
        assert ts.preempt_rate == pytest.approx(
            n_signals / ts.makespan)
        assert ts.makespan == res.makespan

    def test_format_timeseries(self, traced):
        cfg, js, res = traced
        ts = timeseries.compute_timeseries(
            res.trace, n_nodes=cfg.cluster.n_nodes, is_te=js.is_te)
        txt = timeseries.format_timeseries(ts, max_rows=8)
        assert len(txt.splitlines()) == 10      # header + rule + 8

    def test_decomposition_matches_slowdown(self, traced):
        """The decomposition reproduces the paper's Eq. 5 slowdown:
        1 + (initial_wait + grace_stall + requeue_wait) / service."""
        _, js, res = traced
        dec = timeseries.slowdown_decomposition(res.trace)
        sd = res.slowdown
        for j, d in dec.items():
            waits = d.initial_wait + d.grace_stall + d.requeue_wait
            assert 1.0 + waits / d.service == pytest.approx(sd[j])


class TestRingHelpers:
    def test_node_word_packing(self):
        w = ring.node_mask_weights(70)
        assert w.shape == (ring.n_node_words(70), 70)
        # bit k of word k//32 set exactly for node k
        assert int(w[2, 69]) == 1 << (69 % 32)
        assert int(w[0, 69]) == 0

    def test_default_capacity_scales_with_P(self):
        assert ring.default_capacity(100, max_preemptions=3) > \
            ring.default_capacity(100, max_preemptions=1)


class TestParityDiagnostics:
    """Satellite: parity failures speak the event schema, not bare
    tuples."""

    def test_trace_parity_renders_divergence(self):
        a = [Event(0, schema.SUBMIT, 0), Event(1, schema.START, 0,
                                               nodes=(2,))]
        b = [Event(0, schema.SUBMIT, 0), Event(1, schema.START, 1,
                                               nodes=(2,))]
        with pytest.raises(AssertionError) as ei:
            metrics.assert_trace_parity(a, b)
        msg = str(ei.value)
        assert "diverge at event 1" in msg
        assert "START t=1 job=0 nodes=2" in msg
        assert "START t=1 job=1 nodes=2" in msg

    def test_trace_parity_length_mismatch(self):
        a = [Event(0, schema.SUBMIT, 0)]
        with pytest.raises(AssertionError, match="lengths differ"):
            metrics.assert_trace_parity(a, a + a)

    def test_result_parity_renders_preemption_events(self):
        def mk(events):
            n = 2
            return SimResult(
                finish=np.array([5, 9]), exec_total=np.array([4, 4]),
                submit=np.zeros(n, np.int64),
                is_te=np.array([True, False]),
                preempt_count=np.array([0, 1]), events=events,
                makespan=9)
        a = mk([PreemptionEvent(job=1, te_job=0, signal_time=2,
                                vacate_time=3, resume_time=4)])
        b = mk([PreemptionEvent(job=1, te_job=0, signal_time=2,
                                vacate_time=3, resume_time=5)])
        with pytest.raises(AssertionError) as ei:
            metrics.assert_result_parity(a, b)
        msg = str(ei.value)
        assert "diverge at event 0" in msg
        assert "PREEMPT_SIGNAL t=2 job=1 te=0" in msg
        assert "RESUME t=4" in msg and "RESUME t=5" in msg
