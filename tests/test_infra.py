"""Infrastructure tests: trainer, checkpoint, controller, data, sweep."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models, trainer
from repro.checkpoint import (estimate_grace_period, load_pytree,
                              save_pytree, state_bytes)
from repro.configs import get_smoke_config
from repro.core.controller import Controller, JobSpec
from repro.data import make_batch
from repro.optim import AdamWConfig, adamw_init, adamw_update


class TestTrainer:
    def test_microbatch_equivalence(self):
        """grad accumulation over M microbatches == full-batch step."""
        cfg = get_smoke_config("stablelm-12b").replace(dtype="float32")
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                           grad_clip=0.0)
        state0 = trainer.init_train_state(cfg, ocfg, jax.random.key(0))
        batch = make_batch(cfg, 4, 32, seed=0, step=0)
        s1, m1 = trainer.make_train_step(cfg, ocfg, 1)(state0, batch)
        s2, m2 = trainer.make_train_step(cfg, ocfg, 2)(state0, batch)
        assert np.isclose(float(m1["loss"]), float(m2["loss"]), atol=1e-5)
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5)

    def test_grad_clip(self):
        cfg = get_smoke_config("mamba2-1.3b").replace(dtype="float32")
        ocfg = AdamWConfig(lr=1e-2, grad_clip=1e-6, weight_decay=0.0,
                           warmup_steps=0, total_steps=10)
        state = trainer.init_train_state(cfg, ocfg, jax.random.key(0))
        batch = make_batch(cfg, 2, 16, seed=0, step=0)
        new, _ = trainer.make_train_step(cfg, ocfg)(state, batch)
        # with a tiny clip the params should barely move
        delta = max(float(jnp.abs(a - b).max()) for a, b in
                    zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new["params"])))
        assert delta < 1e-2


class TestCheckpoint:
    def test_roundtrip_bf16(self):
        cfg = get_smoke_config("command-r-35b")   # bf16 params
        ocfg = AdamWConfig(moment_dtype="bfloat16")
        state = trainer.init_train_state(cfg, ocfg, jax.random.key(1))
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck.npz")
            save_pytree(state, p)
            state2 = load_pytree(state, p)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
            assert a.dtype == b.dtype
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_grace_period_scales_with_state(self):
        small = {"w": jnp.zeros((1024,))}
        big = {"w": jnp.zeros((512, 1024, 1024))}   # 2 GB f32
        assert estimate_grace_period(big, storage_bw_bytes_per_s=1e7) > \
            estimate_grace_period(small, storage_bw_bytes_per_s=1e7)
        assert state_bytes(big) == 512 * 1024 * 1024 * 4


class TestController:
    def _mk(self, policy="fitgpp", workdir=None):
        return Controller(n_nodes=1, node_cap=(32., 256., 8.),
                          policy=policy, steps_per_tick=2,
                          workdir=workdir or tempfile.mkdtemp())

    def test_preempt_resume_bit_exact(self):
        cfg = get_smoke_config("internvl2-2b")
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=1000)
        # uninterrupted baseline
        st = trainer.init_train_state(cfg, opt,
                                      jax.random.key(hash("be0") % (1 << 31)))
        step = jax.jit(trainer.make_train_step(cfg, opt))
        base = []
        for i in range(16):
            st, m = step(st, make_batch(cfg, 4, 32, seed=1, step=i))
            base.append(float(m["loss"]))
        # controller run with one preemption in the middle
        ctl = self._mk()
        be = ctl.submit(JobSpec("be0", cfg, False,
                                np.array([8., 32., 8.]), total_steps=16))
        te = ctl.submit(JobSpec("te0", cfg, True,
                                np.array([4., 16., 8.]), total_steps=2,
                                submit_tick=2))
        ctl.run()
        assert be.preempt_count == 1
        np.testing.assert_allclose(be.losses, base, atol=1e-6)

    def test_te_latency_beats_fifo(self):
        cfg = get_smoke_config("mamba2-1.3b")

        def run(policy):
            ctl = self._mk(policy)
            ctl.submit(JobSpec("be0", cfg, False, np.array([8., 32., 8.]),
                               total_steps=30))
            te = ctl.submit(JobSpec("te0", cfg, True,
                                    np.array([4., 16., 4.]), total_steps=2,
                                    submit_tick=1))
            ctl.run()
            return ctl.slowdown(te)

        assert run("fitgpp") < run("fifo")

    def test_victim_selection_prefers_short_gp(self):
        cfg = get_smoke_config("mamba2-1.3b")
        ctl = Controller(n_nodes=2, node_cap=(32., 256., 8.),
                         policy="fitgpp", s=4.0,
                         workdir=tempfile.mkdtemp())
        b1 = ctl.submit(JobSpec("be_long_gp", cfg, False,
                                np.array([8., 32., 8.]), total_steps=40,
                                gp_ticks=5))
        b2 = ctl.submit(JobSpec("be_short_gp", cfg, False,
                                np.array([8., 32., 8.]), total_steps=40,
                                gp_ticks=1))
        te = ctl.submit(JobSpec("te", cfg, True, np.array([4., 16., 4.]),
                                total_steps=2, submit_tick=1))
        ctl.run()
        assert b2.preempt_count == 1 and b1.preempt_count == 0


class TestData:
    def test_determinism_and_resume(self):
        cfg = get_smoke_config("stablelm-12b")
        b1 = make_batch(cfg, 4, 32, seed=7, step=5)
        b2 = make_batch(cfg, 4, 32, seed=7, step=5)
        assert np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b2["tokens"]))
        b3 = make_batch(cfg, 4, 32, seed=7, step=6)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_zipf_structure(self):
        cfg = get_smoke_config("stablelm-12b")
        toks = np.asarray(make_batch(cfg, 8, 256, 0, 0)["tokens"]).ravel()
        # low ids must be much more frequent than high ids (Zipf)
        low = (toks < cfg.vocab // 10).mean()
        assert low > 0.3

    def test_multimodal_shapes(self):
        for arch in ("whisper-large-v3", "internvl2-2b"):
            cfg = get_smoke_config(arch)
            b = make_batch(cfg, 2, 64, 0, 0)
            assert "tokens" in b and len(b) == 2


class TestSweep:
    def test_grid_shapes_and_s_effect(self):
        from repro.configs.cluster import SimConfig, WorkloadSpec
        from repro.core import sweep
        cfg = SimConfig(workload=WorkloadSpec(n_jobs=256), policy="fitgpp")
        out = sweep.sensitivity_grid(cfg, 256, s_vals=[0.0, 4.0],
                                     seeds=[0, 1])
        assert out["te_slowdown"].shape == (2, 2, 3)
        assert out["intervals"].shape == (2, 2, 4)
        assert np.isfinite(out["be_slowdown"]).all()
