"""NaN-safety of the masked percentile/summary helpers (DESIGN.md §5).

A trial can legitimately have ZERO valid TE jobs (an all-BE jobset, or
a padded lane whose few TE rows are sentinels) or zero valid BE jobs.
Every summary surface — ``sim_jax.result_summary``, the vmapped
``sweep._trial_result``, the reference-engine tables — must then
return an EXPLICIT ``nan`` for the empty class (no empty-slice
warnings, no garbage values leaking out of an all-NaN reduction), and
nan-aware pooling must exclude the trial instead of poisoning the
aggregate.
"""
import warnings

import numpy as np
import pytest

from repro import api
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, sim_jax, sweep
from repro.core.types import JobSet


def one_class_jobset(n: int, te: bool, seed: int = 0) -> JobSet:
    rng = np.random.default_rng(seed)
    return JobSet(
        submit=np.cumsum(rng.integers(0, 3, n)).astype(np.int64),
        exec_total=rng.integers(1, 20, n).astype(np.int64),
        demand=np.stack([rng.integers(1, 16, n).astype(float),
                         rng.integers(1, 64, n).astype(float),
                         rng.choice([0.0, 1.0, 2.0], n)], axis=1),
        is_te=np.full(n, te),
        gp=rng.integers(0, 5, n).astype(np.int64))


CFG = SimConfig(cluster=ClusterSpec(n_nodes=2), policy="fitgpp",
                workload=WorkloadSpec(n_jobs=24))


class TestJaxSummaries:
    @pytest.mark.parametrize("te", [False, True])
    def test_result_summary_empty_class(self, te):
        js = one_class_jobset(24, te=te)
        jobs = sim_jax.jobs_from_jobset(js)
        st = sim_jax.run_jit(CFG, jobs, 0)
        out = sim_jax.result_summary(jobs, st)
        empty, full = ("BE", "TE") if te else ("TE", "BE")
        assert all(np.isnan(float(v)) for v in out[empty].values())
        assert all(np.isfinite(float(v)) for v in out[full].values())
        if te:     # no BE jobs -> preempted fraction is nan, not 0/0
            assert np.isnan(float(out["preempted_frac"]))
        else:      # no TE jobs -> nothing ever preempted: intervals nan
            assert all(np.isnan(float(v))
                       for v in out["intervals"].values())

    def test_vmapped_sweep_excludes_nan_trials(self):
        """Ragged batch of [all-BE, all-TE, mixed] trials: the empty
        classes come back as explicit nan rows and nan-aware pooling
        sees only the populated trials."""
        from repro.core import workload
        mixed = workload.generate(CFG)
        jobsets = [one_class_jobset(20, te=False),
                   one_class_jobset(28, te=True), mixed]
        stacked = sweep.stack_jobsets(jobsets)
        out = sweep.run_sweep(CFG, stacked, np.full(3, 4.0),
                              np.full(3, 1), range(3))
        te_p95 = out["te_slowdown"][:, 1]
        be_p50 = out["be_slowdown"][:, 0]
        assert np.isnan(te_p95[0]) and np.isfinite(be_p50[0])
        assert np.isnan(be_p50[1]) and np.isfinite(te_p95[1])
        assert np.isfinite(te_p95[2]) and np.isfinite(be_p50[2])
        assert np.isnan(out["preempted_frac"][1])
        # pooling: the all-BE trial drops out of the TE aggregate
        pooled = np.nanmean(te_p95)
        assert np.isfinite(pooled)
        assert pooled == pytest.approx(np.nanmean(te_p95[1:]))

    def test_padded_empty_class_matches_unpadded(self):
        """Sentinel padding must not resurrect an empty class: an
        all-BE jobset padded with sentinel rows reports the same nan/
        finite split as its unpadded run."""
        js = one_class_jobset(20, te=False)
        jobs = sim_jax.jobs_from_jobset(js)
        padded = sweep.pad_jobs(jobs, 32)
        a = sim_jax.result_summary(jobs, sim_jax.run_jit(CFG, jobs, 0))
        st_p = sim_jax.run(CFG, padded, seed=0)
        b = sim_jax.result_summary(padded, st_p)
        for grp in ("TE", "BE"):
            for p, v in a[grp].items():
                np.testing.assert_equal(float(v), float(b[grp][p]))


class TestReferenceSummaries:
    @pytest.mark.parametrize("te", [False, True])
    def test_run_experiment_empty_class(self, te):
        js = one_class_jobset(24, te=te)
        with warnings.catch_warnings():
            warnings.simplefilter("error")     # no empty-slice warnings
            r = api.run_experiment(policy="fitgpp", engine="reference",
                                   cfg=CFG, jobs=js)
        empty, full = ("BE", "TE") if te else ("TE", "BE")
        assert all(np.isnan(v) for v in r.table[empty].values())
        assert all(np.isfinite(v) for v in r.table[full].values())
        if te:
            assert np.isnan(r.preempted_frac)

    def test_pooled_tables_empty_class(self):
        res = api.run_experiment(policy="fitgpp", engine="reference",
                                 cfg=CFG,
                                 jobs=one_class_jobset(24, te=True)).raw
        pooled = metrics.pooled_tables(metrics.merge_results([res]))
        assert np.isnan(pooled["preempted_frac"])
        assert all(np.isnan(v) for v in pooled["preempt_counts"].values())
        assert all(np.isnan(v) for v in pooled["BE"].values())
        assert all(np.isfinite(v) for v in pooled["TE"].values())
