"""Host-side units of the benchmark harness (benchmarks/).

The bench package is importable because pytest runs from the repo
root; skip cleanly anywhere it is not on sys.path.
"""
import pytest

bench = pytest.importorskip("benchmarks.sim_engine_bench")


def test_rss_divisor_platform_units():
    """``ru_maxrss`` is kilobytes on Linux but BYTES on macOS — a
    wrong divisor inflates or deflates every max_rss_mb bench row by
    1024x, silently voiding the bounded-memory claim."""
    assert bench._rss_divisor("darwin") == 1 << 20
    assert bench._rss_divisor("linux") == 1 << 10
    assert bench._rss_divisor("linux2") == 1 << 10
    # default resolves the running platform to one of the two units
    assert bench._rss_divisor() in (1 << 10, 1 << 20)


def test_rss_mb_sane():
    """A live python process peaks well above 10MB and (on a test box)
    below a TB — catches unit slips in either direction."""
    mb = bench._rss_mb()
    assert 10.0 < mb < 1 << 20
