"""Cross-engine trace parity (DESIGN.md §8): the canonical event
stream recorded by the reference driver's hooks must equal — event
for event, ``as_tuple()`` exact — the stream decoded from the JAX
engine's in-jit ring buffer, per (scenario x policy x time mode).

The policy axis is GENERATED from the policy registry (the same
``JAX_EXACT`` rule as the result-parity matrix: every dual-backend
policy that is not rng-driven; the score policies' random fallback is
asserted not to fire, so a silently-firing fallback breaks the test
rather than hiding behind it). Registering a new deterministic
dual-backend policy enrolls it here without touching this file.

Also locked down: the ring itself — tick-vs-event bit-parity of the
traced State (the drain jump must emit the bulk-retired FINISH rows
it skips, in the tick-mode order), loud overflow accounting on an
undersized ring with an intact prefix, and tracing-off compiling the
ring OUT (zero-size buffer, not a zeroed one).
"""
import dataclasses

import numpy as np
import pytest

from repro import scenarios
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, policy_registry, sim_jax, simulator
from repro.core.policy_registry import RNG_ALWAYS
from repro.obs import ring, schema

JAX_EXACT = [s.name for s in policy_registry.all_policies()
             if s.dual_backend and s.rng != RNG_ALWAYS]

# gang-heavy + BOTH trace adapters (native job counts, gang widths
# from GPU counts / inst_num) on the paper-default 84-node cluster —
# the same coverage rule as the gang result-parity matrix.
TRACE_SCENARIOS = ("gang-heavy", "philly-sample", "pai-sample")


def _cfg(policy="fitgpp", n_nodes=None, n_jobs=96, seed=0, **kw):
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=n_jobs), policy=policy,
                    seed=seed, **kw)
    if n_nodes is not None:
        cfg = dataclasses.replace(cfg, cluster=ClusterSpec(n_nodes=n_nodes))
    return cfg


def _assert_cross_engine(cfg, js, mode):
    """Reference traced run vs decoded JAX ring: exact event parity,
    schema-valid, no overflow, and — for score policies — no random
    fallback (the one documented exclusion from exact parity)."""
    ref = simulator.simulate(cfg, js, mode=mode, trace=True)
    st = sim_jax.run_jit(cfg, sim_jax.jobs_from_jobset(js), cfg.seed,
                         time_mode=mode, trace=True)
    if policy_registry.get_policy(cfg.policy).jax_kind == "score":
        assert int(st.fallback_count) == 0, \
            "random fallback fired; pick a quieter config"
    events, overflow = sim_jax.decode_trace(st)
    assert overflow == 0
    schema.validate_events(events, n_jobs=js.n,
                           n_nodes=cfg.cluster.n_nodes)
    metrics.assert_trace_parity(ref.trace, events)
    return events


class TestCrossEngineTraceParity:
    """The registry-generated (scenario x policy x mode) matrix."""

    _jobsets = {}

    @classmethod
    def _jobset(cls, scenario):
        if scenario not in cls._jobsets:
            cls._jobsets[scenario] = scenarios.build(scenario, _cfg())
        return cls._jobsets[scenario]

    @pytest.mark.parametrize("mode", ["tick", "event"])
    @pytest.mark.parametrize("policy", JAX_EXACT)
    @pytest.mark.parametrize("scenario", TRACE_SCENARIOS)
    def test_matrix(self, scenario, policy, mode):
        _assert_cross_engine(_cfg(policy), self._jobset(scenario), mode)

    def test_matrix_covers_new_policies(self):
        assert {"fifo", "fitgpp", "lrtp", "srtp", "minsize"} <= \
            set(JAX_EXACT)


class TestPreemptionTraceCoverage:
    """The matrix above runs on an uncontended cluster (few signals);
    these configs saturate 16 nodes so the full preemption vocabulary
    — SIGNAL / GRACE_EXPIRE / VACATE / REQUEUE / RESUME, and BACKFILL
    under backfill — is exercised through BOTH engines and still
    matches exactly."""

    @pytest.mark.parametrize("mode", ["tick", "event"])
    def test_preemption_heavy(self, mode):
        cfg = _cfg("lrtp", n_nodes=16, seed=3)
        js = scenarios.build("gang-heavy", cfg)
        events = _assert_cross_engine(cfg, js, mode)
        codes = {e.code for e in events}
        assert {schema.PREEMPT_SIGNAL, schema.GRACE_EXPIRE,
                schema.VACATE, schema.REQUEUE, schema.RESUME} <= codes

    @pytest.mark.parametrize("mode", ["tick", "event"])
    def test_backfill_markers(self, mode):
        cfg = _cfg("lrtp", n_nodes=16, seed=3, backfill=True)
        js = scenarios.build("gang-heavy", cfg)
        events = _assert_cross_engine(cfg, js, mode)
        skips = [e.aux for e in events if e.code == schema.BACKFILL]
        assert skips and all(s > 0 for s in skips)


class TestRingBuffer:
    """Mechanics of the in-jit ring itself."""

    def _traced_states(self, **kw):
        cfg = _cfg("lrtp", n_nodes=16, seed=3, **kw)
        js = scenarios.build("gang-heavy", cfg)
        jobs = sim_jax.jobs_from_jobset(js)
        return cfg, js, jobs

    def test_tick_vs_event_ring_bitwise(self):
        """The drain jump's bulk FINISH emission reproduces the
        tick-mode stream ORDER, not just the set: the whole traced
        State — ring buffer rows included — is bit-identical across
        time modes."""
        cfg, _, jobs = self._traced_states()
        a = sim_jax.run_jit(cfg, jobs, 3, time_mode="tick", trace=True)
        b = sim_jax.run_jit(cfg, jobs, 3, time_mode="event", trace=True)
        assert not sim_jax.state_diff_fields(a, b)

    def test_overflow_counted_with_intact_prefix(self):
        """An undersized ring drops the tail LOUDLY — overflow is the
        exact number of rows lost — and the surviving prefix is the
        first ``capacity`` events of the untruncated stream, bit
        exact (the dump row never leaks into the decode)."""
        cfg, js, jobs = self._traced_states()
        full = sim_jax.run_jit(cfg, jobs, 3, trace=True)
        events, overflow = sim_jax.decode_trace(full)
        assert overflow == 0
        cap = 32
        small = sim_jax.run_jit(cfg, jobs, 3, trace=True,
                                trace_capacity=cap)
        got, lost = sim_jax.decode_trace(small)
        assert lost == len(events) - cap > 0
        assert int(sim_jax.trace_overflow(small)) == lost
        metrics.assert_trace_parity(events[:cap], got)

    def test_untraced_ring_compiled_out(self):
        """trace=False is structurally zero-cost: the State carries a
        ZERO-SIZE buffer (no ring, no appends in the compiled step),
        and the summary reports overflow 0."""
        cfg, _, jobs = self._traced_states()
        st = sim_jax.run_jit(cfg, jobs, 3)
        assert st.ev_buf.size == 0
        assert int(sim_jax.trace_overflow(st)) == 0
        events, overflow = sim_jax.decode_trace(st)
        assert events == [] and overflow == 0
        assert int(sim_jax.result_summary(jobs, st)["trace_overflow"]) == 0

    def test_default_capacity_fits_saturated_run(self):
        """The auto-sized ring (``obs.ring.default_capacity``) holds a
        preemption-heavy run without overflow."""
        cfg, js, jobs = self._traced_states()
        cap = sim_jax.resolve_trace_capacity(cfg, jobs)
        assert cap >= ring.default_capacity(js.n)
        st = sim_jax.run_jit(cfg, jobs, 3, trace=True)
        assert int(sim_jax.trace_overflow(st)) == 0

    def test_traced_untraced_same_result(self):
        """Tracing must observe, not perturb: the non-ring State
        fields are bit-identical with tracing on and off."""
        cfg, _, jobs = self._traced_states()
        a = sim_jax.run_jit(cfg, jobs, 3)
        b = sim_jax.run_jit(cfg, jobs, 3, trace=True)
        diff = sim_jax.state_diff_fields(
            a._replace(ev_buf=b.ev_buf, ev_n=b.ev_n), b)
        assert not diff
        np.testing.assert_array_equal(np.asarray(a.finish),
                                      np.asarray(b.finish))
