"""Scenario & trace subsystem: registry, adapters, parity, ragged sweeps."""
import dataclasses
import io
from contextlib import redirect_stdout

import numpy as np
import pytest

from repro import scenarios
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, sim_jax, simulator, sweep
from repro.core.types import JobSet
from repro.scenarios.traces import (PAI_SAMPLE, PHILLY_SAMPLE, load_pai_csv,
                                    load_philly_csv)


def small_cfg(n_jobs=96, n_nodes=8, policy="fitgpp", seed=0, **kw):
    return SimConfig(cluster=ClusterSpec(n_nodes=n_nodes),
                     workload=WorkloadSpec(n_jobs=n_jobs, **kw),
                     policy=policy, seed=seed)


NEW_SCENARIOS = ("diurnal", "burst-storm", "gang-heavy", "gang-trace-mix",
                 "load-ramp", "te-flood", "long-tail-be",
                 "maintenance-drain", "heterogeneous-gp")
PAPER_SCENARIOS = ("paper-synthetic", "trace-proxy", "sparse-long-horizon")
TRACE_SCENARIOS = ("philly-sample", "pai-sample",
                   "philly-tiled", "pai-tiled")


class TestRegistry:
    def test_catalog(self):
        """Acceptance: >= 8 scenarios beyond the paper's, the paper's
        three generators re-registered, and two trace adapters."""
        syn = scenarios.scenario_names(scenarios.SYNTHETIC)
        tr = scenarios.scenario_names(scenarios.TRACE)
        for name in NEW_SCENARIOS + PAPER_SCENARIOS:
            assert name in syn
        for name in TRACE_SCENARIOS:
            assert name in tr
        assert len(set(NEW_SCENARIOS)) >= 8 and len(tr) >= 2

    def test_metadata(self):
        for sc in scenarios.all_scenarios():
            assert sc.description, sc.name
            assert sc.kind in (scenarios.SYNTHETIC, scenarios.TRACE)
            assert all(k and v for k, v in sc.knobs), sc.name

    def test_unknown_name(self):
        with pytest.raises(KeyError, match="registered:"):
            scenarios.get_scenario("nope")

    def test_duplicate_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            scenarios.register_scenario("diurnal")(lambda cfg: None)

    def test_description_required(self):
        with pytest.raises(ValueError, match="description"):
            scenarios.register_scenario("undocumented")(lambda cfg: None)
        assert "undocumented" not in scenarios.scenario_names()

    def test_cli_list(self):
        from repro.scenarios.__main__ import main
        buf = io.StringIO()
        with redirect_stdout(buf):
            main(["list"])
        out = buf.getvalue()
        for name in NEW_SCENARIOS + PAPER_SCENARIOS + TRACE_SCENARIOS:
            assert name in out
        assert f"{len(TRACE_SCENARIOS)} trace adapters" in out


class TestScenarioRuns:
    @pytest.mark.parametrize("name", NEW_SCENARIOS + PAPER_SCENARIOS
                             + TRACE_SCENARIOS)
    def test_tick_event_parity(self, name):
        """Acceptance: every registered scenario runs through BOTH time
        advancement modes of the reference engine bit-identically."""
        cfg = small_cfg()
        js = scenarios.build(name, cfg)            # build() validates
        res_tick = simulator.simulate(cfg, js, mode="tick")
        res_event = simulator.simulate(cfg, js, mode="event")
        metrics.assert_result_parity(res_tick, res_event)
        assert (res_tick.finish > 0).all()
        assert (res_tick.slowdown >= 1 - 1e-9).all()

    @pytest.mark.parametrize("name", NEW_SCENARIOS)
    def test_deterministic_and_scaled(self, name):
        cfg = small_cfg(n_jobs=64)
        a, b = scenarios.build(name, cfg), scenarios.build(name, cfg)
        np.testing.assert_array_equal(a.submit, b.submit)
        np.testing.assert_array_equal(a.demand, b.demand)
        assert a.n == 64
        c = scenarios.build(name, dataclasses.replace(cfg, seed=1))
        assert not (np.array_equal(a.submit, c.submit)
                    and np.array_equal(a.exec_total, c.exec_total))


class TestTraceAdapters:
    def test_philly_semantics(self):
        cfg = small_cfg()
        js, stats = load_philly_csv(PHILLY_SAMPLE, cfg, return_stats=True)
        assert (stats.n_rows, stats.n_jobs) == (28, 26)
        assert stats.n_malformed == 1          # empty end_time
        assert stats.n_zero_runtime == 1       # end == start
        # 16-GPU jobs split into 2 x 8-GPU gang instances
        gang = np.asarray(js.n_nodes) > 1
        assert gang.sum() == 2
        assert (js.n_nodes[gang] == 2).all()
        assert (js.demand[gang, 2] == 8.0).all()
        # TE/BE by runtime threshold; demand snapped + clipped
        np.testing.assert_array_equal(js.is_te, js.exec_total <= 30)
        assert set(np.unique(js.demand[:, 2])) <= set(
            cfg.workload.gpu_quanta)
        assert js.submit[0] == 0 and (np.diff(js.submit) >= 0).all()

    def test_philly_threshold_knob(self):
        cfg = small_cfg()
        strict = load_philly_csv(PHILLY_SAMPLE, cfg, te_runtime_min=5.0)
        loose = load_philly_csv(PHILLY_SAMPLE, cfg, te_runtime_min=120.0)
        assert strict.is_te.sum() < loose.is_te.sum()
        np.testing.assert_array_equal(strict.is_te,
                                      strict.exec_total <= 5)

    def test_pai_semantics(self):
        cfg = small_cfg()
        js, stats = load_pai_csv(PAI_SAMPLE, cfg, return_stats=True)
        assert (stats.n_rows, stats.n_jobs) == (30, 28)
        assert stats.n_malformed == 1          # empty plan_cpu
        assert stats.n_zero_runtime == 1       # end < start
        # earliest row (j_001): plan_cpu 600 -> 6 cores, 29 GB, 1 GPU
        np.testing.assert_array_equal(js.demand[0], [6.0, 29.0, 1.0])
        # inst_num gangs survive intact
        assert int(np.asarray(js.n_nodes).max()) == 8
        assert (np.asarray(js.n_nodes) > 1).sum() == 9

    def test_pai_too_wide_dropped(self):
        cfg = small_cfg(n_nodes=4)
        js, stats = load_pai_csv(PAI_SAMPLE, cfg, return_stats=True)
        assert stats.n_too_wide == 1           # the 8-instance gang
        assert int(np.asarray(js.n_nodes).max()) <= 4

    def test_empty_after_filtering_raises(self):
        with pytest.raises(ValueError, match="no usable jobs"):
            load_philly_csv(PHILLY_SAMPLE, small_cfg(),
                            statuses=("NoSuchStatus",))

    def test_timezone_aware_timestamps(self):
        from repro.scenarios.traces import _parse_ts
        assert _parse_ts("2017-10-03 08:00:00+08:00") == \
            _parse_ts("2017-10-03 00:00:00")
        assert _parse_ts("1588000000") == 1588000000.0


class TestRaggedBatching:
    def test_equal_n_fast_path(self):
        cfg = small_cfg(n_jobs=32)
        js = [scenarios.build("te-flood", dataclasses.replace(cfg, seed=s))
              for s in (0, 1)]
        stacked = sweep.stack_jobsets(js)
        assert stacked.submit.shape == (2, 32)
        assert bool(np.asarray(stacked.valid).all())

    def test_ragged_stack_regression(self):
        """stack_jobsets used to raise on unequal n; now it pads."""
        a = scenarios.build("te-flood", small_cfg(n_jobs=12))
        b = scenarios.build("te-flood", small_cfg(n_jobs=20))
        stacked = sweep.stack_jobsets([a, b])
        assert stacked.submit.shape == (2, 20)
        valid = np.asarray(stacked.valid)
        assert valid[0].sum() == 12 and valid[1].all()
        assert (np.asarray(stacked.demand)[0, 12:] == 0).all()

    def test_padding_is_bit_exact(self):
        """Sentinel contract: a padded trial reproduces the unpadded
        run exactly — finishes, preemptions and makespan."""
        cfg = small_cfg(n_jobs=48)
        js = scenarios.build("burst-storm", cfg)
        jobs = sim_jax.jobs_from_jobset(js)
        padded = sweep.pad_jobs(jobs, js.n + 13)
        st0 = sim_jax.run(cfg, jobs, seed=0)
        st1 = sim_jax.run(cfg, padded, seed=0)
        np.testing.assert_array_equal(np.asarray(st0.finish),
                                      np.asarray(st1.finish[:js.n]))
        np.testing.assert_array_equal(
            np.asarray(st0.preempt_count),
            np.asarray(st1.preempt_count[:js.n]))
        assert int(st0.t) == int(st1.t)
        # sentinels never ran
        assert (np.asarray(st1.finish[js.n:]) == -1).all()
        assert (np.asarray(st1.preempt_count[js.n:]) == 0).all()

    def test_ragged_scenario_sweep(self):
        """Acceptance: one ragged multi-scenario sweep through
        sweep.run on CPU (different job counts per scenario)."""
        out = sweep.scenario_sweep(
            small_cfg(n_jobs=48), ["te-flood", "long-tail-be"],
            seeds=[0, 1])
        assert out["te_slowdown"].shape == (2, 2, 3)
        assert np.isfinite(out["te_slowdown"]).all()
        assert np.isfinite(out["be_slowdown"]).all()
        assert (out["makespan"] > 0).all()

    def test_ragged_sweep_via_public_run(self):
        """A single-node trace slice (the Philly fixture minus its
        gangs) padded against a synthetic scenario, straight through
        the public ``sweep.run`` entry point."""
        cfg = small_cfg(n_jobs=40)
        tr = scenarios.build("philly-sample", cfg)
        single = np.asarray(tr.n_nodes) == 1
        tr = JobSet(submit=tr.submit[single], exec_total=tr.exec_total[single],
                    demand=tr.demand[single], is_te=tr.is_te[single],
                    gp=tr.gp[single], n_nodes=tr.n_nodes[single])
        syn = scenarios.build("te-flood", cfg)
        stacked = sweep.stack_jobsets([tr, syn])
        assert stacked.submit.shape == (2, 40)
        out = sweep.run(cfg, stacked, s_vals=[4.0, 4.0], P_vals=[1, 1],
                        seeds=[0, 0])
        assert np.isfinite(out["te_slowdown"]).all()

    def test_gang_scenarios_sweep_on_jax(self):
        """Gang scenarios run through the vmapped JAX sweep (they used
        to raise NotImplementedError): widths ride the batch."""
        out = sweep.scenario_sweep(small_cfg(n_jobs=32),
                                   ["gang-heavy", "gang-trace-mix"],
                                   seeds=[0])
        assert out["te_slowdown"].shape == (2, 1, 3)
        assert (out["makespan"] > 0).all()

    def test_ragged_gang_batch_bit_exact(self):
        """Regression (stack_jobsets width carry): a RAGGED gang batch
        — unequal n, multi-node widths — padded into one vmapped sweep
        is bit-identical to each jobset's unpadded single run. Before
        Jobs.width existed, padding silently dropped gang widths."""
        cfg = small_cfg(n_jobs=24)
        jobsets = [scenarios.build("gang-trace-mix",
                                   dataclasses.replace(
                                       cfg, seed=s,
                                       workload=WorkloadSpec(n_jobs=n)))
                   for s, n in ((0, 16), (1, 24))]
        assert any((np.asarray(js.n_nodes) > 1).any() for js in jobsets)
        stacked = sweep.stack_jobsets(jobsets)
        # widths survived the ragged padding; sentinels stay width-1
        w0 = np.asarray(stacked.width)
        assert (w0[0, 16:] == 1).all()
        np.testing.assert_array_equal(w0[0, :16],
                                      np.asarray(jobsets[0].n_nodes))
        batched = sweep.run_sweep(cfg, stacked, s_vals=[cfg.s] * 2,
                                  P_vals=[1, 1], seeds=[0, 0])
        for i, js in enumerate(jobsets):
            st = sim_jax.run_jit(cfg, sim_jax.jobs_from_jobset(js), 0)
            single = sim_jax.result_summary(sim_jax.jobs_from_jobset(js),
                                            st)
            np.testing.assert_array_equal(
                batched["makespan"][i], int(st.t))
            for p, key in zip((50, 95, 99), range(3)):
                a = batched["te_slowdown"][i][key]
                b = float(single["TE"][f"p{p}"])
                np.testing.assert_equal(a, np.float32(b))
