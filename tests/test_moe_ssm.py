"""MoE routing and Mamba-2 SSD correctness tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import MoEConfig
from repro.models import moe, ssm


class TestMoE:
    def _cfg(self, **kw):
        return get_smoke_config("qwen3-moe-30b-a3b").replace(
            dtype="float32", **kw)

    def test_scatter_matches_einsum_oracle(self):
        cfg = self._cfg()
        params = moe.init(cfg, jax.random.key(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.3
        y1, a1 = moe.moe_block(cfg, lp, x)
        y2, a2 = moe.moe_block_einsum(cfg, lp, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
        assert np.isclose(float(a1), float(a2))

    def test_capacity_drops_tokens(self):
        """With a tiny capacity factor some tokens must be dropped (their
        MoE output is zero) but the model still runs."""
        cfg = self._cfg(moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                                      capacity_factor=0.1,
                                      router_aux_weight=0.0))
        params = moe.init(cfg, jax.random.key(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.key(1), (1, 64, cfg.d_model)) * 0.3
        y, _ = moe.moe_block(cfg, lp, x)
        norms = jnp.linalg.norm(y[0], axis=-1)
        assert float((norms < 1e-7).sum()) > 0          # dropped tokens
        assert float((norms > 1e-7).sum()) > 0          # routed tokens

    def test_aux_loss_uniform_router(self):
        """A uniform router gives the minimal load-balance loss ~= 1."""
        cfg = self._cfg()
        params = moe.init(cfg, jax.random.key(0))
        lp = dict(jax.tree.map(lambda a: a[0], params["layers"]))
        lp["w_router"] = jnp.zeros_like(lp["w_router"])   # uniform probs
        x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
        _, aux = moe.moe_block(cfg, lp, x)
        assert 0.9 < float(aux) < 1.3

    def test_capacity_multiple_of_4(self):
        cfg = self._cfg()
        assert moe.capacity(cfg, 16) % 4 == 0


class TestSSD:
    def test_chunked_matches_sequential(self):
        B, L, H, P, G, N = 2, 64, 4, 16, 1, 8
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        xdt = jax.random.normal(ks[0], (B, L, H, P)) * 0.3
        loga = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        Bm = jax.random.normal(ks[2], (B, L, G, N)) * 0.3
        Cm = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        y, fin = ssm.ssd_scan(xdt, loga, Bm, Cm, chunk=16)
        state = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(L):
            a = jnp.exp(loga[:, t])
            state = state * a[..., None, None] + jnp.einsum(
                "bhp,bhn->bhpn", xdt[:, t], jnp.repeat(Bm[:, t], H // G, 1))
            ys.append(jnp.einsum("bhpn,bhn->bhp", state,
                                 jnp.repeat(Cm[:, t], H // G, 1)))
        np.testing.assert_allclose(np.asarray(y), np.asarray(
            jnp.stack(ys, 1)), atol=1e-5)
        np.testing.assert_allclose(np.asarray(fin), np.asarray(state),
                                   atol=1e-5)

    def test_initial_state_continuation(self):
        """Splitting a sequence across two ssd_scan calls must agree."""
        B, L, H, P, G, N = 1, 64, 2, 8, 1, 4
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        xdt = jax.random.normal(ks[0], (B, L, H, P)) * 0.3
        loga = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        Bm = jax.random.normal(ks[2], (B, L, G, N)) * 0.3
        Cm = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        y_full, fin_full = ssm.ssd_scan(xdt, loga, Bm, Cm, chunk=16)
        y1, s1 = ssm.ssd_scan(xdt[:, :32], loga[:, :32], Bm[:, :32],
                              Cm[:, :32], chunk=16)
        y2, s2 = ssm.ssd_scan(xdt[:, 32:], loga[:, 32:], Bm[:, 32:],
                              Cm[:, 32:], chunk=16, init_state=s1)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
            atol=1e-5)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(fin_full),
                                   atol=1e-5)

    def test_ssd_chunk_kernel_oracle(self):
        """ref.ssd_chunk_ref (the kernel oracle) == ssd_scan single chunk."""
        from repro.kernels import ref as kref
        B, Q, H, P, N = 2, 32, 4, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(4), 4)
        xdt = jax.random.normal(ks[0], (B, Q, H, P)) * 0.3
        loga = -jax.nn.softplus(jax.random.normal(ks[1], (B, Q, H)))
        Bm = jax.random.normal(ks[2], (B, Q, H, N)) * 0.3
        Cm = jax.random.normal(ks[3], (B, Q, H, N)) * 0.3
        y_ref = kref.ssd_chunk_ref(xdt, loga, Bm, Cm)
        # ssd_scan with G == H (one group per head), single chunk
        y_scan, _ = ssm.ssd_scan(xdt, loga, Bm, Cm, chunk=Q)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_scan),
                                   atol=1e-5)

    def test_conv_state_roundtrip(self):
        B, L, C, W = 2, 16, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        x = jax.random.normal(ks[0], (B, L, C))
        w = jax.random.normal(ks[1], (W, C))
        y_full, final = ssm.causal_conv(x, w)
        # stepwise
        state = jnp.zeros((B, W - 1, C))
        ys = []
        for t in range(L):
            yt, state = ssm.conv_step(x[:, t], w, state)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                                   np.asarray(y_full), atol=1e-5)
        np.testing.assert_allclose(np.asarray(state), np.asarray(final),
                                   atol=1e-6)
