"""Fig. 4 sensitivity sweep on the JAX simulation engine.

Every (s, seed) trial is an independent pure-JAX simulation
(lax.while_loop), so the sweep vmaps and — on a real mesh — shards over
the ``data`` axis (core/sweep.py). On this CPU container it runs on the
1-device local mesh; on a pod the same code spreads 256 trials across
256 chips.

Run:  PYTHONPATH=src python examples/distributed_sweep.py
"""
import numpy as np

from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import sweep
from repro.launch.mesh import make_local_mesh


def main():
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=1024, gp_scale=2.0),
                    policy="fitgpp", max_preemptions=1)
    s_vals = [0.0, 1.0, 2.0, 4.0, 8.0]
    seeds = [0, 1]
    mesh = make_local_mesh()
    out = sweep.sensitivity_grid(cfg, 1024, s_vals, seeds, mesh=mesh)

    print("Fig. 4 — FitGpp sensitivity to s (GP weight), gp_scale=2.0")
    print(f"{'s':>5s} | {'TE p95':>8s} {'TE p99':>8s} | {'BE p50':>8s} "
          f"| {'interval p50':>12s}")
    for i, s in enumerate(s_vals):
        te95 = np.nanmean(out["te_slowdown"][i, :, 1])
        te99 = np.nanmean(out["te_slowdown"][i, :, 2])
        be50 = np.nanmean(out["be_slowdown"][i, :, 0])
        iv50 = np.nanmean(out["intervals"][i, :, 0])
        print(f"{s:5.1f} | {te95:8.2f} {te99:8.2f} | {be50:8.2f} "
              f"| {iv50:12.1f}")
    print("\npaper Fig. 4: TE slowdown falls with s and saturates by "
          "s in [4, 8]; BE slowdown is s-independent.")


if __name__ == "__main__":
    main()
