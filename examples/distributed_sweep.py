"""Fig. 4 sensitivity sweep on the device-parallel sweep fabric.

Every (s, seed) trial is an independent pure-JAX simulation
(lax.while_loop), so the whole grid flattens into ONE trial table that
the fabric ``shard_map``s over the local device mesh (DESIGN.md §11).
On this CPU container that is the single-device vmap; under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or on a real
multi-chip host) the same call spreads the trials across every device,
bit-identically.

Run:  PYTHONPATH=src python examples/distributed_sweep.py
"""
import jax
import numpy as np

from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import sweep
from repro.launch.mesh import mesh_for_sweep


def main():
    cfg = SimConfig(workload=WorkloadSpec(n_jobs=1024, gp_scale=2.0),
                    policy="fitgpp", max_preemptions=1)
    s_vals = [0.0, 1.0, 2.0, 4.0, 8.0]
    seeds = [0, 1]
    n_trials = len(s_vals) * len(seeds)
    mesh = mesh_for_sweep(n_trials)          # None => single-device vmap
    n_dev = 1 if mesh is None else mesh.devices.size
    out = sweep.sensitivity_grid(cfg, 1024, s_vals, seeds, mesh=mesh)

    print(f"Fig. 4 — FitGpp sensitivity to s (GP weight), gp_scale=2.0 "
          f"({n_trials} trials on {n_dev}/{len(jax.devices())} devices)")
    print(f"{'s':>5s} | {'TE p95':>8s} {'TE p99':>8s} | {'BE p50':>8s} "
          f"| {'interval p50':>12s}")
    for i, s in enumerate(s_vals):
        te95 = np.nanmean(out["te_slowdown"][i, :, 1])
        te99 = np.nanmean(out["te_slowdown"][i, :, 2])
        be50 = np.nanmean(out["be_slowdown"][i, :, 0])
        iv50 = np.nanmean(out["intervals"][i, :, 0])
        print(f"{s:5.1f} | {te95:8.2f} {te99:8.2f} | {be50:8.2f} "
              f"| {iv50:12.1f}")
    print("\npaper Fig. 4: TE slowdown falls with s and saturates by "
          "s in [4, 8]; BE slowdown is s-independent.")


if __name__ == "__main__":
    main()
