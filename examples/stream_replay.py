"""Bounded-memory replay of a long trace through the streaming
macro-round engine (DESIGN.md §10).

The monolithic engines hold every job of a workload in memory; this
example replays an arbitrarily long stream through
``core.stream.StreamEngine`` instead — a fixed pool of ``--capacity``
slots recycled as jobs finish, fed chunk by chunk from a
``JobSource``, with per-round event/result draining. Memory scales
with the pool, not the trace: the RSS printed at the end is flat in
``--n-jobs``.

Three source flavors, all submit-ordered chunk iterators:

* ``synthetic`` — ``workload.stream_chunks``, the open-loop chunked
  generator (default; scale ``--n-jobs`` freely, 10^5+ is fine);
* ``philly`` / ``pai`` — a bundled sample fixture tiled end-to-end to
  ``--n-jobs`` (``scenarios.traces.tiled_source``), or point
  ``--csv`` at a real Philly/PAI-style export to stream it row by
  row without ever materializing the full trace.

``--trace out.csv`` attaches an incremental ``CsvTraceWriter`` sink:
the canonical event stream lands on disk round by round in O(batch)
memory. ``--parity`` first checks the §10 bit-parity window
(streamed == monolithic on a small prefix) before the long replay.

Run:  PYTHONPATH=src python examples/stream_replay.py
      PYTHONPATH=src python examples/stream_replay.py \
          --n-jobs 100000 --capacity 2048 --parity
      PYTHONPATH=src python examples/stream_replay.py \
          --source philly --n-jobs 5000 --trace stream.csv
"""
import argparse
import dataclasses
import resource
import time

from repro import api
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics, stream, workload
from repro.obs import export
from repro.scenarios import traces


def make_source(args, cfg):
    if args.source == "synthetic":
        return stream.JobSource(
            workload.stream_chunks(cfg, args.n_jobs, chunk=args.chunk))
    dialect = args.source
    path = args.csv or {"philly": traces.PHILLY_SAMPLE,
                        "pai": traces.PAI_SAMPLE}[dialect]
    if args.csv:
        # a real export: one streaming pass, never materialized
        return traces.trace_source(path, cfg, dialect, chunk=args.chunk)
    # bundled ~26-job fixture: tile it end-to-end up to n_jobs
    return traces.tiled_source(path, cfg, dialect)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--source", default="synthetic",
                    choices=("synthetic", "philly", "pai"))
    ap.add_argument("--csv", default=None,
                    help="real trace CSV to stream (with --source "
                         "philly|pai); default: tiled bundled fixture")
    ap.add_argument("--policy", default="fitgpp",
                    choices=api.policy_names())
    ap.add_argument("--n-jobs", type=int, default=20000)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=None,
                    help="slot-pool size (default 32 x nodes x P)")
    ap.add_argument("--load", type=float, default=0.5,
                    help="open-loop load for the synthetic stream "
                         "(keep < ~0.9: the backlog must fit the pool)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="stream the canonical event CSV to PATH "
                         "round by round (incremental sink)")
    ap.add_argument("--parity", action="store_true",
                    help="check the bit-parity window (streamed == "
                         "monolithic prefix) before replaying")
    args = ap.parse_args()

    cfg = SimConfig(cluster=ClusterSpec(n_nodes=args.nodes),
                    workload=WorkloadSpec(n_jobs=args.n_jobs),
                    policy=args.policy, seed=args.seed)
    cfg = dataclasses.replace(
        cfg, workload=dataclasses.replace(cfg.workload, load=args.load))

    if args.parity:
        diff = stream.verify_prefix_parity(cfg, n_jobs=400,
                                           capacity=96, chunk=64)
        assert diff == [], f"parity window diverged in {diff}"
        print("parity window ok: 400-job streamed prefix bit-identical "
              "to the monolithic engine")

    sink = export.CsvTraceWriter(args.trace) if args.trace else None
    eng = stream.StreamEngine(cfg, make_source(args, cfg),
                              capacity=args.capacity,
                              trace=sink is not None,
                              event_sink=sink.write if sink else None)
    t0 = time.perf_counter()
    res = eng.run()
    dt = time.perf_counter() - t0
    if sink:
        sink.close()
        print(f"{sink.n_written} events -> {args.trace} (incremental, "
              f"overflow={res.trace_overflow})")

    print(f"\n{res.n_jobs} jobs through {res.capacity} slots in "
          f"{res.rounds} rounds (peak live {res.max_live}) — "
          f"{dt:.1f}s, {res.n_jobs / dt:.0f} jobs/s")
    s = res.summary()
    print(metrics.format_table(
        {args.policy: {"TE": s["TE"], "BE": s["BE"]}},
        f"slowdown percentiles (makespan {res.makespan} min)"))
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print(f"peak RSS {rss:.0f} MB — rerun with a different --n-jobs at "
          "the same --capacity to see it stay flat")


if __name__ == "__main__":
    main()
