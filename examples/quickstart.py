"""Quickstart: the three layers of this repo in ~60 seconds.

1. The paper (FitGpp): simulate a cluster and see TE latency collapse.
2. The substrate: one real train step for an assigned architecture.
3. The mechanism: preempt a live training job with a grace period and
   resume it bit-exactly from its checkpoint.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro import api, trainer
from repro.configs import get_smoke_config
from repro.core import metrics
from repro.core.controller import Controller, JobSpec
from repro.data import make_batch
from repro.optim import AdamWConfig


def part1_scheduler():
    print("=" * 64)
    print("1) FitGpp vs FIFO on a synthetic workload (paper Table 1)")
    # One facade call per (scenario, policy, engine) triple; both runs
    # share the same generated jobset (compare_policies builds it once).
    results = api.compare_policies(("fifo", "fitgpp"), n_jobs=2048)
    rows = {name: r.table for name, r in results.items()}
    print(metrics.format_table(rows))
    drop = 1 - rows["fitgpp"]["TE"]["p95"] / rows["fifo"]["TE"]["p95"]
    print(f"-> TE p95 slowdown cut by {drop * 100:.1f}% "
          f"(paper: 96.6%)\n")


def part2_train_step():
    print("=" * 64)
    print("2) Real train steps on a reduced mixtral (MoE) config")
    cfg = get_smoke_config("mixtral-8x22b")
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    state = trainer.init_train_state(cfg, ocfg, jax.random.key(0))
    step = jax.jit(trainer.make_train_step(cfg, ocfg))
    for i in range(10):
        state, m = step(state, make_batch(cfg, 4, 64, seed=0, step=i))
        if i % 3 == 0:
            print(f"   step {i}: loss {float(m['loss']):.4f}")
    print()


def part3_preemption():
    print("=" * 64)
    print("3) Preempt a live job (grace period -> checkpoint -> resume)")
    cfg = get_smoke_config("mamba2-1.3b")
    ctl = Controller(n_nodes=1, node_cap=(32., 256., 8.), policy="fitgpp",
                     steps_per_tick=2, workdir=tempfile.mkdtemp())
    be = ctl.submit(JobSpec("train-be", cfg, False,
                            np.array([8., 32., 8.]), total_steps=16))
    ctl.submit(JobSpec("debug-te", cfg, True, np.array([4., 16., 8.]),
                       total_steps=2, submit_tick=2))
    ctl.run()
    for e in ctl.events:
        print(f"   t={e['t']:2d}  {e['ev']:8s} {e['job']}"
              + (f" (gp={e['gp']})" if "gp" in e else ""))
    print(f"-> BE job preempted {be.preempt_count}x, finished with a "
          f"continuous loss curve ({len(be.losses)} steps).")


if __name__ == "__main__":
    part1_scheduler()
    part2_train_step()
    part3_preemption()
