"""Reproduce the paper's Table 1 / 2 / 3 (reduced scale by default).

Run:  PYTHONPATH=src python examples/cluster_simulation.py [--jobs 8192]
      add --full for paper scale (2^16 jobs, slow).
"""
import argparse
import dataclasses

from repro.configs.cluster import SimConfig, WorkloadSpec
from repro.core import metrics, simulator, workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=4096)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--workloads", type=int, default=2)
    args = ap.parse_args()
    n = 2 ** 16 if args.full else args.jobs

    cfg = SimConfig(workload=WorkloadSpec(n_jobs=n), s=4.0,
                    max_preemptions=1)
    jobsets = [workload.generate(cfg, seed=1000 * i)
               for i in range(args.workloads)]

    rows, pooled = {}, {}
    for pol in ("fifo", "lrtp", "rand", "fitgpp"):
        results = [simulator.simulate(
            dataclasses.replace(cfg, policy=pol), js) for js in jobsets]
        p = metrics.pooled_tables(metrics.merge_results(results))
        rows[pol] = {"TE": p["TE"], "BE": p["BE"]}
        pooled[pol] = p

    print(metrics.format_table(rows, f"Table 1 — slowdown percentiles "
                                     f"({n} jobs x {args.workloads})"))
    print("\nTable 2 — preemption->reschedule intervals [min]")
    for pol in ("lrtp", "rand", "fitgpp"):
        iv = pooled[pol]["intervals"]
        print(f"  {pol:8s} p50={iv['p50']:.1f} p75={iv['p75']:.1f} "
              f"p95={iv['p95']:.1f} p99={iv['p99']:.1f}")
    print("\nTable 3 — proportion of preempted jobs (P=1)")
    for pol in ("lrtp", "rand", "fitgpp"):
        print(f"  {pol:8s} {pooled[pol]['preempted_frac'] * 100:6.2f}%")
    print("\npaper claims: FitGpp cuts TE p95 by 96.6% vs FIFO, halves the")
    print("re-scheduling intervals, and preempts ~15x fewer jobs than LRTP.")


if __name__ == "__main__":
    main()
