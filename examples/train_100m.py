"""End-to-end training driver: a ~100M-parameter dense LM for a few
hundred steps on whatever devices exist (CPU here; the same code path
runs under the pod mesh via repro.launch.train / dryrun).

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
      (about 100M params; expect a few hundred ms/step on CPU)
"""
import argparse
import time

import jax

from repro import models, trainer
from repro.configs import get_config
from repro.data import make_batch
from repro.optim import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: stablelm family scaled down (same code path as 12B)
    cfg = get_config("stablelm-12b").replace(
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=32_000, dtype="float32", remat="none")
    n = models.count_params(cfg)
    print(f"model: {cfg.name}-100m  params={n / 1e6:.1f}M  "
          f"devices={jax.device_count()}")

    ocfg = AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10,
                       total_steps=args.steps)
    state = trainer.init_train_state(cfg, ocfg, jax.random.key(0))
    step = jax.jit(trainer.make_train_step(cfg, ocfg), donate_argnums=(0,))

    t0 = time.time()
    first = None
    for i in range(args.steps):
        batch = make_batch(cfg, args.batch, args.seq_len, 0, i)
        state, m = step(state, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            dt = (time.time() - t0) / (i + 1)
            print(f"step {i:4d}  loss {loss:.4f}  ({dt * 1e3:.0f} ms/step)")
    print(f"loss: {first:.3f} -> {loss:.3f} over {args.steps} steps")
    assert loss < first, "training must make progress"


if __name__ == "__main__":
    main()
