"""End-to-end preemptible-training driver (the paper's mechanism, live).

A 2-node mini-cluster runs REAL training jobs for several assigned
architectures as best-effort work; short trial-and-error jobs arrive and
FitGpp preempts the victim whose (size, grace-period) score is lowest —
grace periods estimated from each job's true checkpoint size. Victims
flush their train state through repro.checkpoint and later resume with
bit-exact loss curves.

Run:  PYTHONPATH=src python examples/preemptible_training.py
"""
import tempfile

import numpy as np

from repro.configs import get_smoke_config
from repro.core.controller import Controller, JobSpec


def main():
    ctl = Controller(n_nodes=2, node_cap=(32., 256., 8.), policy="fitgpp",
                     s=4.0, steps_per_tick=2,
                     workdir=tempfile.mkdtemp(prefix="repro_ctl_"))

    # Best-effort training fleet: three different architecture families.
    ctl.submit(JobSpec("be-mamba", get_smoke_config("mamba2-1.3b"),
                       False, np.array([8., 64., 8.]), total_steps=30))
    ctl.submit(JobSpec("be-moe", get_smoke_config("qwen3-moe-30b-a3b"),
                       False, np.array([8., 64., 8.]), total_steps=30))
    # Trial-and-error jobs arrive while the cluster is full.
    ctl.submit(JobSpec("te-debug-1", get_smoke_config("stablelm-12b"),
                       True, np.array([4., 16., 8.]), total_steps=3,
                       submit_tick=2))
    ctl.submit(JobSpec("te-debug-2", get_smoke_config("internvl2-2b"),
                       True, np.array([4., 16., 4.]), total_steps=3,
                       submit_tick=6))
    ctl.run()

    print("event log:")
    for e in ctl.events:
        extra = f" for {e['for']}" if "for" in e else ""
        extra += f" (gp={e['gp']})" if "gp" in e else ""
        print(f"  t={e['t']:3d} {e['ev']:8s} {e['job']}{extra}")
    print("\nper-job outcome:")
    for job in ctl.jobs:
        kind = "TE" if job.spec.is_te else "BE"
        print(f"  {job.spec.name:12s} [{kind}] steps={job.steps_done:3d} "
              f"preempted={job.preempt_count} slowdown="
              f"{ctl.slowdown(job):.2f} final_loss={job.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
