"""Replay public GPU-cluster traces through the FitGpp policies,
with the §8 telemetry pipeline on top.

The paper validated FitGpp on a private PFN trace; this example replays
public-format traces (Microsoft-Philly-style / Alibaba-PAI-style CSV)
through every policy instead, using the bundled sample fixtures by
default — point ``--philly`` / ``--pai`` at a real trace export to
reproduce at scale (``--time-scale`` compresses a months-long trace
into a tractable horizon).

Alongside the slowdown tables, the FitGpp run is traced through the
canonical event stream (``obs.schema``): the example prints the
replayed utilization / queue-depth time series, the per-job slowdown
decomposition summary (initial wait / grace stall / requeue wait /
service — the identity that makes Eq. 5 auditable), and ``--trace``
writes the stream as a Perfetto JSON (open in https://ui.perfetto.dev)
or CSV.

Run:  PYTHONPATH=src python examples/trace_replay.py
      PYTHONPATH=src python examples/trace_replay.py \
          --philly my_philly.csv --time-scale 60 --nodes 84
      PYTHONPATH=src python examples/trace_replay.py \
          --trace pai.perfetto.json
"""
import argparse
import dataclasses

import numpy as np

from repro import scenarios
from repro.configs.cluster import ClusterSpec, SimConfig
from repro.core import metrics, simulator
from repro.obs import export, timeseries


def telemetry(label: str, js, cfg, res, trace_path, trace_format):
    """Time-series + decomposition view of one traced run."""
    ts = timeseries.compute_timeseries(
        res.trace, n_nodes=cfg.cluster.n_nodes, is_te=js.is_te)
    print(f"\n{label} fitgpp telemetry: mean utilization "
          f"{ts.mean_utilization() * 100:.1f}%, "
          f"{ts.preempt_rate:.3f} preemptions/min over "
          f"{ts.makespan} min")
    print(timeseries.format_timeseries(ts, max_rows=12))

    dec = timeseries.slowdown_decomposition(res.trace)
    parts = np.array([[d.initial_wait, d.grace_stall, d.requeue_wait,
                       d.service] for d in dec.values()], dtype=float)
    assert all(d.identity_holds() for d in dec.values())
    names = ("initial wait", "grace stall", "requeue wait", "service")
    total = parts.sum()
    print("turnaround decomposition (summed over jobs, identity "
          "wait+stall+requeue+service == finish-submit holds per job):")
    for name, col in zip(names, parts.sum(axis=0)):
        print(f"  {name:13s} {int(col):7d} min ({col / total * 100:5.1f}%)")

    if trace_path:
        export.write_trace(trace_path, res.trace, fmt=trace_format,
                           n_nodes=cfg.cluster.n_nodes,
                           is_te=np.asarray(js.is_te))
        print(f"{len(res.trace)} events -> {trace_path} [{trace_format}]")


def replay(label: str, loader, path, cfg, time_scale,
           trace_path=None, trace_format="perfetto"):
    js, stats = loader(path, cfg, time_scale=time_scale,
                       return_stats=True)
    gangs = int((np.asarray(js.n_nodes) > 1).sum())
    print(f"\n=== {label}: {stats.n_jobs}/{stats.n_rows} rows kept "
          f"({stats.n_malformed} malformed, {stats.n_zero_runtime} "
          f"zero-runtime, {stats.n_too_wide} too wide) — "
          f"{int(js.is_te.sum())} TE, {gangs} gangs, "
          f"horizon {int(js.submit.max())} min ===")
    rows = {}
    traced = None
    for pol in ("fifo", "lrtp", "rand", "fitgpp"):
        res = simulator.simulate(
            dataclasses.replace(cfg, policy=pol), js, trace=True)
        rows[pol] = metrics.slowdown_table(res)
        if pol == "fitgpp":
            traced = res
    print(metrics.format_table(rows, "slowdown percentiles"))
    telemetry(label, js, cfg, traced, trace_path, trace_format)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--philly", default=scenarios.traces.PHILLY_SAMPLE,
                    help="Philly-style CSV (default: bundled fixture)")
    ap.add_argument("--pai", default=scenarios.traces.PAI_SAMPLE,
                    help="PAI-style CSV (default: bundled fixture)")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write the PAI replay's fitgpp event stream "
                         "to PATH")
    ap.add_argument("--trace-format", default="perfetto",
                    choices=("perfetto", "csv"))
    args = ap.parse_args()

    cfg = SimConfig(cluster=ClusterSpec(n_nodes=args.nodes),
                    seed=args.seed)
    replay("Philly-style", scenarios.load_philly_csv, args.philly,
           cfg, args.time_scale)
    replay("PAI-style", scenarios.load_pai_csv, args.pai,
           cfg, args.time_scale,
           trace_path=args.trace, trace_format=args.trace_format)
    print("\nTE/BE split: runtime <= 30 min is TE (paper §4.2 truncation);"
          "\ngrace periods are sampled from the cfg GP distribution "
          "(traces record none).")


if __name__ == "__main__":
    main()
