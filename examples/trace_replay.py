"""Replay public GPU-cluster traces through the FitGpp policies.

The paper validated FitGpp on a private PFN trace; this example replays
public-format traces (Microsoft-Philly-style / Alibaba-PAI-style CSV)
through every policy instead, using the bundled sample fixtures by
default — point ``--philly`` / ``--pai`` at a real trace export to
reproduce at scale (``--time-scale`` compresses a months-long trace
into a tractable horizon).

Run:  PYTHONPATH=src python examples/trace_replay.py
      PYTHONPATH=src python examples/trace_replay.py \
          --philly my_philly.csv --time-scale 60 --nodes 84
"""
import argparse
import dataclasses

import numpy as np

from repro import scenarios
from repro.configs.cluster import ClusterSpec, SimConfig
from repro.core import metrics, simulator


def replay(label: str, loader, path, cfg, time_scale):
    js, stats = loader(path, cfg, time_scale=time_scale,
                       return_stats=True)
    gangs = int((np.asarray(js.n_nodes) > 1).sum())
    print(f"\n=== {label}: {stats.n_jobs}/{stats.n_rows} rows kept "
          f"({stats.n_malformed} malformed, {stats.n_zero_runtime} "
          f"zero-runtime, {stats.n_too_wide} too wide) — "
          f"{int(js.is_te.sum())} TE, {gangs} gangs, "
          f"horizon {int(js.submit.max())} min ===")
    rows = {}
    for pol in ("fifo", "lrtp", "rand", "fitgpp"):
        res = simulator.simulate(
            dataclasses.replace(cfg, policy=pol), js)
        rows[pol] = metrics.slowdown_table(res)
    print(metrics.format_table(rows, "slowdown percentiles"))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--philly", default=scenarios.traces.PHILLY_SAMPLE,
                    help="Philly-style CSV (default: bundled fixture)")
    ap.add_argument("--pai", default=scenarios.traces.PAI_SAMPLE,
                    help="PAI-style CSV (default: bundled fixture)")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = SimConfig(cluster=ClusterSpec(n_nodes=args.nodes),
                    seed=args.seed)
    replay("Philly-style", scenarios.load_philly_csv, args.philly,
           cfg, args.time_scale)
    replay("PAI-style", scenarios.load_pai_csv, args.pai,
           cfg, args.time_scale)
    print("\nTE/BE split: runtime <= 30 min is TE (paper §4.2 truncation);"
          "\ngrace periods are sampled from the cfg GP distribution "
          "(traces record none).")


if __name__ == "__main__":
    main()
