"""Scenario & trace subsystem (DESIGN.md §5).

One registry over every workload the repo can evaluate a policy on:
the paper's own generators, a library of named synthetic stress
scenarios, and adapters for public GPU-cluster trace formats
(Philly-style / Alibaba-PAI-style CSV) with bundled sample fixtures.

    from repro import scenarios
    js = scenarios.build("burst-storm", cfg)     # SimConfig -> JobSet
    scenarios.scenario_names()                   # all registered names

CLI: ``PYTHONPATH=src python -m repro.scenarios list|describe|run|sweep``.
"""
from repro.scenarios.registry import (SYNTHETIC, TRACE, Scenario, build,
                                      all_scenarios, get_scenario,
                                      get_source, register_scenario,
                                      scenario_names)
# importing these modules populates the registry
from repro.scenarios import library as library          # noqa: F401
from repro.scenarios import traces as traces            # noqa: F401
from repro.scenarios.traces import (TraceStats, iter_trace_csv,
                                    load_pai_csv, load_philly_csv)

__all__ = [
    "SYNTHETIC", "TRACE", "Scenario", "TraceStats",
    "all_scenarios", "build", "get_scenario", "get_source",
    "iter_trace_csv", "library", "load_pai_csv", "load_philly_csv",
    "register_scenario", "scenario_names", "traces",
]
