"""Named synthetic scenarios beyond the paper's §4.2 / §4.4 workloads.

Every scenario is a ``SimConfig -> JobSet`` function registered under
``@register_scenario`` (see registry.py): it samples per-class
execution / demand / grace-period marginals from ``cfg.workload`` (the
paper's fitted truncated normals) and differs in the *arrival process*,
*class mix*, *gang structure* or *GP structure* — the axes the paper
could not explore on its single private trace.

Determinism: every scenario derives its rng from ``cfg.seed`` (plus a
per-scenario salt so two scenarios never share a stream) and scales
with ``cfg.workload.n_jobs``. All of them run through both the tick and
event-driven reference engines bit-identically (tests/test_scenarios).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.cluster import SimConfig, TruncNormal
from repro.core import workload
from repro.core.types import JobSet
from repro.scenarios.registry import SYNTHETIC, register_scenario

# ---------------------------------------------------------------------------
# shared sampling helpers
# ---------------------------------------------------------------------------


def _rng(cfg: SimConfig, salt: int) -> np.random.Generator:
    return np.random.default_rng((cfg.seed, salt))


def _class_samples(cfg: SimConfig, rng: np.random.Generator, n: int,
                   te_fraction: float = None, is_te: np.ndarray = None):
    """(is_te, exec_total, demand, gp) from the cfg per-class marginals.

    ``is_te`` overrides the Bernoulli class draw when the scenario
    assigns classes itself (e.g. burst membership)."""
    wl = cfg.workload
    if is_te is None:
        frac = wl.te_fraction if te_fraction is None else te_fraction
        is_te = rng.random(n) < frac
    exec_total = np.zeros(n, np.int64)
    demand = np.zeros((n, 3))
    n_te = int(is_te.sum())
    exec_total[is_te], demand[is_te] = workload.sample_class(
        rng, wl.te, n_te, wl.gpu_quanta)
    exec_total[~is_te], demand[~is_te] = workload.sample_class(
        rng, wl.be, n - n_te, wl.gpu_quanta)
    gp = np.round(workload.sample_trunc_normal(
        rng, wl.scaled_gp(), n)).astype(np.int64)
    return is_te, exec_total, demand, gp


def _rate(cfg: SimConfig, exec_total, demand, n_nodes=1,
          load: float = None) -> float:
    """Arrival rate [jobs/min] that injects ``load`` × cluster capacity
    of work per minute (open-loop analogue of the closed-loop target)."""
    cluster_cap = (np.asarray(cfg.cluster.node.as_tuple())
                   * cfg.cluster.n_nodes)
    work = exec_total * workload.cluster_fraction(demand, cluster_cap) \
        * n_nodes
    tgt = cfg.workload.load if load is None else load
    return tgt / max(float(np.mean(work)), 1e-9)


def _submit_from_gaps(gaps: np.ndarray) -> np.ndarray:
    return np.floor(np.cumsum(gaps)).astype(np.int64)


def _sorted_jobset(submit, exec_total, demand, is_te, gp,
                   n_nodes=None) -> JobSet:
    order = np.argsort(submit, kind="stable")
    return JobSet(
        submit=np.asarray(submit, np.int64)[order],
        exec_total=np.asarray(exec_total, np.int64)[order],
        demand=np.asarray(demand, np.float64)[order],
        is_te=np.asarray(is_te, bool)[order],
        gp=np.asarray(gp, np.int64)[order],
        n_nodes=None if n_nodes is None
        else np.asarray(n_nodes, np.int64)[order])


# ---------------------------------------------------------------------------
# the paper's own generators, re-registered
# ---------------------------------------------------------------------------

register_scenario(
    "paper-synthetic", kind=SYNTHETIC,
    description="Paper §4.2: truncated-normal classes, closed-loop "
                "admission at FIFO-normalized load",
    knobs={"workload.load": "FIFO-normalized backlog target (2.0)",
           "workload.te_fraction": "share of TE jobs (0.30)",
           "workload.multi_node_frac": "gang fraction (0 = paper)"},
)(workload.generate)

register_scenario(
    "trace-proxy", kind=SYNTHETIC,
    description="Paper §4.4 proxy: log-normal executions, bursty "
                "day/night arrivals",
    knobs={"workload.load": "target work injection rate",
           "workload.multi_node_frac": "gang fraction (0 = paper)"},
)(workload.generate_trace_proxy)


@register_scenario(
    "sparse-long-horizon", kind=SYNTHETIC,
    knobs={"workload.n_jobs": "job count",
           "gap_mean": "mean arrival gap, minutes (180)"})
def sparse_long_horizon(cfg: SimConfig, gap_mean: float = 180.0) -> JobSet:
    """Trickle arrivals over a long horizon (engine-benchmark regime)."""
    return workload.sparse_long_horizon(cfg.workload.n_jobs, seed=cfg.seed,
                                        gap_mean=gap_mean)


# ---------------------------------------------------------------------------
# stress scenarios (beyond the paper)
# ---------------------------------------------------------------------------


@register_scenario(
    "diurnal", kind=SYNTHETIC,
    knobs={"period_min": "day length, minutes (1440)",
           "amplitude": "rate swing, 0..1 (0.8)",
           "workload.load": "mean work injection rate"})
def diurnal(cfg: SimConfig, period_min: float = 1440.0,
            amplitude: float = 0.8) -> JobSet:
    """Sinusoidal day/night arrival intensity around the target load."""
    rng = _rng(cfg, 101)
    n = cfg.workload.n_jobs
    is_te, exec_total, demand, gp = _class_samples(cfg, rng, n)
    lam = _rate(cfg, exec_total, demand)
    gaps = rng.exponential(1.0 / lam, n)
    # modulate by the local time-of-day intensity (first-order: phase
    # from the unmodulated clock)
    t_approx = np.cumsum(gaps)
    intensity = 1.0 + amplitude * np.sin(2 * np.pi * t_approx / period_min)
    gaps = gaps / np.maximum(intensity, 1e-3)
    return _sorted_jobset(_submit_from_gaps(gaps), exec_total, demand,
                          is_te, gp)


@register_scenario(
    "burst-storm", kind=SYNTHETIC,
    knobs={"n_bursts": "number of TE storms (6)",
           "burst_frac": "share of jobs inside bursts (0.4)",
           "burst_width_min": "storm duration, minutes (5)"})
def burst_storm(cfg: SimConfig, n_bursts: int = 6, burst_frac: float = 0.4,
                burst_width_min: float = 5.0) -> JobSet:
    """Steady BE background + compact storms of TE arrivals.

    The worst case for victim selection: many TEs demand placement in
    the same handful of minutes, so a policy that preempts large or
    long-GP victims pays immediately."""
    rng = _rng(cfg, 102)
    n = cfg.workload.n_jobs
    # the background stream anchors the burst times, so keep >= 1 of it
    n_burst = min(int(n * burst_frac), n - 1)
    n_bg = n - n_burst

    is_te = np.zeros(n, bool)
    is_te[:n_bg] = rng.random(n_bg) < 0.1          # background: mostly BE
    is_te[n_bg:] = rng.random(n_burst) < 0.9       # storms: mostly TE
    _, exec_total, demand, gp = _class_samples(cfg, rng, n, is_te=is_te)

    lam = _rate(cfg, exec_total[:n_bg], demand[:n_bg])
    submit = np.zeros(n, np.int64)
    submit[:n_bg] = _submit_from_gaps(rng.exponential(1.0 / lam, n_bg))
    horizon = max(int(submit[:n_bg].max()), 1)
    starts = rng.uniform(0, horizon, n_bursts)
    which = rng.integers(0, n_bursts, n_burst)
    submit[n_bg:] = np.floor(
        starts[which] + rng.uniform(0, burst_width_min, n_burst)
    ).astype(np.int64)
    return _sorted_jobset(submit, exec_total, demand, is_te, gp)


@register_scenario(
    "gang-heavy", kind=SYNTHETIC,
    knobs={"gang_frac": "fraction of jobs that are gangs (0.5)",
           "widths": "gang widths sampled uniformly (2, 4, 8)"})
def gang_heavy(cfg: SimConfig, gang_frac: float = 0.5,
               widths=(2, 4, 8)) -> JobSet:
    """Distributed-DL regime: half the jobs are multi-node gangs.

    Reuses the paper generator (closed-loop admission) with the
    beyond-paper gang knobs turned up; stresses all-or-nothing
    placement and gang victim selection on both engines."""
    widths = tuple(w for w in widths if w <= cfg.cluster.n_nodes)
    wl = dataclasses.replace(cfg.workload, multi_node_frac=gang_frac,
                             multi_node_widths=widths or (2,))
    return workload.generate(dataclasses.replace(cfg, workload=wl))


@register_scenario(
    "gang-trace-mix", kind=SYNTHETIC,
    knobs={"gang_frac": "fraction of jobs that are gangs (0.35)",
           "widths": "empirical inst_num widths from the PAI fixture"})
def gang_trace_mix(cfg: SimConfig, gang_frac: float = 0.35) -> JobSet:
    """Synthetic arrivals with gang widths resampled from the PAI
    fixture's empirical ``inst_num`` distribution.

    The dedicated stress workload for gang-aware placement and victim
    selection: unlike ``gang-heavy``'s uniform widths, the width mix
    here is the one a real task table reports (mostly 1, a long-ish
    tail of 2/4/8-instance workers), over an open-loop arrival
    process — wide gangs must be packed around a churning single-node
    background on BOTH engines."""
    from repro.scenarios.traces import PAI_SAMPLE, load_pai_csv

    rng = _rng(cfg, 108)
    n = cfg.workload.n_jobs
    is_te, exec_total, demand, gp = _class_samples(cfg, rng, n)
    pai_widths = np.asarray(load_pai_csv(PAI_SAMPLE, cfg).n_nodes)
    pai_widths = pai_widths[pai_widths <= cfg.cluster.n_nodes]
    if len(pai_widths) == 0:
        pai_widths = np.ones(1, np.int64)
    gang = rng.random(n) < gang_frac
    n_nodes = np.where(gang, rng.choice(pai_widths, n), 1).astype(np.int64)
    lam = _rate(cfg, exec_total, demand, n_nodes=n_nodes)
    gaps = rng.exponential(1.0 / lam, n)
    return _sorted_jobset(_submit_from_gaps(gaps), exec_total, demand,
                          is_te, gp, n_nodes=n_nodes)


@register_scenario(
    "load-ramp", kind=SYNTHETIC,
    knobs={"ramp_lo": "initial load multiplier (0.25)",
           "ramp_hi": "final load multiplier (4.0)"})
def load_ramp(cfg: SimConfig, ramp_lo: float = 0.25,
              ramp_hi: float = 4.0) -> JobSet:
    """Arrival rate ramps linearly from under- to over-subscription.

    Crosses the load=1 boundary mid-trace: the early segment measures
    pure placement latency, the late segment queue-growth behaviour."""
    rng = _rng(cfg, 103)
    n = cfg.workload.n_jobs
    is_te, exec_total, demand, gp = _class_samples(cfg, rng, n)
    lam = _rate(cfg, exec_total, demand)
    ramp = np.linspace(ramp_lo, ramp_hi, n)
    gaps = rng.exponential(1.0 / lam, n) / ramp
    return _sorted_jobset(_submit_from_gaps(gaps), exec_total, demand,
                          is_te, gp)


@register_scenario(
    "te-flood", kind=SYNTHETIC,
    knobs={"te_fraction": "share of TE jobs (0.85)",
           "load_mult": "load multiplier vs cfg.workload.load (1.5)"})
def te_flood(cfg: SimConfig, te_fraction: float = 0.85,
             load_mult: float = 1.5) -> JobSet:
    """Inverted class mix: TE jobs dominate the arrival stream.

    With few BE victims to evict, preemptive policies degrade toward
    FIFO — the regime where the paper's 30%-TE assumption breaks."""
    rng = _rng(cfg, 104)
    n = cfg.workload.n_jobs
    is_te, exec_total, demand, gp = _class_samples(
        cfg, rng, n, te_fraction=te_fraction)
    lam = _rate(cfg, exec_total, demand,
                load=cfg.workload.load * load_mult)
    gaps = rng.exponential(1.0 / lam, n)
    return _sorted_jobset(_submit_from_gaps(gaps), exec_total, demand,
                          is_te, gp)


@register_scenario(
    "long-tail-be", kind=SYNTHETIC,
    knobs={"sigma": "BE log-normal shape (2.0)",
           "median_min": "BE median execution, minutes (30)",
           "cap_min": "BE execution cap, minutes (2880)"})
def long_tail_be(cfg: SimConfig, sigma: float = 2.0,
                 median_min: float = 30.0, cap_min: float = 2880.0
                 ) -> JobSet:
    """Heavy-tailed BE executions: a few multi-day jobs hold resources.

    Long-running victims maximize the cost of a bad preemption choice
    (LRTP's target) and of head-of-line blocking under FIFO."""
    rng = _rng(cfg, 105)
    n = cfg.workload.n_jobs
    is_te, exec_total, demand, gp = _class_samples(cfg, rng, n)
    be = ~is_te
    tail = np.exp(np.log(median_min)
                  + sigma * rng.standard_normal(int(be.sum())))
    exec_total[be] = np.maximum(
        np.clip(tail, 3.0, cap_min).astype(np.int64), 1)
    lam = _rate(cfg, exec_total, demand)
    gaps = rng.exponential(1.0 / lam, n)
    return _sorted_jobset(_submit_from_gaps(gaps), exec_total, demand,
                          is_te, gp)


@register_scenario(
    "maintenance-drain", kind=SYNTHETIC,
    knobs={"drain_start_frac": "window start as horizon fraction (0.4)",
           "drain_min": "window length, minutes (240)"})
def maintenance_drain(cfg: SimConfig, drain_start_frac: float = 0.4,
                      drain_min: float = 240.0) -> JobSet:
    """Submission freeze mid-trace, then the deferred backlog floods in.

    Models a maintenance window: arrivals inside [t0, t0+drain) are
    held and released together at the window end — an adversarial
    step-function in queue depth."""
    rng = _rng(cfg, 106)
    n = cfg.workload.n_jobs
    is_te, exec_total, demand, gp = _class_samples(cfg, rng, n)
    lam = _rate(cfg, exec_total, demand)
    submit = _submit_from_gaps(rng.exponential(1.0 / lam, n))
    t0 = int(submit.max() * drain_start_frac)
    t1 = t0 + int(drain_min)
    submit = np.where((submit >= t0) & (submit < t1), t1, submit)
    return _sorted_jobset(submit, exec_total, demand, is_te, gp)


@register_scenario(
    "heterogeneous-gp", kind=SYNTHETIC,
    knobs={"zero_gp_frac": "share of GP=0 (checkpoint-free) BE jobs (0.5)",
           "long_gp": "TruncNormal(12, 6, [5, 40]) for the rest"})
def heterogeneous_gp(cfg: SimConfig, zero_gp_frac: float = 0.5) -> JobSet:
    """Bimodal grace periods: instant-vacate jobs next to slow movers.

    Maximizes the spread FitGpp's GP term (Eq. 3) can exploit; under
    GP-blind policies the long-GP half dominates re-scheduling
    intervals."""
    rng = _rng(cfg, 107)
    n = cfg.workload.n_jobs
    is_te, exec_total, demand, gp = _class_samples(cfg, rng, n)
    zero = rng.random(n) < zero_gp_frac
    long_gp = np.round(workload.sample_trunc_normal(
        rng, TruncNormal(12.0, 6.0, 5.0, 40.0), n)).astype(np.int64)
    gp = np.where(zero, 0, long_gp)
    lam = _rate(cfg, exec_total, demand)
    gaps = rng.exponential(1.0 / lam, n)
    return _sorted_jobset(_submit_from_gaps(gaps), exec_total, demand,
                          is_te, gp)


def _stream_synthetic_source(cfg: SimConfig):
    from repro.core.stream.source import JobSource
    return JobSource(workload.stream_chunks(cfg))


@register_scenario(
    "stream-synthetic", kind=SYNTHETIC,
    source=_stream_synthetic_source,
    knobs={"n_jobs": "total jobs (workload.n_jobs; streams O(chunk))",
           "load": "open-loop arrival intensity (workload.load)",
           "chunk": "generator chunk size, jobs (1024)"})
def stream_synthetic(cfg: SimConfig) -> JobSet:
    """Open-loop chunked synthetic stream (workload.stream_chunks).

    The §4.4 trace-proxy arrival model in streamable form: chunk k is
    drawn entirely from ``rng((seed, k))``, so any window of the
    stream regenerates without its prefix and the streaming engine
    replays 10^5-10^6 jobs in O(capacity) memory (DESIGN.md §10).
    Unlike ``paper-synthetic``, arrivals are open-loop — sub-critical
    ``workload.load`` (< ~0.9) keeps the backlog bounded. This
    registry entry materializes the same stream for the monolithic
    engines."""
    from repro.core.stream.source import materialize
    return materialize(_stream_synthetic_source(cfg))


def _stream_closed_loop_source(cfg: SimConfig):
    from repro.core.stream.admission import ClosedLoopAdmission
    from repro.core.stream.source import JobSource
    return JobSource(ClosedLoopAdmission(
        cfg, JobSource(workload.stream_chunks(cfg))))


@register_scenario(
    "stream-closed-loop", kind=SYNTHETIC,
    source=_stream_closed_loop_source,
    knobs={"n_jobs": "total jobs (workload.n_jobs; streams O(backlog))",
           "load": "FIFO-normalized backlog target (workload.load, "
                   "2.0 = the paper's saturated regime)",
           "chunk": "generator chunk size, jobs (1024)"})
def stream_closed_loop(cfg: SimConfig) -> JobSet:
    """The §4.2 closed-loop arrival regime in streamable form: the
    chunked synthetic job data of ``stream-synthetic`` with its
    open-loop submit times re-stamped as closed-loop admit ticks
    (``stream.ClosedLoopAdmission``) holding the FIFO-normalized
    backlog at ``workload.load``. Saturated loads (2.0) stream in
    O(backlog + chunk) memory — the closed loop itself bounds the
    backlog, so no fixed pool starves. This registry entry computes
    the identical admit ticks monolithically
    (``workload.closed_loop_submit_times``) for the non-streaming
    engines; streamed and monolithic runs are bit-exact
    (``stream.verify_closed_loop_parity``)."""
    from repro.core.stream.source import JobSource, materialize
    js = materialize(JobSource(workload.stream_chunks(cfg)))
    js.submit = workload.closed_loop_submit_times(cfg, js)
    return js
