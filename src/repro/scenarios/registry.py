"""Scenario registry: one namespace for every workload the repo can run.

A *scenario* is any ``SimConfig -> JobSet`` function — synthetic
generators (paper §4.2, stress variants) and trace adapters (public
GPU-cluster traces) register through the same decorator, so the CLI
(``python -m repro.scenarios``), the benchmarks and the sweeps discover
them uniformly:

    @register_scenario("te-flood", kind=SYNTHETIC,
                       knobs={"te_fraction": "share of TE jobs (0.85)"})
    def te_flood(cfg: SimConfig) -> JobSet:
        ...

Scenario functions must honor ``cfg.workload.n_jobs`` (scale),
``cfg.seed`` (determinism) and ``cfg.cluster`` (capacities): ``build``
re-validates every JobSet against the node shape before handing it out.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.configs.cluster import SimConfig
from repro.core.types import JobSet

SYNTHETIC = "synthetic"
TRACE = "trace"
_KINDS = (SYNTHETIC, TRACE)

ScenarioFn = Callable[[SimConfig], JobSet]
# SimConfig -> core.stream.JobSource (typed loosely to keep the
# registry import-light; core/stream is only imported on use)
SourceFn = Callable[[SimConfig], Any]


@dataclass(frozen=True)
class Scenario:
    name: str
    fn: ScenarioFn
    kind: str                          # SYNTHETIC | TRACE
    description: str                   # one line, shown by ``list``
    knobs: Tuple[Tuple[str, str], ...]  # (knob, meaning) pairs
    # Optional streaming variant: builds a chunked JobSource over the
    # SAME workload ``fn`` describes, without materializing it (trace
    # readers, chunked synthetic generators). DESIGN.md §10.
    source: Optional[SourceFn] = None

    def build(self, cfg: SimConfig) -> JobSet:
        js = self.fn(cfg)
        js.validate(np.asarray(cfg.cluster.node.as_tuple()))
        return js


_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(name: str, *, kind: str = SYNTHETIC,
                      description: str = "",
                      knobs: Optional[Mapping[str, str]] = None,
                      source: Optional[SourceFn] = None):
    """Decorator registering ``fn`` as scenario ``name``.

    ``description`` defaults to the first line of the docstring; knobs
    document the tunable parameters (config fields or closure defaults).
    ``source`` optionally registers a streaming variant (a
    ``SimConfig -> JobSource`` factory over the same workload) for the
    bounded-memory engine; scenarios without one still stream via
    :func:`get_source`'s materialized fallback.
    """
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")

    def deco(fn: ScenarioFn) -> ScenarioFn:
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        doc = (fn.__doc__ or "").strip().splitlines()
        desc = description or (doc[0] if doc else "")
        if not desc:
            raise ValueError(
                f"scenario {name!r} needs a description (pass "
                "description=... or give the function a docstring)")
        _REGISTRY[name] = Scenario(
            name=name, fn=fn, kind=kind, description=desc,
            knobs=tuple(sorted((knobs or {}).items())), source=source)
        return fn

    return deco


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY)) or "<none>"
        raise KeyError(f"unknown scenario {name!r}; registered: {known}") \
            from None


def scenario_names(kind: Optional[str] = None) -> List[str]:
    return sorted(n for n, sc in _REGISTRY.items()
                  if kind is None or sc.kind == kind)


def all_scenarios(kind: Optional[str] = None) -> List[Scenario]:
    return [_REGISTRY[n] for n in scenario_names(kind)]


def build(name: str, cfg: SimConfig) -> JobSet:
    """Build + validate the named scenario's JobSet for ``cfg``."""
    return get_scenario(name).build(cfg)


def get_source(name: str, cfg: SimConfig):
    """JobSource for the named scenario (the streaming engine's input).

    Scenarios with a registered ``source`` stream without ever
    materializing the workload (trace readers, chunked generators);
    the rest fall back to a chunked view over the built JobSet —
    same jobs, but O(n_jobs) host memory during the build.
    """
    sc = get_scenario(name)
    from repro.core.stream.source import from_jobset
    if sc.source is None:
        return from_jobset(sc.build(cfg))
    return sc.source(cfg)
