"""Public GPU-cluster trace adapters -> validated :class:`JobSet`.

Two CSV dialects are supported, modelled on the public traces the
related schedulers evaluate on (DL2, arXiv:1909.06040; prediction-
assisted scheduling, arXiv:2501.05563):

* **Philly-style** (Microsoft Philly job log flattened to CSV):
  ``jobid,vc,submit_time,start_time,end_time,gpus,status`` with
  ISO-8601 or epoch-second timestamps and a whole-job GPU count.
  Philly publishes no CPU/RAM requests, so those are estimated
  pro-rata to the job's GPU share of a node (half-GPU floor).
* **Alibaba-PAI-style** (pai_task_table):
  ``job_name,task_name,inst_num,status,start_time,end_time,
  plan_cpu,plan_mem,plan_gpu`` with epoch-second timestamps,
  ``plan_cpu``/``plan_gpu`` in percent (100 = 1 core / 1 GPU),
  ``plan_mem`` in GB and ``inst_num`` gang instances. The task table
  records no queueing, so ``start_time`` doubles as the submit time.

Shared normalization (the adapter contract, DESIGN.md §5):

* rows with unparseable fields, a missing/negative runtime, or a gang
  wider than the cluster are dropped (counted in ``TraceStats``);
* times rebase to minute 0 at the earliest submit; ``time_scale``
  compresses gaps (a months-long trace replays in a tractable horizon);
* demand snaps to node quanta: GPUs to ``cfg.workload.gpu_quanta``,
  CPU/RAM to whole units, everything clipped to the node capacity;
* gang width: Philly jobs wider than one node split into
  ``ceil(gpus / node.gpu)`` equal instances; PAI uses ``inst_num``;
* TE/BE: runtime <= ``te_runtime_min`` is TE (the paper's TE class is
  short trial runs; its §4.2 truncation, 30 min, is the default);
* grace periods are not recorded in public traces — they are sampled
  from ``cfg.workload.scaled_gp()`` under ``cfg.seed`` (deterministic).

Every dialect has two entry points over the SAME row parser:

* ``load_*_csv`` — one monolithic, globally-sorted JobSet (rows may
  arrive in any order; gp drawn once under ``(seed, 0xB07)``);
* :func:`iter_trace_csv` — a one-pass STREAMING reader for the
  bounded-memory engine (``core/stream``, DESIGN.md §10): yields
  normalized JobSet chunks holding O(chunk) rows, requires the CSV be
  submit-ordered, and draws gp per chunk under
  ``(seed, 0xB07, chunk_idx)`` — so a streamed replay's grace periods
  differ from the monolithic loader's, but are deterministic given
  the chunk size.

:func:`tiled_trace_chunks` tiles a bundled fixture end-to-end K times
with time offsets — a public-log-length stream from a few-KB file.
"""
from __future__ import annotations

import csv
import math
import os
from dataclasses import dataclass
from datetime import datetime, timezone
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.configs.cluster import SimConfig
from repro.core import workload
from repro.core.stream.source import JobSource, materialize
from repro.core.types import JobSet
from repro.scenarios.registry import TRACE, register_scenario

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures")
PHILLY_SAMPLE = os.path.join(FIXTURE_DIR, "philly_sample.csv")
PAI_SAMPLE = os.path.join(FIXTURE_DIR, "pai_sample.csv")


@dataclass
class TraceStats:
    """What the adapter kept and why it dropped the rest."""
    n_rows: int = 0
    n_jobs: int = 0
    n_malformed: int = 0
    n_zero_runtime: int = 0
    n_too_wide: int = 0
    n_filtered_status: int = 0


def _parse_ts(raw: str) -> float:
    """Epoch seconds from an ISO-8601 or numeric timestamp."""
    raw = raw.strip()
    if not raw:
        raise ValueError("empty timestamp")
    try:
        return float(raw)
    except ValueError:
        dt = datetime.fromisoformat(raw)
        if dt.tzinfo is None:              # naive stamps read as UTC
            dt = dt.replace(tzinfo=timezone.utc)
        return dt.timestamp()


# One parsed row: (submit_sec, exec_min, (cpu, ram, gpu), gang_width).
# Parsers return this tuple, or the TraceStats counter name to bump
# when the row is dropped — the single definition of each dialect,
# shared by the monolithic loaders and the streaming reader.
_Row = Tuple[float, int, Tuple[float, float, float], int]


def _philly_row(row, cfg: SimConfig):
    node = cfg.cluster.node
    try:
        sub = _parse_ts(row["submit_time"])
        start = _parse_ts(row["start_time"])
        end = _parse_ts(row["end_time"])
        gpus = float(row["gpus"])
    except (KeyError, ValueError, TypeError):
        return "n_malformed"
    runtime_min = math.ceil((end - start) / 60.0)
    if runtime_min <= 0 or start < sub or gpus < 0:
        return "n_zero_runtime"
    width = max(1, math.ceil(gpus / node.gpu))
    if width > cfg.cluster.n_nodes:
        return "n_too_wide"
    gpu_pn = gpus / width
    # Philly has no CPU/RAM requests: estimate pro-rata to the GPU
    # share of a node, with a half-GPU floor for CPU-only
    share = max(gpu_pn, 0.5) / node.gpu
    return (sub, runtime_min,
            (node.cpu * share, node.ram * share, gpu_pn), width)


def _pai_row(row, cfg: SimConfig):
    try:
        start = _parse_ts(row["start_time"])
        end = _parse_ts(row["end_time"])
        inst = int(float(row["inst_num"]))
        cpu = float(row["plan_cpu"]) / 100.0
        ram = float(row["plan_mem"])
        gpu = float(row["plan_gpu"]) / 100.0
    except (KeyError, ValueError, TypeError):
        return "n_malformed"
    runtime_min = math.ceil((end - start) / 60.0)
    if runtime_min <= 0 or inst < 1 or min(cpu, ram, gpu) < 0:
        return "n_zero_runtime"
    if inst > cfg.cluster.n_nodes:
        return "n_too_wide"
    # the task table records no queueing: start doubles as submit
    return (start, runtime_min, (cpu, ram, gpu), inst)


DIALECTS = {"philly": _philly_row, "pai": _pai_row}


def _iter_parsed(path: str, parser, cfg: SimConfig, stats: TraceStats,
                 statuses: Optional[Sequence[str]]) -> Iterator[_Row]:
    """One pass over the CSV: parsed rows out, drops counted."""
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            stats.n_rows += 1
            if statuses is not None and row.get("status") not in statuses:
                stats.n_filtered_status += 1
                continue
            out = parser(row, cfg)
            if isinstance(out, str):
                setattr(stats, out, getattr(stats, out) + 1)
                continue
            stats.n_jobs += 1
            yield out


def _snap_demand(cfg: SimConfig, demand: np.ndarray,
                 node_cap: np.ndarray) -> np.ndarray:
    """Demand snapping: GPUs to the allocation quanta, CPU/RAM to
    whole units; everything clipped to a node."""
    demand[:, 0] = np.clip(np.round(demand[:, 0]), 1.0, node_cap[0])
    demand[:, 1] = np.clip(np.round(demand[:, 1]), 1.0, node_cap[1])
    demand[:, 2] = np.clip(
        workload.snap(demand[:, 2], cfg.workload.gpu_quanta),
        0.0, node_cap[2])
    return demand


def _finalize(cfg: SimConfig, submit_min, exec_min, demand, n_nodes,
              te_runtime_min: float) -> JobSet:
    """Shared monolithic tail: snap/clip demand, classify, sample GPs,
    sort globally."""
    node_cap = np.asarray(cfg.cluster.node.as_tuple())
    submit = np.asarray(submit_min, np.int64)
    exec_total = np.maximum(np.asarray(exec_min, np.int64), 1)
    demand = _snap_demand(
        cfg, np.asarray(demand, np.float64).reshape(-1, 3), node_cap)
    n_nodes = np.asarray(n_nodes, np.int64)
    n = len(submit)

    is_te = exec_total <= te_runtime_min
    rng = np.random.default_rng((cfg.seed, 0xB07))
    gp = np.round(workload.sample_trunc_normal(
        rng, cfg.workload.scaled_gp(), n)).astype(np.int64)

    if n == 0:
        raise ValueError(
            "trace produced no usable jobs (every row malformed, "
            "zero-runtime, status-filtered or wider than the cluster)")
    order = np.argsort(submit, kind="stable")
    submit = submit[order] - submit.min()
    js = JobSet(submit=submit, exec_total=exec_total[order],
                demand=demand[order], is_te=is_te[order], gp=gp[order],
                n_nodes=n_nodes[order])
    js.validate(node_cap)
    return js


def _load_csv(path: str, cfg: SimConfig, dialect: str, *,
              te_runtime_min: float, time_scale: float,
              statuses: Optional[Sequence[str]], return_stats: bool):
    stats = TraceStats()
    submit_min, exec_min, demand, n_nodes = [], [], [], []
    for sub, rt, dem, width in _iter_parsed(
            path, DIALECTS[dialect], cfg, stats, statuses):
        submit_min.append(sub / 60.0 / time_scale)
        exec_min.append(rt)
        demand.append(dem)
        n_nodes.append(width)
    js = _finalize(cfg, np.floor(submit_min), exec_min, demand, n_nodes,
                   te_runtime_min)
    return (js, stats) if return_stats else js


def load_philly_csv(path: str, cfg: SimConfig, *,
                    te_runtime_min: float = 30.0, time_scale: float = 1.0,
                    statuses: Optional[Sequence[str]] = None,
                    return_stats: bool = False):
    """Philly-style CSV -> JobSet (see module docstring for the dialect).

    ``statuses`` restricts to the given job outcomes (default: keep all
    — Killed/Failed jobs consumed resources too). ``return_stats`` also
    returns the :class:`TraceStats` drop accounting.
    """
    return _load_csv(path, cfg, "philly", te_runtime_min=te_runtime_min,
                     time_scale=time_scale, statuses=statuses,
                     return_stats=return_stats)


def load_pai_csv(path: str, cfg: SimConfig, *,
                 te_runtime_min: float = 30.0, time_scale: float = 1.0,
                 statuses: Optional[Sequence[str]] = None,
                 return_stats: bool = False):
    """Alibaba-PAI-style CSV -> JobSet (dialect in the module docstring).

    ``plan_cpu`` / ``plan_gpu`` are percentages (100 = 1 core / 1 GPU),
    ``plan_mem`` is GB, ``inst_num`` is the gang width.
    """
    return _load_csv(path, cfg, "pai", te_runtime_min=te_runtime_min,
                     time_scale=time_scale, statuses=statuses,
                     return_stats=return_stats)


def iter_trace_csv(path: str, cfg: SimConfig, dialect: str = "philly", *,
                   chunk: int = 4096, te_runtime_min: float = 30.0,
                   time_scale: float = 1.0,
                   statuses: Optional[Sequence[str]] = None,
                   stats: Optional[TraceStats] = None
                   ) -> Iterator[JobSet]:
    """One-pass streaming trace reader: normalized, validated JobSet
    chunks of up to ``chunk`` rows — never the whole trace in memory.

    The CSV must already be submit-ordered (public trace dumps are;
    an out-of-order row raises — a global sort needs the full trace,
    which is exactly what streaming avoids, so unsorted files must go
    through the monolithic ``load_*_csv``). Times rebase to the FIRST
    kept row (== the global minimum when sorted). Grace periods draw
    per chunk from ``rng((cfg.seed, 0xB07, chunk_idx))``, so the
    stream is reproducible given ``chunk`` but its gp values differ
    from the monolithic loader's single draw. ``stats`` (a
    :class:`TraceStats`) fills in-place as the pass advances — drop
    accounting comes for free with the same read.
    """
    wl = cfg.workload
    node_cap = np.asarray(cfg.cluster.node.as_tuple())
    stats = TraceStats() if stats is None else stats
    t0: Optional[int] = None
    last_submit: Optional[int] = None
    k = 0
    buf: list = []

    def emit() -> JobSet:
        nonlocal k, last_submit
        sub_sec = np.array([r[0] for r in buf], np.float64)
        submit = np.floor(sub_sec / 60.0 / time_scale).astype(np.int64)
        if (np.diff(submit) < 0).any() or (
                last_submit is not None and int(submit[0]) < last_submit):
            raise ValueError(
                f"{path}: rows are not submit-ordered; the streaming "
                "reader cannot globally sort — use the monolithic "
                f"load_{dialect}_csv for unsorted traces")
        last_submit = int(submit[-1])
        exec_total = np.maximum(
            np.array([r[1] for r in buf], np.int64), 1)
        demand = _snap_demand(
            cfg, np.array([r[2] for r in buf], np.float64).reshape(-1, 3),
            node_cap)
        rng = np.random.default_rng((cfg.seed, 0xB07, k))
        gp = np.round(workload.sample_trunc_normal(
            rng, wl.scaled_gp(), len(buf))).astype(np.int64)
        js = JobSet(submit=submit - t0,
                    exec_total=exec_total, demand=demand,
                    is_te=exec_total <= te_runtime_min, gp=gp,
                    n_nodes=np.array([r[3] for r in buf], np.int64))
        js.validate(node_cap)
        k += 1
        return js

    for parsed in _iter_parsed(path, DIALECTS[dialect], cfg, stats,
                               statuses):
        if t0 is None:
            t0 = int(math.floor(parsed[0] / 60.0 / time_scale))
        buf.append(parsed)
        if len(buf) >= chunk:
            yield emit()
            buf = []
    if buf:
        yield emit()


def trace_source(path: str, cfg: SimConfig, dialect: str = "philly",
                 **kw) -> JobSource:
    """:class:`JobSource` over :func:`iter_trace_csv` with the drop
    accounting attached (``source.stats``) for one-pass consumers."""
    stats = TraceStats()
    return JobSource(
        iter_trace_csv(path, cfg, dialect, stats=stats, **kw),
        stats=stats)


def tiled_trace_chunks(path: str, cfg: SimConfig, dialect: str = "philly",
                       *, repeats: Optional[int] = None, gap_min: int = 1,
                       te_runtime_min: float = 30.0,
                       time_scale: float = 1.0,
                       statuses: Optional[Sequence[str]] = None
                       ) -> Iterator[JobSet]:
    """Tile a small fixture trace end-to-end ``repeats`` times with
    time offsets — a public-log-length stream from a bundled file,
    O(fixture) memory. Each repeat shifts by the fixture's submit
    span plus its longest runtime (so steady state drains between
    tiles) plus ``gap_min``, and resamples grace periods under
    ``rng((cfg.seed, 0xB07, repeat))``. ``repeats`` defaults to
    whatever reaches ``cfg.workload.n_jobs`` total jobs."""
    base = _load_csv(path, cfg, dialect, te_runtime_min=te_runtime_min,
                     time_scale=time_scale, statuses=statuses,
                     return_stats=False)
    if repeats is None:
        repeats = max(1, -(-int(cfg.workload.n_jobs) // base.n))
    span = int(base.submit[-1]) + int(base.exec_total.max()) + int(gap_min)
    for r in range(int(repeats)):
        rng = np.random.default_rng((cfg.seed, 0xB07, r))
        gp = np.round(workload.sample_trunc_normal(
            rng, cfg.workload.scaled_gp(), base.n)).astype(np.int64)
        yield JobSet(submit=base.submit + r * span,
                     exec_total=base.exec_total, demand=base.demand,
                     is_te=base.is_te, gp=gp, n_nodes=base.n_nodes)


def tiled_source(path: str, cfg: SimConfig, dialect: str = "philly",
                 **kw) -> JobSource:
    """:class:`JobSource` over :func:`tiled_trace_chunks`."""
    return JobSource(tiled_trace_chunks(path, cfg, dialect, **kw))


@register_scenario(
    "philly-sample", kind=TRACE,
    knobs={"te_runtime_min": "TE/BE runtime threshold, minutes (30)",
           "time_scale": "arrival-gap compression factor (1.0)",
           "statuses": "job outcomes to keep (all)"},
    source=lambda cfg: trace_source(PHILLY_SAMPLE, cfg, "philly"))
def philly_sample(cfg: SimConfig) -> JobSet:
    """Bundled Microsoft-Philly-style sample trace (fixtures/, no network)."""
    return load_philly_csv(PHILLY_SAMPLE, cfg)


@register_scenario(
    "pai-sample", kind=TRACE,
    knobs={"te_runtime_min": "TE/BE runtime threshold, minutes (30)",
           "time_scale": "arrival-gap compression factor (1.0)",
           "statuses": "task outcomes to keep (all)"},
    source=lambda cfg: trace_source(PAI_SAMPLE, cfg, "pai"))
def pai_sample(cfg: SimConfig) -> JobSet:
    """Bundled Alibaba-PAI-style sample trace (fixtures/, no network)."""
    return load_pai_csv(PAI_SAMPLE, cfg)


def _philly_tiled_source(cfg: SimConfig) -> JobSource:
    return tiled_source(PHILLY_SAMPLE, cfg, "philly")


@register_scenario(
    "philly-tiled", kind=TRACE,
    knobs={"repeats": "fixture tilings (auto: reach workload.n_jobs)",
           "gap_min": "idle gap between tiles, minutes (1)"},
    source=_philly_tiled_source)
def philly_tiled(cfg: SimConfig) -> JobSet:
    """Philly sample tiled end-to-end to ~``workload.n_jobs`` jobs.

    The repeated-fixture long trace (DESIGN.md §10): a public-log-
    length workload from the bundled few-KB fixture. Streams through
    ``core/stream`` in bounded memory; this registry entry
    materializes the same stream for the monolithic engines."""
    return materialize(_philly_tiled_source(cfg))


def _pai_tiled_source(cfg: SimConfig) -> JobSource:
    return tiled_source(PAI_SAMPLE, cfg, "pai")


@register_scenario(
    "pai-tiled", kind=TRACE,
    knobs={"repeats": "fixture tilings (auto: reach workload.n_jobs)",
           "gap_min": "idle gap between tiles, minutes (1)"},
    source=_pai_tiled_source)
def pai_tiled(cfg: SimConfig) -> JobSet:
    """PAI sample tiled end-to-end to ~``workload.n_jobs`` jobs.

    Same construction as ``philly-tiled`` over the Alibaba-PAI-style
    fixture (gang instances included)."""
    return materialize(_pai_tiled_source(cfg))
