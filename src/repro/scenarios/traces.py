"""Public GPU-cluster trace adapters -> validated :class:`JobSet`.

Two CSV dialects are supported, modelled on the public traces the
related schedulers evaluate on (DL2, arXiv:1909.06040; prediction-
assisted scheduling, arXiv:2501.05563):

* **Philly-style** (Microsoft Philly job log flattened to CSV):
  ``jobid,vc,submit_time,start_time,end_time,gpus,status`` with
  ISO-8601 or epoch-second timestamps and a whole-job GPU count.
  Philly publishes no CPU/RAM requests, so those are estimated
  pro-rata to the job's GPU share of a node (half-GPU floor).
* **Alibaba-PAI-style** (pai_task_table):
  ``job_name,task_name,inst_num,status,start_time,end_time,
  plan_cpu,plan_mem,plan_gpu`` with epoch-second timestamps,
  ``plan_cpu``/``plan_gpu`` in percent (100 = 1 core / 1 GPU),
  ``plan_mem`` in GB and ``inst_num`` gang instances. The task table
  records no queueing, so ``start_time`` doubles as the submit time.

Shared normalization (the adapter contract, DESIGN.md §5):

* rows with unparseable fields, a missing/negative runtime, or a gang
  wider than the cluster are dropped (counted in ``TraceStats``);
* times rebase to minute 0 at the earliest submit; ``time_scale``
  compresses gaps (a months-long trace replays in a tractable horizon);
* demand snaps to node quanta: GPUs to ``cfg.workload.gpu_quanta``,
  CPU/RAM to whole units, everything clipped to the node capacity;
* gang width: Philly jobs wider than one node split into
  ``ceil(gpus / node.gpu)`` equal instances; PAI uses ``inst_num``;
* TE/BE: runtime <= ``te_runtime_min`` is TE (the paper's TE class is
  short trial runs; its §4.2 truncation, 30 min, is the default);
* grace periods are not recorded in public traces — they are sampled
  from ``cfg.workload.scaled_gp()`` under ``cfg.seed`` (deterministic).
"""
from __future__ import annotations

import csv
import math
import os
from dataclasses import dataclass
from datetime import datetime, timezone
from typing import Optional, Sequence

import numpy as np

from repro.configs.cluster import SimConfig
from repro.core import workload
from repro.core.types import JobSet
from repro.scenarios.registry import TRACE, register_scenario

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures")
PHILLY_SAMPLE = os.path.join(FIXTURE_DIR, "philly_sample.csv")
PAI_SAMPLE = os.path.join(FIXTURE_DIR, "pai_sample.csv")


@dataclass
class TraceStats:
    """What the adapter kept and why it dropped the rest."""
    n_rows: int = 0
    n_jobs: int = 0
    n_malformed: int = 0
    n_zero_runtime: int = 0
    n_too_wide: int = 0
    n_filtered_status: int = 0


def _parse_ts(raw: str) -> float:
    """Epoch seconds from an ISO-8601 or numeric timestamp."""
    raw = raw.strip()
    if not raw:
        raise ValueError("empty timestamp")
    try:
        return float(raw)
    except ValueError:
        dt = datetime.fromisoformat(raw)
        if dt.tzinfo is None:              # naive stamps read as UTC
            dt = dt.replace(tzinfo=timezone.utc)
        return dt.timestamp()


def _finalize(cfg: SimConfig, submit_min, exec_min, demand, n_nodes,
              te_runtime_min: float) -> JobSet:
    """Shared tail: snap/clip demand, classify, sample GPs, sort."""
    wl = cfg.workload
    node_cap = np.asarray(cfg.cluster.node.as_tuple())
    submit = np.asarray(submit_min, np.int64)
    exec_total = np.maximum(np.asarray(exec_min, np.int64), 1)
    demand = np.asarray(demand, np.float64).reshape(-1, 3)
    n_nodes = np.asarray(n_nodes, np.int64)
    n = len(submit)

    # demand snapping: GPUs to the allocation quanta, CPU/RAM to whole
    # units; everything clipped to a node
    demand[:, 0] = np.clip(np.round(demand[:, 0]), 1.0, node_cap[0])
    demand[:, 1] = np.clip(np.round(demand[:, 1]), 1.0, node_cap[1])
    demand[:, 2] = np.clip(
        workload.snap(demand[:, 2], wl.gpu_quanta), 0.0, node_cap[2])

    is_te = exec_total <= te_runtime_min
    rng = np.random.default_rng((cfg.seed, 0xB07))
    gp = np.round(workload.sample_trunc_normal(
        rng, wl.scaled_gp(), n)).astype(np.int64)

    if n == 0:
        raise ValueError(
            "trace produced no usable jobs (every row malformed, "
            "zero-runtime, status-filtered or wider than the cluster)")
    order = np.argsort(submit, kind="stable")
    submit = submit[order] - submit.min()
    js = JobSet(submit=submit, exec_total=exec_total[order],
                demand=demand[order], is_te=is_te[order], gp=gp[order],
                n_nodes=n_nodes[order])
    js.validate(node_cap)
    return js


def load_philly_csv(path: str, cfg: SimConfig, *,
                    te_runtime_min: float = 30.0, time_scale: float = 1.0,
                    statuses: Optional[Sequence[str]] = None,
                    return_stats: bool = False):
    """Philly-style CSV -> JobSet (see module docstring for the dialect).

    ``statuses`` restricts to the given job outcomes (default: keep all
    — Killed/Failed jobs consumed resources too). ``return_stats`` also
    returns the :class:`TraceStats` drop accounting.
    """
    node = cfg.cluster.node
    stats = TraceStats()
    submit_min, exec_min, demand, n_nodes = [], [], [], []
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            stats.n_rows += 1
            if statuses is not None and row.get("status") not in statuses:
                stats.n_filtered_status += 1
                continue
            try:
                sub = _parse_ts(row["submit_time"])
                start = _parse_ts(row["start_time"])
                end = _parse_ts(row["end_time"])
                gpus = float(row["gpus"])
            except (KeyError, ValueError, TypeError):
                stats.n_malformed += 1
                continue
            runtime_min = math.ceil((end - start) / 60.0)
            if runtime_min <= 0 or start < sub or gpus < 0:
                stats.n_zero_runtime += 1
                continue
            width = max(1, math.ceil(gpus / node.gpu))
            if width > cfg.cluster.n_nodes:
                stats.n_too_wide += 1
                continue
            gpu_pn = gpus / width
            # Philly has no CPU/RAM requests: estimate pro-rata to the
            # GPU share of a node, with a half-GPU floor for CPU-only
            share = max(gpu_pn, 0.5) / node.gpu
            submit_min.append(sub / 60.0 / time_scale)
            exec_min.append(runtime_min)
            demand.append((node.cpu * share, node.ram * share, gpu_pn))
            n_nodes.append(width)
    stats.n_jobs = len(submit_min)
    js = _finalize(cfg, np.floor(submit_min), exec_min, demand, n_nodes,
                   te_runtime_min)
    return (js, stats) if return_stats else js


def load_pai_csv(path: str, cfg: SimConfig, *,
                 te_runtime_min: float = 30.0, time_scale: float = 1.0,
                 statuses: Optional[Sequence[str]] = None,
                 return_stats: bool = False):
    """Alibaba-PAI-style CSV -> JobSet (dialect in the module docstring).

    ``plan_cpu`` / ``plan_gpu`` are percentages (100 = 1 core / 1 GPU),
    ``plan_mem`` is GB, ``inst_num`` is the gang width.
    """
    stats = TraceStats()
    submit_min, exec_min, demand, n_nodes = [], [], [], []
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            stats.n_rows += 1
            if statuses is not None and row.get("status") not in statuses:
                stats.n_filtered_status += 1
                continue
            try:
                start = _parse_ts(row["start_time"])
                end = _parse_ts(row["end_time"])
                inst = int(float(row["inst_num"]))
                cpu = float(row["plan_cpu"]) / 100.0
                ram = float(row["plan_mem"])
                gpu = float(row["plan_gpu"]) / 100.0
            except (KeyError, ValueError, TypeError):
                stats.n_malformed += 1
                continue
            runtime_min = math.ceil((end - start) / 60.0)
            if runtime_min <= 0 or inst < 1 or min(cpu, ram, gpu) < 0:
                stats.n_zero_runtime += 1
                continue
            if inst > cfg.cluster.n_nodes:
                stats.n_too_wide += 1
                continue
            # the task table records no queueing: start doubles as submit
            submit_min.append(start / 60.0 / time_scale)
            exec_min.append(runtime_min)
            demand.append((cpu, ram, gpu))
            n_nodes.append(inst)
    stats.n_jobs = len(submit_min)
    js = _finalize(cfg, np.floor(submit_min), exec_min, demand, n_nodes,
                   te_runtime_min)
    return (js, stats) if return_stats else js


@register_scenario(
    "philly-sample", kind=TRACE,
    knobs={"te_runtime_min": "TE/BE runtime threshold, minutes (30)",
           "time_scale": "arrival-gap compression factor (1.0)",
           "statuses": "job outcomes to keep (all)"})
def philly_sample(cfg: SimConfig) -> JobSet:
    """Bundled Microsoft-Philly-style sample trace (fixtures/, no network)."""
    return load_philly_csv(PHILLY_SAMPLE, cfg)


@register_scenario(
    "pai-sample", kind=TRACE,
    knobs={"te_runtime_min": "TE/BE runtime threshold, minutes (30)",
           "time_scale": "arrival-gap compression factor (1.0)",
           "statuses": "task outcomes to keep (all)"})
def pai_sample(cfg: SimConfig) -> JobSet:
    """Bundled Alibaba-PAI-style sample trace (fixtures/, no network)."""
    return load_pai_csv(PAI_SAMPLE, cfg)
