"""Scenario runner CLI.

    PYTHONPATH=src python -m repro.scenarios list [--kind synthetic|trace]
    PYTHONPATH=src python -m repro.scenarios describe NAME [--n-jobs 512]
    PYTHONPATH=src python -m repro.scenarios run NAME [--policy fitgpp]
        [--engine reference|jax] [--score-backend jnp|pallas]
        [--n-jobs 512] [--nodes 16] [--seed 0] [--mode event|tick]
        [--trace out.json [--trace-format perfetto|csv]]
        [--stream [--capacity N]]
    PYTHONPATH=src python -m repro.scenarios sweep NAME [NAME ...]
        [--seeds 0,1] [--n-jobs 256] [--policy fitgpp]
        [--mode event|tick] [--devices N] [--mesh auto|off]

``run`` replays one scenario through ``repro.api.run_experiment`` on
either engine (any registered policy — the choices come from the
policy registry) and prints the paper-style slowdown table; with
``--stream`` it goes through the bounded-memory macro-round engine
(``repro.api.run_stream``, DESIGN.md §10) instead, whose memory
scales with ``--capacity`` rather than the trace length. ``sweep``
batches every (scenario, seed) trial — ragged job counts included —
into one vmapped JAX sweep. ``describe`` adds one-pass streamed
workload stats (job counts, TE/BE split, reader drop accounting) for
scenarios with a registered streaming source.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro import api, scenarios
from repro.configs.cluster import ClusterSpec, SimConfig, WorkloadSpec
from repro.core import metrics


def _cfg(args, seed=None) -> SimConfig:
    return SimConfig(
        cluster=ClusterSpec(n_nodes=args.nodes),
        workload=WorkloadSpec(n_jobs=args.n_jobs),
        policy=args.policy,
        score_backend=getattr(args, "score_backend", "jnp"),
        time_mode=getattr(args, "mode", "event"),
        seed=args.seed if seed is None else seed)


def cmd_list(args) -> None:
    rows = scenarios.all_scenarios(args.kind)
    width = max(len(s.name) for s in rows)
    for sc in rows:
        print(f"{sc.name:{width}s}  [{sc.kind}]  {sc.description}")
    n_syn = len(scenarios.scenario_names(scenarios.SYNTHETIC))
    n_tr = len(scenarios.scenario_names(scenarios.TRACE))
    print(f"\n{n_syn} synthetic scenarios, {n_tr} trace adapters")


def cmd_describe(args) -> None:
    sc = scenarios.get_scenario(args.name)
    print(f"{sc.name} [{sc.kind}]\n  {sc.description}")
    lines = (sc.fn.__doc__ or "").strip().splitlines()
    if lines and lines[0].strip() == sc.description:
        lines = lines[1:]                      # summary already printed
    if any(ln.strip() for ln in lines):
        print("\n" + "\n".join(f"  {ln.strip()}" for ln in lines))
    if sc.knobs:
        print("\n  knobs:")
        for k, v in sc.knobs:
            print(f"    {k:28s} {v}")
    if sc.source is not None:
        # one bounded-memory pass over the registered stream: job
        # counts, class split and reader drop accounting in one read
        from repro.core import stream
        cfg = SimConfig(cluster=ClusterSpec(n_nodes=args.nodes),
                        workload=WorkloadSpec(n_jobs=args.n_jobs),
                        seed=args.seed)
        info = stream.scan(sc.source(cfg))
        print(f"\n  stream (one pass, n_jobs={args.n_jobs}):")
        print(f"    {info.n_jobs} jobs: {info.n_te} TE / {info.n_be} BE, "
              f"{info.n_gang} gangs; horizon {info.horizon} min, "
              f"{info.total_exec_min} exec-min total")
        ts = info.stats
        if ts is not None:
            print(f"    kept {ts.n_jobs}/{ts.n_rows} rows (dropped: "
                  f"{ts.n_malformed} malformed, {ts.n_zero_runtime} "
                  f"zero-runtime, {ts.n_too_wide} too-wide, "
                  f"{ts.n_filtered_status} status-filtered)")


def cmd_run(args) -> None:
    cfg = _cfg(args)
    if args.stream:
        r = api.run_stream(args.name, cfg.policy, cfg=cfg,
                           capacity=args.capacity, mode=args.mode,
                           trace=bool(args.trace),
                           admission=(True if args.closed_loop else None))
        res = r.raw
        arrivals = ("closed-loop "
                    f"(load {cfg.workload.load:g}) " if args.closed_loop
                    else "")
        print(f"{args.name}: {res.n_jobs} jobs streamed {arrivals}through "
              f"{res.capacity} slots in {res.rounds} rounds "
              f"(peak live {res.max_live}, spilled {res.n_spilled}), "
              f"policy={cfg.policy}, engine=stream, "
              f"nodes={cfg.cluster.n_nodes}")
        print(metrics.format_table(
            {r.policy: r.table},
            f"slowdown percentiles (makespan {r.makespan} min)"))
        print(f"resched intervals [min]: p50={r.intervals['p50']:.1f} "
              f"p95={r.intervals['p95']:.1f}   preempted "
              f"{r.preempted_frac * 100:.1f}% of BE jobs")
        print(f"fallback_count={r.fallback_count} "
              f"trace_overflow={r.trace_overflow}")
        if args.trace:
            from repro.obs import export
            export.write_trace(args.trace, r.events,
                               fmt=args.trace_format,
                               n_nodes=cfg.cluster.n_nodes,
                               is_te=res.is_te,
                               preemptive=api.get_policy(
                                   cfg.policy).preemptive)
            print(f"{len(r.events)} events -> {args.trace} "
                  f"[{args.trace_format}]"
                  + (f" (WARNING: {r.trace_overflow} rows dropped)"
                     if r.trace_overflow else ""))
        return
    js = scenarios.build(args.name, cfg)
    gangs = int((np.asarray(js.n_nodes) > 1).sum())
    print(f"{args.name}: {js.n} jobs ({int(js.is_te.sum())} TE, "
          f"{gangs} gangs), horizon {int(js.submit.max())} min, "
          f"policy={cfg.policy}, engine={args.engine}, "
          f"nodes={cfg.cluster.n_nodes}")
    r = api.run_experiment(args.name, cfg.policy, args.engine, cfg=cfg,
                           jobs=js, mode=args.mode, trace=bool(args.trace))
    print(metrics.format_table(
        {r.policy: r.table},
        f"slowdown percentiles (makespan {r.makespan} min)"))
    print(f"resched intervals [min]: p50={r.intervals['p50']:.1f} "
          f"p95={r.intervals['p95']:.1f}   preempted "
          f"{r.preempted_frac * 100:.1f}% of BE jobs")
    if args.engine == "jax":
        print(f"fallback_count={r.fallback_count} "
              f"trace_overflow={r.trace_overflow}")
    if args.trace:
        from repro.obs import export
        export.write_trace(args.trace, r.events,
                           fmt=args.trace_format,
                           n_nodes=cfg.cluster.n_nodes,
                           is_te=np.asarray(js.is_te),
                           preemptive=api.get_policy(cfg.policy).preemptive)
        print(f"{len(r.events)} events -> {args.trace} "
              f"[{args.trace_format}]"
              + (f" (WARNING: {r.trace_overflow} rows dropped)"
                 if r.trace_overflow else ""))


def cmd_sweep(args) -> None:
    import jax
    seeds = [int(s) for s in args.seeds.split(",")]
    devices = 1 if args.mesh == "off" else args.devices
    out = api.scenario_sweep(_cfg(args), args.names, seeds,
                             devices=devices)
    n_trials = len(args.names) * len(seeds)
    mesh = api.mesh_for_sweep(n_trials, devices=devices)
    n_dev = 1 if mesh is None else mesh.devices.size
    print(f"ragged sweep: {len(args.names)} scenarios x {len(seeds)} "
          f"seeds, policy={args.policy} (seed-averaged), "
          f"{n_dev}/{len(jax.devices())} devices")
    hdr = f"{'scenario':22s} | {'TE p50':>8s} {'TE p95':>8s} " \
          f"| {'BE p50':>8s} {'BE p95':>8s} | {'preempted':>9s}"
    print(hdr + "\n" + "-" * len(hdr))
    for i, name in enumerate(args.names):
        te = np.nanmean(out["te_slowdown"][i], axis=0)
        be = np.nanmean(out["be_slowdown"][i], axis=0)
        pf = np.nanmean(out["preempted_frac"][i])
        print(f"{name:22s} | {te[0]:8.2f} {te[1]:8.2f} "
              f"| {be[0]:8.2f} {be[1]:8.2f} | {pf * 100:8.1f}%")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.scenarios",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("list", help="list registered scenarios")
    p.add_argument("--kind", choices=(scenarios.SYNTHETIC, scenarios.TRACE),
                   default=None)
    p.set_defaults(fn=cmd_list)

    p = sub.add_parser("describe", help="knobs + doc for one scenario "
                                        "(+ one-pass stream stats when "
                                        "it has a streaming source)")
    p.add_argument("name")
    p.add_argument("--n-jobs", type=int, default=512,
                   help="stream length for the one-pass stats (512)")
    p.add_argument("--nodes", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    p.set_defaults(fn=cmd_describe)

    def sim_args(p):
        p.add_argument("--policy", default="fitgpp",
                       choices=api.policy_names())
        p.add_argument("--n-jobs", type=int, default=512)
        p.add_argument("--nodes", type=int, default=16)
        p.add_argument("--seed", type=int, default=0)

    p = sub.add_parser("run", help="replay through either engine "
                                   "(repro.api.run_experiment)")
    p.add_argument("name")
    sim_args(p)
    p.add_argument("--engine", default="reference", choices=api.ENGINES)
    p.add_argument("--mode", default="event", choices=("event", "tick"),
                   help="time advancement, either engine (bit-identical; "
                        "event skips no-op ticks)")
    p.add_argument("--score-backend", default="jnp",
                   choices=api.score_backend_names(),
                   help="JAX-engine score path for score policies")
    p.add_argument("--trace", metavar="PATH", default=None,
                   help="record the canonical event stream (both "
                        "engines; in-jit ring buffer on jax) and write "
                        "it to PATH")
    p.add_argument("--trace-format", default="perfetto",
                   choices=("perfetto", "csv"),
                   help="trace file format: Chrome/Perfetto JSON "
                        "(load in ui.perfetto.dev) or lossless CSV")
    p.add_argument("--stream", action="store_true",
                   help="replay through the bounded-memory streaming "
                        "engine (core/stream): memory scales with "
                        "--capacity, not --n-jobs")
    p.add_argument("--capacity", type=int, default=None,
                   help="streaming slot-pool size (default "
                        "32 x nodes x max_preemptions)")
    p.add_argument("--closed-loop", action="store_true",
                   help="with --stream: re-stamp the source's submit "
                        "times as closed-loop admit ticks holding the "
                        "FIFO backlog at the workload load (paper "
                        "§4.2; bit-exact with the monolithic "
                        "closed-loop scenarios)")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("sweep", help="ragged multi-scenario JAX sweep")
    p.add_argument("names", nargs="+")
    sim_args(p)
    p.add_argument("--seeds", default="0,1")
    p.add_argument("--mode", default="event", choices=("event", "tick"),
                   help="JAX-engine time advancement inside the vmapped "
                        "sweep (per-lane event jumps)")
    p.add_argument("--devices", type=int, default=None,
                   help="cap the sweep-fabric trial mesh at N devices "
                        "(default: every local device; loud fallback "
                        "when fewer are present)")
    p.add_argument("--mesh", default="auto", choices=("auto", "off"),
                   help="'off' forces the single-device vmap "
                        "(bit-identical to the sharded run)")
    p.set_defaults(fn=cmd_sweep)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
