"""One Policy API: ``repro.api`` — the single entrypoint for running
(scenario × policy × engine) experiments.

The paper's contribution is a decision rule; the repo's job is to
evaluate decision rules under as many workloads as possible. Both axes
are registries (``core/policy_registry.py``, ``scenarios/registry.py``)
and this facade is where they meet:

    from repro import api
    r = api.run_experiment(scenario="burst-storm", policy="srtp",
                           engine="jax", n_jobs=512, n_nodes=16)
    r.table["TE"]["p95"], r.preempted_frac, r.makespan

``run_experiment`` builds the config (validated against the policy
registry at construction), builds the scenario's ``JobSet``, runs the
chosen engine — ``"reference"`` (numpy) or ``"jax"`` (jit/vmap-able
fixed-capacity engine) — and normalizes the result into an
:class:`ExperimentResult` with the paper-style tables, however it was
produced. Both engines share the tick/event mode switch
(``SimConfig.time_mode``), gang (multi-node) jobs and
``SimConfig.backfill``; ``score_backend="pallas"`` routes score
policies through their registered kernel on the JAX engine.

Batched studies go through the same module: :func:`sensitivity_grid`
and :func:`scenario_sweep` re-export the classic sweep wrappers
(``core/sweep.py``), and :func:`run_table` / :func:`build_table` /
:func:`pooled_tables` expose the device-parallel sweep fabric
underneath them (``core/sweep_fabric.py``, DESIGN.md §11) — trial
tables ``shard_map``-ed over ``mesh_for_sweep``'s 1-D trial mesh,
bit-exact with the single-device vmap. The scenarios CLI, the engine
benchmark and the examples all sit on this facade. DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro import scenarios
from repro.configs.cluster import SimConfig
from repro.core import metrics, sim_jax, simulator
from repro.core.policy_registry import (all_policies, get_policy, make,
                                        policy_names, score_backend_names)
from repro.core.sweep import run_sweep, scenario_sweep, sensitivity_grid
from repro.core.sweep_fabric import (SweepResult, TrialTable, build_table,
                                     pooled_tables, run_table)
from repro.core.types import JobSet
from repro.launch.mesh import mesh_for_sweep

ENGINES = ("reference", "jax")
DEFAULT_SCENARIO = "paper-synthetic"

__all__ = [
    "DEFAULT_SCENARIO", "ENGINES", "ExperimentResult", "SweepResult",
    "TrialTable", "all_policies", "build_table", "compare_policies",
    "get_policy", "make", "make_config", "mesh_for_sweep",
    "policy_names", "pooled_tables", "run_experiment", "run_stream",
    "run_sweep", "run_table", "scenario_names", "scenario_sweep",
    "score_backend_names", "sensitivity_grid",
]

scenario_names = scenarios.scenario_names


@dataclass(frozen=True)
class ExperimentResult:
    """Engine-agnostic result of one (scenario, policy, engine) run.

    ``table`` is the paper-style slowdown-percentile table
    (``{"TE": {"p50": ...}, "BE": {...}}``, metrics.format_table-ready);
    ``intervals`` the preemption→reschedule percentiles; ``raw`` the
    engine-native result (``SimResult`` for the reference engine,
    ``(Jobs, State)`` for JAX) for callers that need more.
    """
    scenario: str
    policy: str
    engine: str
    cfg: SimConfig
    table: Dict[str, Dict[str, float]]
    intervals: Dict[str, float]
    preempted_frac: float
    makespan: int
    raw: Any = field(repr=False, compare=False, default=None)
    # Canonical event stream (List[obs.schema.Event]) when the run was
    # traced (``run_experiment(trace=True)``), else None. Identical
    # vocabulary from either engine — the trace-parity contract.
    events: Optional[list] = field(repr=False, compare=False, default=None)
    # Ring-buffer rows dropped in a traced JAX run (0 = complete trace;
    # reference traces never overflow). Surfaced loudly: a nonzero
    # value means the decoded stream is truncated.
    trace_overflow: int = 0
    # Random-fallback invocations on the JAX engine (score policies
    # under cluster pressure). Nonzero means the run left the
    # deterministic cross-engine parity domain (DESIGN.md §8).
    fallback_count: int = 0


def make_config(policy: Optional[str] = None, *,
                base: Optional[SimConfig] = None,
                n_jobs: Optional[int] = None, n_nodes: Optional[int] = None,
                seed: Optional[int] = None, s: Optional[float] = None,
                P: Optional[int] = None,
                score_backend: Optional[str] = None,
                backfill: Optional[bool] = None) -> SimConfig:
    """SimConfig from the common experiment knobs (None = keep the
    ``base`` value — including ``policy``, so a caller-configured base
    is never silently re-pointed).

    ``base`` seeds every field not overridden here; construction
    validates ``policy`` / ``s`` / ``P`` / ``score_backend`` against
    the policy registry.
    """
    cfg = base if base is not None else SimConfig()
    repl: Dict[str, Any] = {}
    if policy is not None:
        repl["policy"] = policy
    if n_nodes is not None:
        repl["cluster"] = dataclasses.replace(cfg.cluster, n_nodes=n_nodes)
    if n_jobs is not None:
        repl["workload"] = dataclasses.replace(cfg.workload, n_jobs=n_jobs)
    if seed is not None:
        repl["seed"] = seed
    if s is not None:
        repl["s"] = s
    if P is not None:
        repl["max_preemptions"] = P
    if score_backend is not None:
        repl["score_backend"] = score_backend
    if backfill is not None:
        repl["backfill"] = backfill
    return dataclasses.replace(cfg, **repl) if repl else cfg


def _run_reference(cfg: SimConfig, js: JobSet, mode: str, trace: bool):
    res = simulator.simulate(cfg, js, mode=mode, trace=trace)
    return (metrics.slowdown_table(res), metrics.resched_table(res),
            res.preempted_fraction(), int(res.makespan), res,
            res.trace, 0, 0)


def _run_jax(cfg: SimConfig, js: JobSet, mode: str, trace: bool,
             trace_capacity: Optional[int]):
    jobs = sim_jax.jobs_from_jobset(js)
    st = sim_jax.run_jit(cfg, jobs, cfg.seed, time_mode=mode,
                         trace=trace, trace_capacity=trace_capacity)
    summary = sim_jax.result_summary(jobs, st)
    table = {k: {p: float(v) for p, v in summary[k].items()}
             for k in ("TE", "BE")}
    intervals = {p: float(v) for p, v in summary["intervals"].items()}
    events, overflow = (None, 0)
    if trace:
        events, overflow = sim_jax.decode_trace(st)
    return (table, intervals, float(summary["preempted_frac"]),
            int(st.t), (jobs, st), events, int(overflow),
            int(summary["fallback_count"]))


def run_experiment(scenario: str = DEFAULT_SCENARIO,
                   policy: Optional[str] = None,
                   engine: str = "reference", *,
                   cfg: Optional[SimConfig] = None,
                   jobs: Optional[JobSet] = None,
                   n_jobs: Optional[int] = None,
                   n_nodes: Optional[int] = None,
                   seed: Optional[int] = None,
                   s: Optional[float] = None,
                   P: Optional[int] = None,
                   score_backend: Optional[str] = None,
                   backfill: Optional[bool] = None,
                   mode: Optional[str] = None,
                   trace: bool = False,
                   trace_capacity: Optional[int] = None) -> ExperimentResult:
    """Run one (scenario, policy) experiment on the chosen engine.

    Any registered policy runs on any registered scenario through
    either engine with no engine edits — policies declare their
    backends once in ``core/policies.py``. ``jobs`` short-circuits the
    scenario build (e.g. to share one JobSet across policies);
    ``mode`` ("event" | "tick", default ``cfg.time_mode`` — like every
    other entry point) selects the time advancement on BOTH engines
    (results are bit-identical either way; "event" compresses no-op
    ticks — reference DESIGN.md §4, JAX §7). Engine-native output is
    in ``.raw``.

    ``trace=True`` records the canonical scheduler-event stream
    (``obs.schema.Event``) into ``.events`` — via driver hooks on the
    reference engine, via the in-jit ring buffer on the JAX engine
    (decoded post-run; ``.trace_overflow`` counts any dropped rows,
    ``trace_capacity`` overrides the auto-sized ring). Feed ``.events``
    to ``obs.export.write_trace`` (Perfetto / CSV) or
    ``obs.timeseries`` (utilization, queue depth, slowdown
    decomposition). DESIGN.md §8.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    if mode not in (None, "event", "tick"):
        raise ValueError(f"unknown mode {mode!r}; one of ('event', 'tick')")
    cfg = make_config(policy, base=cfg, n_jobs=n_jobs, n_nodes=n_nodes,
                      seed=seed, s=s, P=P, score_backend=score_backend,
                      backfill=backfill)
    if mode is None:
        mode = cfg.time_mode
    js = scenarios.build(scenario, cfg) if jobs is None else jobs
    if engine == "reference":
        (table, intervals, pf, makespan, raw, events, overflow,
         fallback) = _run_reference(cfg, js, mode, trace)
    else:
        (table, intervals, pf, makespan, raw, events, overflow,
         fallback) = _run_jax(cfg, js, mode, trace, trace_capacity)
    return ExperimentResult(
        scenario=scenario, policy=cfg.policy, engine=engine, cfg=cfg,
        table=table, intervals=intervals, preempted_frac=pf,
        makespan=makespan, raw=raw, events=events,
        trace_overflow=overflow, fallback_count=fallback)


def run_stream(scenario: str = DEFAULT_SCENARIO,
               policy: Optional[str] = None, *,
               cfg: Optional[SimConfig] = None,
               source=None,
               capacity: Optional[int] = None,
               n_jobs: Optional[int] = None,
               n_nodes: Optional[int] = None,
               seed: Optional[int] = None,
               mode: Optional[str] = None,
               trace: bool = False,
               trace_capacity: Optional[int] = None,
               admission=None) -> ExperimentResult:
    """Replay a scenario through the streaming macro-round engine
    (``core/stream``, DESIGN.md §10) — bounded memory, arbitrary trace
    length, results bit-identical to ``engine="jax"`` on the same
    workload.

    The workload comes from the scenario's registered streaming
    ``source`` (trace readers / chunked generators; scenarios without
    one fall back to a chunked view of the built JobSet), or from an
    explicit ``source`` (a ``core.stream.JobSource``). ``capacity``
    bounds in-flight jobs — memory scales with it, not with the trace
    (default ``stream.default_capacity(cfg)``). ``admission`` turns on
    closed-loop arrivals (paper §4.2): the source's submit times are
    re-stamped as admit ticks holding the FIFO-normalized backlog at
    ``cfg.workload.load`` (``admission=True``) or at an explicit float
    target — the streamed twin of the registry's closed-loop
    scenarios. ``.raw`` holds the
    :class:`repro.core.stream.StreamResult` (per-job arrays, round
    count, peak live jobs, spill counters); ``.events`` the
    gid-remapped canonical stream when traced.
    """
    from repro.core import stream
    if mode not in (None, "event", "tick"):
        raise ValueError(f"unknown mode {mode!r}; one of ('event', 'tick')")
    cfg = make_config(policy, base=cfg, n_jobs=n_jobs, n_nodes=n_nodes,
                      seed=seed)
    if mode is None:
        mode = cfg.time_mode
    if source is None:
        source = scenarios.get_source(scenario, cfg)
    eng = stream.StreamEngine(cfg, source, capacity=capacity,
                              time_mode=mode, trace=trace,
                              trace_capacity=trace_capacity,
                              admission=admission)
    res = eng.run()
    summary = res.summary()
    table = {k: {p: float(v) for p, v in summary[k].items()}
             for k in ("TE", "BE")}
    intervals = {p: float(v) for p, v in summary["intervals"].items()}
    return ExperimentResult(
        scenario=scenario, policy=cfg.policy, engine="stream", cfg=cfg,
        table=table, intervals=intervals,
        preempted_frac=float(summary["preempted_frac"]),
        makespan=res.makespan, raw=res, events=res.events,
        trace_overflow=res.trace_overflow,
        fallback_count=res.fallback_count)


def compare_policies(policies, scenario: str = DEFAULT_SCENARIO,
                     engine: str = "reference",
                     **kw) -> Dict[str, ExperimentResult]:
    """Run several policies on ONE shared JobSet (Table 1 shape).

    The scenario is built once from the first policy's config — every
    registered scenario derives its jobset from ``cfg.seed`` /
    ``cfg.workload`` / ``cfg.cluster`` only, so the comparison is
    apples-to-apples by construction.
    """
    policies = list(policies)
    cfg0 = make_config(policies[0], base=kw.get("cfg"),
                       n_jobs=kw.get("n_jobs"), n_nodes=kw.get("n_nodes"),
                       seed=kw.get("seed"))
    js = scenarios.build(scenario, cfg0)
    return {p: run_experiment(scenario, p, engine, jobs=js, **kw)
            for p in policies}
