from repro.data.pipeline import make_batch, make_eval_batch

__all__ = ["make_batch", "make_eval_batch"]
