"""Deterministic synthetic data pipeline.

Stateless: batch ``i`` of any (cfg, seed) is a pure function of
``fold_in(seed, i)``, so every data-parallel shard can generate its slice
independently (shard via sharding constraints on the returned batch) and
a preempted job resumes mid-stream with no data-order drift — which is
exactly the property checkpoint-resume preemption (the paper's GP
mechanism) needs from a pipeline.

Tokens follow a Zipf-ish distribution over the vocab so losses have
realistic structure (uniform tokens make CE flat at log V).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _zipf_tokens(key: jax.Array, shape, vocab: int) -> jax.Array:
    """Zipf(1.0)-distributed token ids via inverse-CDF on u^alpha."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    # rank ~ exp(u * log V) gives p(rank) ~ 1/rank
    r = jnp.exp(u * jnp.log(float(vocab))) - 1.0
    return jnp.clip(r.astype(jnp.int32), 0, vocab - 1)


def make_batch(cfg: ModelConfig, batch: int, seq_len: int, seed: int,
               step) -> dict:
    """One training batch for any model family."""
    key = jax.random.fold_in(jax.random.key(seed), step)
    k1, k2 = jax.random.split(key)
    if cfg.family == "audio":
        F = cfg.encoder.n_frontend_tokens
        dec = max(seq_len - F, 8)
        return {
            "audio_embeds": jax.random.normal(
                k1, (batch, F, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.3,
            "tokens": _zipf_tokens(k2, (batch, dec), cfg.vocab),
        }
    if cfg.family == "vlm":
        nv = cfg.vlm.n_visual_tokens
        txt = max(seq_len - nv, 8)
        return {
            "visual_embeds": jax.random.normal(
                k1, (batch, nv, cfg.vlm.d_visual), jnp.dtype(cfg.dtype)) * 0.3,
            "tokens": _zipf_tokens(k2, (batch, txt), cfg.vocab),
        }
    return {"tokens": _zipf_tokens(k1, (batch, seq_len), cfg.vocab)}


def make_eval_batch(cfg: ModelConfig, batch: int, seq_len: int,
                    seed: int = 1234) -> dict:
    return make_batch(cfg, batch, seq_len, seed, step=0)
