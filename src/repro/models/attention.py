"""GQA attention: query-chunked reference path + KV-cache utilities.

The pure-jnp path is the XLA/dry-run implementation (Pallas TPU kernels
cannot lower on the CPU container backend); ``repro.kernels.ops`` provides
the TPU flash kernel with identical semantics, selected via
``REPRO_ATTN_IMPL=pallas``. Memory behaviour of the jnp path matches the
flash kernel's O(S) footprint by scanning over query blocks instead of
materializing the full (Sq, Skv) score matrix.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common

_NEG_INF = -1e30


def _impl() -> str:
    return os.environ.get("REPRO_ATTN_IMPL", "jnp")


def _scores_softmax_pv(q, k, v, mask, softcap_val):
    """q: (B, Sq, KV, QpK, hd); k/v: (B, Skv, KV, hd); mask (B?, Sq, Skv)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = common.softcap(logits, softcap_val)
    m = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    logits = jnp.where(m, logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out


def attend(
    q: jax.Array,                 # (B, Sq, H, hd)
    k: jax.Array,                 # (B, Skv, KV, hd)
    v: jax.Array,                 # (B, Skv, KV, hd)
    *,
    mask: jax.Array,              # (Sq, Skv) or (B, Sq, Skv) bool
    softcap_val: float = 0.0,
    q_chunk: int = 1024,
    causal: Optional[bool] = None,   # semantic hints enabling the Pallas
    window: int = 0,                 # kernel path (mask stays the oracle)
) -> jax.Array:
    """Grouped-query attention. Returns (B, Sq, H, hd).

    Scans over query chunks so peak memory is O(q_chunk * Skv), matching
    the flash kernel's footprint class instead of O(Sq * Skv).

    When ``REPRO_ATTN_IMPL=pallas`` and the caller supplied the semantic
    hints (``causal``/``window`` describing ``mask``), dispatches to the
    TPU flash kernel instead of the jnp path.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, Sq, KV, H // KV, hd)

    if _impl() == "pallas" and Sq > 1 and causal is not None:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    softcap=softcap_val)

    if Sq <= q_chunk or Sq % q_chunk != 0:
        out = _scores_softmax_pv(qg, k, v, mask, softcap_val)
        return out.reshape(B, Sq, H, hd)

    n = Sq // q_chunk
    qs = qg.reshape(B, n, q_chunk, KV, H // KV, hd).swapaxes(0, 1)
    if mask.ndim == 2:
        ms = mask.reshape(n, q_chunk, mask.shape[-1])
    else:
        ms = mask.reshape(B, n, q_chunk, mask.shape[-1]).swapaxes(0, 1)

    def step(_, qm):
        qc, mc = qm
        return None, _scores_softmax_pv(qc, k, v, mc, softcap_val)

    _, outs = common.scan(step, None, (qs, ms))
    out = outs.swapaxes(0, 1).reshape(B, Sq, KV, H // KV, hd)
    return out.reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------
# A cache is a dict pytree:
#   k, v     : (L, B, S_cache, KV, hd)
#   kv_pos   : (S_cache,) int32 — absolute position held by each slot,
#              -1 if empty. Shared across layers/batch (all sequences in a
#              batch advance in lockstep for our serving model).
#   next_pos : () int32 — absolute position of the NEXT token to write.
# For a full cache S_cache == max_len and slot i holds position i.
# For a ring (sliding-window) cache S_cache == window and slot
# (pos % window) holds position pos.


def init_cache(n_layers: int, batch: int, cache_len: int, n_kv: int,
               head_dim: int, dtype) -> dict:
    return {
        "k": jnp.zeros((n_layers, batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, cache_len, n_kv, head_dim), dtype),
        "kv_pos": jnp.full((cache_len,), -1, jnp.int32),
        "next_pos": jnp.zeros((), jnp.int32),
    }


def abstract_cache(n_layers: int, batch: int, cache_len: int, n_kv: int,
                   head_dim: int, dtype) -> dict:
    s = jax.ShapeDtypeStruct
    d = jnp.dtype(dtype)
    return {
        "k": s((n_layers, batch, cache_len, n_kv, head_dim), d),
        "v": s((n_layers, batch, cache_len, n_kv, head_dim), d),
        "kv_pos": s((cache_len,), jnp.int32),
        "next_pos": s((), jnp.int32),
    }


def cache_logical_specs() -> dict:
    """Logical axes for cache leaves (see sharding/plans.py)."""
    return {
        "k": ("layers", "cache_batch", "cache_seq", "kv", "head_dim"),
        "v": ("layers", "cache_batch", "cache_seq", "kv", "head_dim"),
        "kv_pos": (None,),
        "next_pos": (),
    }


def cache_write_slot(cache_len: int, pos: jax.Array, ring: bool) -> jax.Array:
    return jnp.where(ring, pos % cache_len, pos) if isinstance(ring, jax.Array) \
        else (pos % cache_len if ring else pos)


def decode_mask(q_pos: jax.Array, kv_pos: jax.Array,
                window: int = 0) -> jax.Array:
    """Mask for one-token decode. q_pos (), kv_pos (S,). Returns (1, S)."""
    m = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window > 0:
        m &= kv_pos > q_pos - window
    return m[None, :]


def update_layer_cache(k_l: jax.Array, v_l: jax.Array, new_k: jax.Array,
                       new_v: jax.Array, slot: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Write one token's (B, 1, KV, hd) into layer cache (B, S, KV, hd)."""
    k_l = jax.lax.dynamic_update_slice(k_l, new_k.astype(k_l.dtype),
                                       (0, slot, 0, 0))
    v_l = jax.lax.dynamic_update_slice(v_l, new_v.astype(v_l.dtype),
                                       (0, slot, 0, 0))
    return k_l, v_l
