"""Dense decoder-only LM (command-r, stablelm, nemotron-4, mistral-large).

Pre-norm GQA transformer with RoPE, gated or plain MLP, scan-over-layers
with configurable remat. Also exports the layer building blocks reused by
the MoE and VLM models.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common
from repro.models.common import ParamDef


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, L: int) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "attn_norm": ParamDef((L, D), ("layers", "embed"), init="zeros"),
        "wq": ParamDef((L, D, H, hd), ("layers", "embed", "heads", "head_dim")),
        "wk": ParamDef((L, D, KV, hd), ("layers", "embed", "kv", "head_dim")),
        "wv": ParamDef((L, D, KV, hd), ("layers", "embed", "kv", "head_dim")),
        "wo": ParamDef((L, H, hd, D), ("layers", "heads", "head_dim", "embed")),
    }


def mlp_defs(cfg: ModelConfig, L: int) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    defs = {
        "mlp_norm": ParamDef((L, D), ("layers", "embed"), init="zeros"),
        "w_up": ParamDef((L, D, F), ("layers", "embed", "mlp")),
        "w_down": ParamDef((L, F, D), ("layers", "mlp", "embed")),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((L, D, F), ("layers", "embed", "mlp"))
    return defs


def param_defs(cfg: ModelConfig) -> dict:
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=0.02),
        "final_norm": ParamDef((D,), ("embed",), init="zeros"),
        "layers": {**attn_defs(cfg, L), **mlp_defs(cfg, L)},
    }
    if not cfg.tie_embeddings:
        defs["out_head"] = ParamDef((D, V), ("embed", "vocab"))
    return defs


def init(cfg: ModelConfig, rng: jax.Array):
    return common.materialize(param_defs(cfg), rng, cfg.dtype)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def attn_block(cfg: ModelConfig, lp: dict, x: jax.Array,
               positions: jax.Array, mask: jax.Array,
               window: int = None,
               ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention sublayer. x (B, S, D). Returns (out, (k, v)).

    ``window`` defaults to ``cfg.window``; hybrid passes its local window.
    """
    if window is None:
        window = cfg.window
    from repro.sharding.constraints import BATCH, SEQ, constrain
    h = common.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dnh->bsnh", h, lp["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", h, lp["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", h, lp["wv"])
    q = constrain(q, BATCH, None, "model", None)
    q = common.rope(q, positions, cfg.rope_theta)
    k = common.rope(k, positions, cfg.rope_theta)
    o = attention.attend(q, k, v, mask=mask, causal=True, window=window)
    o = constrain(o, BATCH, None, "model", None)
    out = jnp.einsum("bsnh,nhd->bsd", o, lp["wo"])
    out = constrain(out, BATCH, SEQ, None)
    return out, (k, v)


def attn_decode_block(cfg: ModelConfig, lp: dict, x: jax.Array,
                      k_cache: jax.Array, v_cache: jax.Array,
                      pos: jax.Array, slot: jax.Array, mask: jax.Array,
                      ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token attention. x (B, 1, D); caches (B, S, KV, hd)."""
    h = common.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dnh->bsnh", h, lp["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", h, lp["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", h, lp["wv"])
    posv = jnp.full((1,), 0, jnp.int32) + pos
    q = common.rope(q, posv, cfg.rope_theta)
    k = common.rope(k, posv, cfg.rope_theta)
    k_cache, v_cache = attention.update_layer_cache(k_cache, v_cache, k, v, slot)
    o = attention.attend(q, k_cache, v_cache, mask=mask)
    out = jnp.einsum("bsnh,nhd->bsd", o, lp["wo"])
    return out, (k_cache, v_cache)


def mlp_block(cfg: ModelConfig, lp: dict, x: jax.Array) -> jax.Array:
    from repro.sharding.constraints import BATCH, SEQ, constrain
    h = common.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", h, lp["w_up"])
    up = constrain(up, BATCH, None, "model")
    if cfg.gated_mlp:
        gate = jnp.einsum("bsd,df->bsf", h, lp["w_gate"])
        act = common.activate(gate, cfg.activation) * up
    else:
        act = common.activate(up, cfg.activation)
    out = jnp.einsum("bsf,fd->bsd", act, lp["w_down"])
    return constrain(out, BATCH, SEQ, None)


def _layer(cfg: ModelConfig, x, lp, positions, mask):
    a, kv = attn_block(cfg, lp, x, positions, mask)
    x = x + a
    x = x + mlp_block(cfg, lp, x)
    return x, kv


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return fn


def _stack(cfg: ModelConfig, x, layers, positions, mask, collect_kv: bool):
    body = _maybe_remat(
        cfg, functools.partial(_layer, cfg, positions=positions, mask=mask))

    def step(h, lp):
        h, kv = body(h, lp)
        return h, kv if collect_kv else None

    x, kvs = common.scan(step, x, layers)
    return x, kvs


def unembed(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["out_head"])
    return common.softcap(logits, cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Public model API
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """Training/scoring forward. tokens (B, S) -> logits (B, S, V)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(S)
    mask = common.causal_mask(S, S, window=cfg.window)
    x, _ = _stack(cfg, x, params["layers"], positions, mask, collect_kv=False)
    return unembed(cfg, params, x)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward(cfg, params, batch["tokens"])
    return common.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            pad_to: int = 0) -> Tuple[jax.Array, dict]:
    """Build a KV cache from a prompt. Returns (last-token logits, cache).

    ``pad_to`` reserves cache room for subsequent decode steps.
    """
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(S)
    mask = common.causal_mask(S, S, window=cfg.window)
    x, kvs = _stack(cfg, x, params["layers"], positions, mask, collect_kv=True)
    logits = unembed(cfg, params, x[:, -1:])
    k, v = kvs
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    if pad_to > S:
        pad = [(0, 0), (0, 0), (0, pad_to - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        kv_pos = jnp.concatenate(
            [kv_pos, jnp.full((pad_to - S,), -1, jnp.int32)])
    cache = {"k": k, "v": v, "kv_pos": kv_pos,
             "next_pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def init_decode_cache(cfg: ModelConfig, batch: int, context_len: int,
                      abstract: bool = False) -> dict:
    """Cache for serve_step. Ring buffer of the window size when the arch
    has sliding-window attention; else full ``context_len``.

    Note: ``cfg.decode_window`` (the beyond-paper long-context variant) is
    applied by the *launcher* via ``cfg.replace(window=cfg.decode_window)``
    for the ``long_500k`` shape only — this module honours ``cfg.window``.
    """
    w = min(cfg.window, context_len) if cfg.window > 0 else 0
    cache_len = w if w > 0 else context_len
    fn = attention.abstract_cache if abstract else attention.init_cache
    return fn(cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.head_dim,
              jnp.dtype(cfg.dtype))


def serve_step(cfg: ModelConfig, params: dict, cache: dict,
               tokens: jax.Array) -> Tuple[jax.Array, dict]:
    """Decode ONE token. tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    B, _ = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    pos = cache["next_pos"]
    cache_len = cache["k"].shape[2]
    w = cfg.window   # 0 = full attention (see init_decode_cache docstring)
    # Ring buffer only when the cache was allocated at exactly the window
    # size (init_decode_cache); a prefill-padded full cache writes at pos.
    ring = w > 0 and cache_len == w
    slot = pos % cache_len if ring else pos
    kv_pos = cache["kv_pos"].at[slot].set(pos)   # current token attends to itself
    mask = attention.decode_mask(pos, kv_pos, window=w)

    body = functools.partial(attn_decode_block, cfg)

    def step(h, layer_in):
        lp, k_l, v_l = layer_in
        a, (k_l, v_l) = body(lp, h, k_l, v_l, pos, slot, mask)
        h = h + a
        h = h + mlp_block(cfg, lp, h)
        return h, (k_l, v_l)

    x, (ks, vs) = common.scan(step, x,
                              (params["layers"], cache["k"], cache["v"]))
    logits = unembed(cfg, params, x)
    new_cache = {"k": ks, "v": vs, "kv_pos": kv_pos, "next_pos": pos + 1}
    return logits, new_cache
