"""Mixture-of-Experts LM (qwen3-moe-30b-a3b, mixtral-8x22b).

Attention blocks are shared with ``dense``; the MLP is replaced by a
top-k routed expert layer with capacity-based token dropping.

Dispatch is SCATTER-based (O(E·C·D) memory) rather than the textbook
dense one-hot einsum (O(T·E·C)): at production shapes the one-hot
dispatch tensor for qwen3 (4096 tokens × 128 experts × 320 capacity,
bf16) is ~336 MB *per sequence* and cannot live in HBM next to the
weights. ``moe_block_einsum`` keeps the textbook formulation as a
cross-check oracle for tests.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, dense
from repro.models.common import ParamDef


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    c = math.ceil(tokens_per_group * m.top_k / m.num_experts
                  * m.capacity_factor)
    return max(4 * math.ceil(c / 4), 4)   # pad to a multiple of 4


def moe_defs(cfg: ModelConfig, L: int) -> dict:
    D, m = cfg.d_model, cfg.moe
    E, F = m.num_experts, m.d_expert
    defs = {
        "mlp_norm": ParamDef((L, D), ("layers", "embed"), init="zeros"),
        "w_router": ParamDef((L, D, E), ("layers", "embed", None)),
        "w_up": ParamDef((L, E, D, F), ("layers", "experts", "embed", "mlp")),
        "w_down": ParamDef((L, E, F, D), ("layers", "experts", "mlp", "embed")),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((L, E, D, F),
                                  ("layers", "experts", "embed", "mlp"))
    return defs


def param_defs(cfg: ModelConfig) -> dict:
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=0.02),
        "final_norm": ParamDef((D,), ("embed",), init="zeros"),
        "layers": {**dense.attn_defs(cfg, L), **moe_defs(cfg, L)},
    }
    if not cfg.tie_embeddings:
        defs["out_head"] = ParamDef((D, V), ("embed", "vocab"))
    return defs


def init(cfg: ModelConfig, rng: jax.Array):
    return common.materialize(param_defs(cfg), rng, cfg.dtype)


# ---------------------------------------------------------------------------
# Routed expert layer
# ---------------------------------------------------------------------------

def _route(cfg: ModelConfig, lp: dict, h: jax.Array):
    """h (B, S, D) -> (gates (B,S,k), idx (B,S,k), aux_loss)."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", h, lp["w_router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss over all assignments.
    f = jnp.zeros((m.num_experts,), jnp.float32)
    f = f.at[idx.reshape(-1)].add(1.0, mode="drop")
    f = f / jnp.maximum(idx.size, 1)
    p = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(f * p)
    return gates.astype(h.dtype), idx, aux


def _expert_ffn(cfg: ModelConfig, lp: dict, xin: jax.Array) -> jax.Array:
    """xin (E, C, D) -> (E, C, D), per-expert (optionally gated) MLP."""
    up = jnp.einsum("ecd,edf->ecf", xin, lp["w_up"])
    if cfg.gated_mlp:
        gate = jnp.einsum("ecd,edf->ecf", xin, lp["w_gate"])
        act = common.activate(gate, cfg.activation) * up
    else:
        act = common.activate(up, cfg.activation)
    return jnp.einsum("ecf,efd->ecd", act, lp["w_down"])


def moe_block(cfg: ModelConfig, lp: dict, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Scatter-dispatch MoE sublayer. x (B, S, D) -> (out, aux_loss).

    Each batch row is one routing group (tokens_per_group = S).
    """
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    C = capacity(cfg, S)

    h = common.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    gates, idx, aux = _route(cfg, lp, h)

    def one_group(hb, gb, ib):
        # hb (S, D); gb/ib (S, k)
        flat_e = ib.reshape(-1)                              # (S*k,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)
        rank = (jnp.cumsum(onehot, axis=0) - 1.0)            # rank within expert
        pos = jnp.sum(rank * onehot, axis=-1).astype(jnp.int32)
        keep = pos < C
        slot = jnp.where(keep, flat_e * C + pos, E * C)      # E*C = dropped
        tok = jnp.repeat(jnp.arange(S), k)
        xin = jnp.zeros((E * C, D), hb.dtype)
        xin = xin.at[slot].add(hb[tok] * keep[:, None].astype(hb.dtype),
                               mode="drop")
        yout = _expert_ffn(cfg, lp, xin.reshape(E, C, D)).reshape(E * C, D)
        gath = yout.at[slot].get(mode="fill", fill_value=0.0)
        w = (gb.reshape(-1) * keep.astype(gb.dtype))[:, None]
        return jnp.sum((gath * w).reshape(S, k, D), axis=1)

    out = jax.vmap(one_group)(h, gates, idx)
    return out, aux


def moe_block_einsum(cfg: ModelConfig, lp: dict, x: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Textbook dense one-hot dispatch — oracle for small-shape tests."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    C = capacity(cfg, S)

    h = common.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    gates, idx, aux = _route(cfg, lp, h)

    def one_group(hb, gb, ib):
        eoh = jax.nn.one_hot(ib.reshape(-1), E, dtype=jnp.float32)  # (S*k, E)
        rank = jnp.cumsum(eoh, axis=0) * eoh - eoh
        pos = jnp.sum(rank, -1).astype(jnp.int32)                   # (S*k,)
        coh = jax.nn.one_hot(pos, C, dtype=jnp.float32)             # 0 if >= C
        a = (eoh[:, :, None] * coh[:, None, :]).reshape(S, k, E, C)
        xin = jnp.einsum("skec,sd->ecd", a, hb.astype(jnp.float32))
        yout = _expert_ffn(cfg, lp, xin.astype(hb.dtype))
        comb = jnp.einsum("skec,sk->sec", a, gb.astype(jnp.float32))
        return jnp.einsum("sec,ecd->sd", comb,
                          yout.astype(jnp.float32)).astype(hb.dtype)

    out = jax.vmap(one_group)(h, gates, idx)
    return out, aux


# ---------------------------------------------------------------------------
# Model API (mirrors dense)
# ---------------------------------------------------------------------------

def _stack(cfg: ModelConfig, x, layers, positions, mask, collect_kv: bool):
    def block(carry, lp):
        h, aux = carry
        a, kv = dense.attn_block(cfg, lp, h, positions, mask)
        h = h + a
        mo, la = moe_block(cfg, lp, h)
        return (h + mo, aux + la), kv if collect_kv else None

    body = dense._maybe_remat(cfg, block)
    (x, aux), kvs = common.scan(body, (x, jnp.zeros((), jnp.float32)),
                                layers)
    return x, aux, kvs


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            return_aux: bool = False):
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(S)
    mask = common.causal_mask(S, S, window=cfg.window)
    x, aux, _ = _stack(cfg, x, params["layers"], positions, mask, False)
    logits = dense.unembed(cfg, params, x)
    return (logits, aux) if return_aux else logits


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits, aux = forward(cfg, params, batch["tokens"], return_aux=True)
    ce = common.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
    return ce + cfg.moe.router_aux_weight * aux / cfg.n_layers


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            pad_to: int = 0) -> Tuple[jax.Array, dict]:
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(S)
    mask = common.causal_mask(S, S, window=cfg.window)
    x, _, kvs = _stack(cfg, x, params["layers"], positions, mask, True)
    logits = dense.unembed(cfg, params, x[:, -1:])
    k, v = kvs
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    if pad_to > S:
        pad = [(0, 0), (0, 0), (0, pad_to - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        kv_pos = jnp.concatenate(
            [kv_pos, jnp.full((pad_to - S,), -1, jnp.int32)])
    return logits, {"k": k, "v": v, "kv_pos": kv_pos,
                    "next_pos": jnp.asarray(S, jnp.int32)}


init_decode_cache = dense.init_decode_cache


def serve_step(cfg: ModelConfig, params: dict, cache: dict,
               tokens: jax.Array) -> Tuple[jax.Array, dict]:
    B, _ = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    pos = cache["next_pos"]
    cache_len = cache["k"].shape[2]
    w = cfg.window
    ring = w > 0 and cache_len == w
    slot = pos % cache_len if ring else pos
    kv_pos = cache["kv_pos"].at[slot].set(pos)
    mask = attention.decode_mask(pos, kv_pos, window=w)

    def step(h, layer_in):
        lp, k_l, v_l = layer_in
        a, (k_l, v_l) = dense.attn_decode_block(cfg, lp, h, k_l, v_l,
                                                pos, slot, mask)
        h = h + a
        mo, _ = moe_block(cfg, lp, h)
        return h + mo, (k_l, v_l)

    x, (ks, vs) = common.scan(step, x,
                              (params["layers"], cache["k"], cache["v"]))
    logits = dense.unembed(cfg, params, x)
    return logits, {"k": ks, "v": vs, "kv_pos": kv_pos, "next_pos": pos + 1}
