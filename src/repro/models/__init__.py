"""Model registry: family -> module, plus uniform abstract/spec helpers."""
from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

_FAMILY_MODULES = {
    "dense": "dense",
    "moe": "moe",
    "ssm": "ssm",
    "hybrid": "hybrid",
    "audio": "whisper",
    "vlm": "vlm",
}


def get_module(cfg: ModelConfig):
    return importlib.import_module(
        f"repro.models.{_FAMILY_MODULES[cfg.family]}")


def init(cfg: ModelConfig, rng: jax.Array):
    return get_module(cfg).init(cfg, rng)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct tree for dry-run lowering — no allocation."""
    from repro.models import common
    return common.abstract(get_module(cfg).param_defs(cfg), cfg.dtype)


def param_logical_specs(cfg: ModelConfig):
    from repro.models import common
    return common.logical_specs(get_module(cfg).param_defs(cfg))


def count_params(cfg: ModelConfig) -> int:
    from repro.models import common
    return common.count_params(get_module(cfg).param_defs(cfg))


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    return get_module(cfg).loss_fn(cfg, params, batch)


def forward(cfg: ModelConfig, params, batch):
    mod = get_module(cfg)
    if cfg.family in ("audio", "vlm"):
        return mod.forward(cfg, params, batch)
    return mod.forward(cfg, params, batch["tokens"])


def prefill(cfg: ModelConfig, params, batch, pad_to: int = 0):
    mod = get_module(cfg)
    if cfg.family in ("audio", "vlm"):
        return mod.prefill(cfg, params, batch, pad_to=pad_to)
    return mod.prefill(cfg, params, batch["tokens"], pad_to=pad_to)


def serve_step(cfg: ModelConfig, params, cache, tokens):
    return get_module(cfg).serve_step(cfg, params, cache, tokens)


def init_decode_cache(cfg: ModelConfig, batch: int, context_len: int,
                      abstract: bool = False):
    return get_module(cfg).init_decode_cache(cfg, batch, context_len,
                                             abstract=abstract)


def cache_logical_specs(cfg: ModelConfig):
    from repro.models import attention
    mod = get_module(cfg)
    if hasattr(mod, "cache_logical_specs"):
        return mod.cache_logical_specs()
    return attention.cache_logical_specs()


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins; also shapes for the data pipeline)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, batch: int, seq_len: int,
                kind: str) -> dict:
    """ShapeDtypeStructs for one step's inputs.

    ``kind``: train | prefill -> full batch dict; decode -> one token
    (the cache is built separately via ``init_decode_cache(abstract=)``).

    For audio/vlm the modality frontend is stubbed: the spec hands the
    model precomputed frame/patch embeddings of the right shape, and the
    declared ``seq_len`` covers frontend tokens + text tokens.
    """
    s = jax.ShapeDtypeStruct
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if kind == "decode":
        return {"tokens": s((batch, 1), i32)}
    if cfg.family == "audio":
        F = cfg.encoder.n_frontend_tokens
        dec = max(seq_len - F, 8)
        return {"audio_embeds": s((batch, F, cfg.d_model), f),
                "tokens": s((batch, dec), i32)}
    if cfg.family == "vlm":
        nv = cfg.vlm.n_visual_tokens
        txt = max(seq_len - nv, 8)
        return {"visual_embeds": s((batch, nv, cfg.vlm.d_visual), f),
                "tokens": s((batch, txt), i32)}
    return {"tokens": s((batch, seq_len), i32)}
