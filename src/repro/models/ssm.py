"""Mamba-2 (SSD — state-space duality) LM. Attention-free.

Chunked SSD forward (arXiv:2405.21060 §6): within-chunk quadratic dual
form + inter-chunk linear recurrence, both expressed with jnp einsums and
``lax`` scans so XLA/SPMD can shard (batch→data, heads→model). The
per-chunk quadratic term is the Pallas ``ssd_chunk`` kernel's oracle.

Decode keeps a constant-size recurrent state — this is the native
sub-quadratic path that legitimizes ``long_500k`` for this arch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import ParamDef


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.n_groups, s.d_state


def param_defs(cfg: ModelConfig) -> dict:
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    d_inner, H, P, G, N = _dims(cfg)
    s = cfg.ssm
    layers = {
        "norm": ParamDef((L, D), ("layers", "embed"), init="zeros"),
        "w_z": ParamDef((L, D, d_inner), ("layers", "embed", "mlp")),
        "w_x": ParamDef((L, D, d_inner), ("layers", "embed", "mlp")),
        "w_B": ParamDef((L, D, G * N), ("layers", "embed", None)),
        "w_C": ParamDef((L, D, G * N), ("layers", "embed", None)),
        "w_dt": ParamDef((L, D, H), ("layers", "embed", "heads")),
        "conv_x": ParamDef((L, s.d_conv, d_inner), ("layers", None, "mlp"),
                           scale=0.5),
        "conv_B": ParamDef((L, s.d_conv, G * N), ("layers", None, None),
                           scale=0.5),
        "conv_C": ParamDef((L, s.d_conv, G * N), ("layers", None, None),
                           scale=0.5),
        "dt_bias": ParamDef((L, H), ("layers", "heads"), init="zeros"),
        "A_log": ParamDef((L, H), ("layers", "heads"), init="zeros"),
        "D": ParamDef((L, H), ("layers", "heads"), init="ones"),
        "gn": ParamDef((L, d_inner), ("layers", "mlp"), init="zeros"),
        "w_out": ParamDef((L, d_inner, D), ("layers", "mlp", "embed")),
    }
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=0.02),
        "final_norm": ParamDef((D,), ("embed",), init="zeros"),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        defs["out_head"] = ParamDef((D, V), ("embed", "vocab"))
    return defs


def init(cfg: ModelConfig, rng: jax.Array):
    return common.materialize(param_defs(cfg), rng, cfg.dtype)


# ---------------------------------------------------------------------------
# Causal depthwise conv as shifted sums (shardable on the channel dim)
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array,
                init_state: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """x (B, L, C), w (W, C). Returns (y (B, L, C), final (B, W-1, C))."""
    B, L, C = x.shape
    W = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + xp[:, i:i + L] * w[i]
    return y, xp[:, L:]


def conv_step(x_t: jax.Array, w: jax.Array, state: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """One-token conv. x_t (B, C); state (B, W-1, C)."""
    xp = jnp.concatenate([state, x_t[:, None]], axis=1)   # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", xp, w)
    return y, xp[:, 1:]


# ---------------------------------------------------------------------------
# SSD scan (chunked dual form)
# ---------------------------------------------------------------------------

def segsum(loga: jax.Array) -> jax.Array:
    """loga (..., q) -> (..., q, q): T[i, j] = sum_{j<k<=i}, -inf for j>i."""
    q = loga.shape[-1]
    z = jnp.cumsum(loga, axis=-1)
    T = z[..., :, None] - z[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, T, -jnp.inf)


def ssd_scan(xdt: jax.Array, loga: jax.Array, Bm: jax.Array, Cm: jax.Array,
             chunk: int, init_state: jax.Array = None,
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. xdt (B,L,H,P) = dt*x; loga (B,L,H); Bm/Cm (B,L,G,N).

    Recurrence per head: h_t = exp(loga_t) h_{t-1} + xdt_t ⊗ B_t,
    y_t = C_t · h_t. Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    Bsz, L, H, P = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0, (L, chunk)
    c, q = L // chunk, chunk
    rep = H // G

    xc = xdt.reshape(Bsz, c, q, H, P)
    lc = loga.reshape(Bsz, c, q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, c, q, G, N)
    Cc = Cm.reshape(Bsz, c, q, G, N)
    # expand groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)            # (B,c,q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    zc = jnp.cumsum(lc, axis=2)                  # within-chunk cumsum
    # ---- intra-chunk (quadratic dual form) ----
    Lmat = jnp.exp(segsum(lc.transpose(0, 3, 1, 2)))   # (B,H,c,q,q)
    scores = jnp.einsum("bcqhn,bcshn->bhcqs", Ch, Bh)
    y_diag = jnp.einsum("bhcqs,bhcqs,bcshp->bcqhp",
                        scores, Lmat, xc.astype(jnp.float32))
    # ---- chunk states ----
    decay = jnp.exp(zc[:, :, -1:, :] - zc)       # (B,c,q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        Bh.astype(jnp.float32), decay,
                        xc.astype(jnp.float32))
    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(zc[:, :, -1, :])       # (B,c,H)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)
    else:
        init_state = init_state.astype(jnp.float32)

    def scan_fn(h, inp):
        st, dc = inp                              # (B,H,P,N), (B,H)
        prev = h
        h = h * dc[..., None, None] + st
        return h, prev

    final, prev_states = jax.lax.scan(
        scan_fn, init_state,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)      # (B,c,H,P,N)
    # ---- off-diagonal (carry-in) contribution ----
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Ch.astype(jnp.float32), prev_states, jnp.exp(zc))
    y = (y_diag + y_off).reshape(Bsz, L, H, P).astype(xdt.dtype)
    return y, final.astype(xdt.dtype)


def ssd_step(state: jax.Array, x_t: jax.Array, dt: jax.Array,
             A_log: jax.Array, B_t: jax.Array, C_t: jax.Array,
             ) -> Tuple[jax.Array, jax.Array]:
    """One decode step. state (B,H,P,N); x_t (B,H,P); dt (B,H);
    B_t/C_t (B,G,N). Returns (y (B,H,P), new state)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)            # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1)
    a = jnp.exp(-jnp.exp(A_log.astype(jnp.float32)) * dt.astype(jnp.float32))
    xdt = x_t * dt[..., None].astype(x_t.dtype)
    sf = state.astype(jnp.float32)
    sf = sf * a[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xdt.astype(jnp.float32), Bh.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", sf, Ch.astype(jnp.float32))
    return y.astype(x_t.dtype), sf.astype(state.dtype)


# ---------------------------------------------------------------------------
# Block + model API
# ---------------------------------------------------------------------------

def _proj(cfg, lp, x):
    """Shared projections. x (B,L,D) -> z, xh, B, C, dt (pre-conv/softplus)."""
    z = jnp.einsum("bld,di->bli", x, lp["w_z"])
    xh = jnp.einsum("bld,di->bli", x, lp["w_x"])
    Bm = jnp.einsum("bld,di->bli", x, lp["w_B"])
    Cm = jnp.einsum("bld,di->bli", x, lp["w_C"])
    dt = jnp.einsum("bld,dh->blh", x, lp["w_dt"])
    return z, xh, Bm, Cm, dt


def _gated_out(cfg, lp, y, z):
    d_inner = y.shape[-1]
    g = y * jax.nn.silu(z)
    g = common.rms_norm(g, lp["gn"], cfg.norm_eps)
    return jnp.einsum("bli,id->bld", g, lp["w_out"])


def ssm_block(cfg: ModelConfig, lp: dict, x: jax.Array,
              conv_state=None, ssm_state=None, collect_state=False):
    """Full-sequence Mamba-2 mixer. x (B, L, D)."""
    d_inner, H, P, G, N = _dims(cfg)
    h = common.rms_norm(x, lp["norm"], cfg.norm_eps)
    z, xh, Bm, Cm, dt = _proj(cfg, lp, h)
    cs_x = cs_B = cs_C = None
    xh, cs_x = causal_conv(xh, lp["conv_x"],
                           None if conv_state is None else conv_state["x"])
    Bm, cs_B = causal_conv(Bm, lp["conv_B"],
                           None if conv_state is None else conv_state["B"])
    Cm, cs_C = causal_conv(Cm, lp["conv_C"],
                           None if conv_state is None else conv_state["C"])
    xh, Bm, Cm = jax.nn.silu(xh), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    loga = -jnp.exp(lp["A_log"].astype(jnp.float32)) * dt    # (B,L,H)

    Bsz, L, _ = x.shape
    xheads = xh.reshape(Bsz, L, H, P)
    xdt = xheads * dt[..., None].astype(xheads.dtype)
    Bmr, Cmr = Bm.reshape(Bsz, L, G, N), Cm.reshape(Bsz, L, G, N)
    pad = (-L) % cfg.ssm.chunk
    if pad:
        # zero inputs + zero log-decay leave the carried state untouched
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        Bmr = jnp.pad(Bmr, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmr = jnp.pad(Cmr, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, final = ssd_scan(xdt, loga, Bmr, Cmr,
                        cfg.ssm.chunk, init_state=ssm_state)
    y = y[:, :L]
    y = y + xheads * lp["D"][None, None, :, None].astype(xheads.dtype)
    out = _gated_out(cfg, lp, y.reshape(Bsz, L, d_inner), z)
    if collect_state:
        return out, ({"x": cs_x, "B": cs_B, "C": cs_C}, final)
    return out, None


def ssm_decode_block(cfg: ModelConfig, lp: dict, x: jax.Array,
                     conv_state: dict, ssm_state: jax.Array):
    """One-token mixer. x (B, 1, D)."""
    d_inner, H, P, G, N = _dims(cfg)
    h = common.rms_norm(x, lp["norm"], cfg.norm_eps)
    z, xh, Bm, Cm, dt = _proj(cfg, lp, h)
    xh1, cs_x = conv_step(xh[:, 0], lp["conv_x"], conv_state["x"])
    Bm1, cs_B = conv_step(Bm[:, 0], lp["conv_B"], conv_state["B"])
    Cm1, cs_C = conv_step(Cm[:, 0], lp["conv_C"], conv_state["C"])
    xh1, Bm1, Cm1 = jax.nn.silu(xh1), jax.nn.silu(Bm1), jax.nn.silu(Cm1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"])
    Bsz = x.shape[0]
    y, new_state = ssd_step(ssm_state, xh1.reshape(Bsz, H, P), dt1,
                            lp["A_log"], Bm1.reshape(Bsz, G, N),
                            Cm1.reshape(Bsz, G, N))
    y = y + xh1.reshape(Bsz, H, P) * lp["D"][None, :, None].astype(x.dtype)
    out = _gated_out(cfg, lp, y.reshape(Bsz, 1, d_inner), z)
    return out, ({"x": cs_x, "B": cs_B, "C": cs_C}, new_state)


def _stack(cfg, x, layers, collect_state: bool):
    def block(h, lp):
        o, st = ssm_block(cfg, lp, h, collect_state=collect_state)
        return h + o, st

    from repro.models import dense
    body = dense._maybe_remat(cfg, block)
    x, states = common.scan(body, x, layers)
    return x, states


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    from repro.models import dense
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x, _ = _stack(cfg, x, params["layers"], collect_state=False)
    return dense.unembed(cfg, params, x)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward(cfg, params, batch["tokens"])
    return common.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])


def init_decode_cache(cfg: ModelConfig, batch: int, context_len: int,
                      abstract: bool = False) -> dict:
    """Constant-size recurrent state — independent of context_len."""
    d_inner, H, P, G, N = _dims(cfg)
    W = cfg.ssm.d_conv
    L = cfg.n_layers
    dt = jnp.dtype(cfg.dtype)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract \
        else (lambda s, d: jnp.zeros(s, d))
    return {
        "conv": {"x": mk((L, batch, W - 1, d_inner), dt),
                 "B": mk((L, batch, W - 1, G * N), dt),
                 "C": mk((L, batch, W - 1, G * N), dt)},
        "state": mk((L, batch, H, P, N), dt),
        "next_pos": mk((), jnp.int32),
    }


def cache_logical_specs() -> dict:
    return {
        "conv": {"x": ("layers", "cache_batch", None, "mlp"),
                 "B": ("layers", "cache_batch", None, None),
                 "C": ("layers", "cache_batch", None, None)},
        "state": ("layers", "cache_batch", "heads", "head_dim", "state"),
        "next_pos": (),
    }


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            pad_to: int = 0) -> Tuple[jax.Array, dict]:
    from repro.models import dense
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x, states = _stack(cfg, x, params["layers"], collect_state=True)
    conv_states, ssm_states = states
    logits = dense.unembed(cfg, params, x[:, -1:])
    cache = {"conv": conv_states, "state": ssm_states,
             "next_pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def serve_step(cfg: ModelConfig, params: dict, cache: dict,
               tokens: jax.Array) -> Tuple[jax.Array, dict]:
    from repro.models import dense
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))

    def step(h, layer_in):
        lp, conv_l, state_l = layer_in
        o, (new_conv, new_state) = ssm_decode_block(cfg, lp, h, conv_l, state_l)
        return h + o, (new_conv, new_state)

    x, (convs, states) = common.scan(
        step, x, (params["layers"], cache["conv"], cache["state"]))
    logits = dense.unembed(cfg, params, x)
    return logits, {"conv": convs, "state": states,
                    "next_pos": cache["next_pos"] + 1}
