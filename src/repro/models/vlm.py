"""InternVL2-2B [vlm]: InternLM2-style GQA decoder consuming projected
visual tokens. The InternViT vision tower is the one allowed STUB —
``input_specs`` supplies patch embeddings (B, n_visual, d_visual); the
2-layer MLP projector and the whole language model are real.

Sequence layout: [visual prefix | text tokens]; loss on text only.
Decode reuses the dense cache semantics (the visual prefix lives in the
cache after prefill).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, dense
from repro.models.common import ParamDef


def param_defs(cfg: ModelConfig) -> dict:
    defs = dense.param_defs(cfg)
    dv, D = cfg.vlm.d_visual, cfg.d_model
    defs["projector"] = {
        "w1": ParamDef((dv, D), ("state", "embed")),
        "b1": ParamDef((D,), ("embed",), init="zeros"),
        "w2": ParamDef((D, D), ("embed", "embed")),
        "b2": ParamDef((D,), ("embed",), init="zeros"),
    }
    return defs


def init(cfg: ModelConfig, rng: jax.Array):
    return common.materialize(param_defs(cfg), rng, cfg.dtype)


def project(cfg: ModelConfig, params: dict, visual: jax.Array) -> jax.Array:
    """(B, n_vis, d_visual) -> (B, n_vis, D) visual prefix tokens."""
    pp = params["projector"]
    h = jnp.einsum("bnd,de->bne", visual.astype(jnp.dtype(cfg.dtype)),
                   pp["w1"]) + pp["b1"]
    h = jax.nn.gelu(h)
    return jnp.einsum("bne,ef->bnf", h, pp["w2"]) + pp["b2"]


def _embed_multimodal(cfg, params, batch):
    prefix = project(cfg, params, batch["visual_embeds"])
    text = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    return jnp.concatenate([prefix, text], axis=1)


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """-> logits over the FULL sequence (visual positions included)."""
    x = _embed_multimodal(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    mask = common.causal_mask(S, S, window=cfg.window)
    x, _ = dense._stack(cfg, x, params["layers"], positions, mask,
                        collect_kv=False)
    return dense.unembed(cfg, params, x)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """Next-token CE on the text region only."""
    nv = cfg.vlm.n_visual_tokens
    logits = forward(cfg, params, batch)
    # predict text token t+1 from position nv+t
    pred = logits[:, nv:-1]
    gold = batch["tokens"][:, 1:]
    return common.cross_entropy(pred, gold)


def prefill(cfg: ModelConfig, params: dict, batch: dict, pad_to: int = 0
            ) -> Tuple[jax.Array, dict]:
    x = _embed_multimodal(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    mask = common.causal_mask(S, S, window=cfg.window)
    x, kvs = dense._stack(cfg, x, params["layers"], positions, mask,
                          collect_kv=True)
    logits = dense.unembed(cfg, params, x[:, -1:])
    k, v = kvs
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    if pad_to > S:
        pad = [(0, 0), (0, 0), (0, pad_to - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        kv_pos = jnp.concatenate(
            [kv_pos, jnp.full((pad_to - S,), -1, jnp.int32)])
    return logits, {"k": k, "v": v, "kv_pos": kv_pos,
                    "next_pos": jnp.asarray(S, jnp.int32)}


init_decode_cache = dense.init_decode_cache
serve_step = dense.serve_step   # text-token decode is identical to dense
