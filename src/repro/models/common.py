"""Shared model machinery: param definitions, init, abstract shapes, specs.

Models are pure-functional: parameters are nested dicts of arrays. Each
model module defines its parameters once as a tree of :class:`ParamDef`
(shape + logical axes + initializer); from that single source of truth we
derive

* ``init``          — materialized parameters (smoke scale, CPU),
* ``abstract``      — ShapeDtypeStruct tree (dry-run, no allocation),
* ``logical_specs`` — matching tree of logical-axis tuples consumed by
  ``sharding/plans.py`` to build PartitionSpecs.

Logical axis vocabulary (see sharding/plans.py for the mesh mapping):
  "layers"   stacked scan dim (never sharded)
  "embed"    d_model            "mlp"     d_ff / expert hidden
  "heads"    q heads            "kv"      kv heads
  "head_dim" per-head dim       "vocab"   vocabulary
  "experts"  MoE expert dim     "state"   SSM/LRU state dims
  None       replicated
"""
from __future__ import annotations

import dataclasses
import math
import os
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def scan(body, init, xs, length=None):
    """lax.scan that fully unrolls when REPRO_UNROLL_SCANS=1.

    The dry-run sets this flag so ``compiled.cost_analysis()`` counts every
    layer (XLA reports while-loop bodies ONCE, regardless of trip count —
    unrolling makes the FLOP/byte roofline terms exact at the cost of a
    bigger HLO).
    """
    unroll = os.environ.get("REPRO_UNROLL_SCANS") == "1"
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if unroll else 1)

Params = Any      # nested dict of arrays
Tree = Any


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | lru_lambda
    scale: Optional[float] = None   # None -> 1/sqrt(fan_in) for "normal"
    dtype: Optional[str] = None     # None -> model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # Convention: last axis is the output axis for projection matrices.
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return int(np.prod(shape[:-1]))


def materialize(defs: Tree, rng: jax.Array, dtype: str) -> Params:
    """Initialize a ParamDef tree into real arrays."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for d, k in zip(leaves, rngs):
        dt = jnp.dtype(d.dtype or dtype)
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dt)
        elif d.init == "lru_lambda":
            # RG-LRU Lambda param: recurrence decay in [0.9, 0.999]
            u = jax.random.uniform(k, d.shape, jnp.float32,
                                   minval=0.9, maxval=0.999)
            # stored as softplus^-1 of -log(a_max) style parameterization
            val = jnp.log(jnp.expm1(-jnp.log(u)))
            arr = val.astype(dt)
        else:
            scale = d.scale if d.scale is not None else 1.0 / math.sqrt(_fan_in(d.shape))
            arr = (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract(defs: Tree, dtype: str) -> Tree:
    """ShapeDtypeStruct tree — dry-run stand-in, no allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or dtype)),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def logical_specs(defs: Tree) -> Tree:
    """Tree of logical-axes tuples matching the param tree structure."""
    return jax.tree.map(lambda d: d.axes, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs: Tree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(np.prod(d.shape) for d in leaves))


# ---------------------------------------------------------------------------
# Common layer math (pure functions over param dicts)
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "sq_relu":              # Nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]   # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def causal_mask(sq: int, skv: int, *, q_offset: int = 0,
                window: int = 0) -> jax.Array:
    """(sq, skv) boolean mask; True = attend. Query i sits at absolute
    position ``q_offset + i``; keys at 0..skv-1."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits (..., V) float; labels int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
