"""Whisper-large-v3 [audio]: encoder-decoder transformer backbone.

The mel-spectrogram + conv feature extractor is the one allowed STUB:
``input_specs`` supplies precomputed frame embeddings (B, 1500, 1280)
directly to the encoder. Everything transformer-side is real: 32-layer
encoder, 32-layer decoder with causal self-attention + cross-attention,
LayerNorm with biases (Whisper-style), GELU MLP, tied unembedding.

Deviation (documented): positions are sinusoidal for BOTH stacks (real
Whisper uses a learned 448-entry decoder table) so that the decode shapes
(32k cache) can be lowered without a table-size cap.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common
from repro.models.common import ParamDef


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln_defs(L: int, D: int, name: str) -> dict:
    return {
        f"{name}_g": ParamDef((L, D), ("layers", "embed"), init="ones"),
        f"{name}_b": ParamDef((L, D), ("layers", "embed"), init="zeros"),
    }


def _attn_defs(cfg: ModelConfig, L: int, prefix: str) -> dict:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        f"{prefix}_wq": ParamDef((L, D, H, hd), ("layers", "embed", "heads", "head_dim")),
        f"{prefix}_bq": ParamDef((L, H, hd), ("layers", "heads", "head_dim"), init="zeros"),
        f"{prefix}_wk": ParamDef((L, D, H, hd), ("layers", "embed", "heads", "head_dim")),
        f"{prefix}_wv": ParamDef((L, D, H, hd), ("layers", "embed", "heads", "head_dim")),
        f"{prefix}_bv": ParamDef((L, H, hd), ("layers", "heads", "head_dim"), init="zeros"),
        f"{prefix}_wo": ParamDef((L, H, hd, D), ("layers", "heads", "head_dim", "embed")),
        f"{prefix}_bo": ParamDef((L, D), ("layers", "embed"), init="zeros"),
    }


def _mlp_defs(cfg: ModelConfig, L: int) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_up": ParamDef((L, D, F), ("layers", "embed", "mlp")),
        "b_up": ParamDef((L, F), ("layers", "mlp"), init="zeros"),
        "w_down": ParamDef((L, F, D), ("layers", "mlp", "embed")),
        "b_down": ParamDef((L, D), ("layers", "embed"), init="zeros"),
    }


def param_defs(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab
    Le, Ld = cfg.encoder.n_layers, cfg.n_layers
    enc = {**_ln_defs(Le, D, "ln1"), **_attn_defs(cfg, Le, "self"),
           **_ln_defs(Le, D, "ln2"), **_mlp_defs(cfg, Le)}
    dec = {**_ln_defs(Ld, D, "ln1"), **_attn_defs(cfg, Ld, "self"),
           **_ln_defs(Ld, D, "ln2"), **_attn_defs(cfg, Ld, "cross"),
           **_ln_defs(Ld, D, "ln3"), **_mlp_defs(cfg, Ld)}
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=0.02),
        "enc": enc,
        "dec": dec,
        "enc_ln_g": ParamDef((D,), ("embed",), init="ones"),
        "enc_ln_b": ParamDef((D,), ("embed",), init="zeros"),
        "dec_ln_g": ParamDef((D,), ("embed",), init="ones"),
        "dec_ln_b": ParamDef((D,), ("embed",), init="zeros"),
    }


def init(cfg: ModelConfig, rng: jax.Array):
    return common.materialize(param_defs(cfg), rng, cfg.dtype)


# ---------------------------------------------------------------------------
# Blocks (LayerNorm + biased projections, Whisper-style)
# ---------------------------------------------------------------------------

def _proj_qkv(lp, prefix, hq, hkv):
    q = jnp.einsum("bsd,dnh->bsnh", hq, lp[f"{prefix}_wq"]) + lp[f"{prefix}_bq"]
    k = jnp.einsum("bsd,dnh->bsnh", hkv, lp[f"{prefix}_wk"])
    v = jnp.einsum("bsd,dnh->bsnh", hkv, lp[f"{prefix}_wv"]) + lp[f"{prefix}_bv"]
    return q, k, v


def _attn_out(lp, prefix, o):
    return jnp.einsum("bsnh,nhd->bsd", o, lp[f"{prefix}_wo"]) + lp[f"{prefix}_bo"]


def _enc_layer(cfg, x, lp):
    h = common.layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
    q, k, v = _proj_qkv(lp, "self", h, h)
    S = x.shape[1]
    mask = jnp.ones((S, S), bool)          # bidirectional
    o = attention.attend(q, k, v, mask=mask, causal=False)
    x = x + _attn_out(lp, "self", o)
    h = common.layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
    x = x + _mlp_post(cfg, lp, h)
    return x


def _mlp_post(cfg, lp, h):
    up = jnp.einsum("bsd,df->bsf", h, lp["w_up"]) + lp["b_up"]
    act = common.activate(up, cfg.activation)
    return jnp.einsum("bsf,fd->bsd", act, lp["w_down"]) + lp["b_down"]


def encode(cfg: ModelConfig, params: dict, audio_embeds: jax.Array
           ) -> jax.Array:
    """audio_embeds (B, F, D) — precomputed frame embeddings (stub)."""
    B, F, D = audio_embeds.shape
    x = audio_embeds.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(jnp.arange(F), D).astype(x.dtype)[None]

    def body(h, lp):
        return _enc_layer(cfg, h, lp), None

    from repro.models import dense
    x, _ = common.scan(dense._maybe_remat(cfg, body), x, params["enc"])
    return common.layer_norm(x, params["enc_ln_g"], params["enc_ln_b"],
                             cfg.norm_eps)


def _dec_layer(cfg, x, lp, enc_out, mask, positions=None,
               collect_kv=False):
    h = common.layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
    q, k, v = _proj_qkv(lp, "self", h, h)
    o = attention.attend(q, k, v, mask=mask, causal=True)
    x = x + _attn_out(lp, "self", o)
    h = common.layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
    cq, ck, cv = _proj_qkv(lp, "cross", h, enc_out)
    F = enc_out.shape[1]
    o = attention.attend(cq, ck, cv, mask=jnp.ones((x.shape[1], F), bool),
                         causal=False)
    x = x + _attn_out(lp, "cross", o)
    h = common.layer_norm(x, lp["ln3_g"], lp["ln3_b"], cfg.norm_eps)
    x = x + _mlp_post(cfg, lp, h)
    return x, ((k, v, ck, cv) if collect_kv else None)


def decode_train(cfg: ModelConfig, params: dict, enc_out: jax.Array,
                 tokens: jax.Array, collect_kv=False):
    B, S = tokens.shape
    D = cfg.d_model
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(jnp.arange(S), D).astype(x.dtype)[None]
    mask = common.causal_mask(S, S)

    def body(h, lp):
        return _dec_layer(cfg, h, lp, enc_out, mask, collect_kv=collect_kv)

    from repro.models import dense
    x, kvs = common.scan(dense._maybe_remat(cfg, body), x, params["dec"])
    x = common.layer_norm(x, params["dec_ln_g"], params["dec_ln_b"],
                          cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, kvs


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    enc_out = encode(cfg, params, batch["audio_embeds"])
    logits, _ = decode_train(cfg, params, enc_out, batch["tokens"])
    return logits


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward(cfg, params, batch)
    return common.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, context_len: int,
                      abstract: bool = False) -> dict:
    L, H, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    F = cfg.encoder.n_frontend_tokens
    dt = jnp.dtype(cfg.dtype)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract \
        else (lambda s, d: jnp.zeros(s, d))
    return {
        "k": mk((L, batch, context_len, H, hd), dt),
        "v": mk((L, batch, context_len, H, hd), dt),
        "cross_k": mk((L, batch, F, H, hd), dt),
        "cross_v": mk((L, batch, F, H, hd), dt),
        "kv_pos": mk((context_len,), jnp.int32) if abstract
        else jnp.full((context_len,), -1, jnp.int32),
        "next_pos": mk((), jnp.int32),
    }


def cache_logical_specs() -> dict:
    return {
        "k": ("layers", "cache_batch", "cache_seq", "kv", "head_dim"),
        "v": ("layers", "cache_batch", "cache_seq", "kv", "head_dim"),
        "cross_k": ("layers", "cache_batch", None, "kv", "head_dim"),
        "cross_v": ("layers", "cache_batch", None, "kv", "head_dim"),
        "kv_pos": (None,),
        "next_pos": (),
    }


def prefill(cfg: ModelConfig, params: dict, batch: dict, pad_to: int = 0
            ) -> Tuple[jax.Array, dict]:
    """Encode audio + run decoder over prompt tokens, building caches."""
    enc_out = encode(cfg, params, batch["audio_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    logits, kvs = decode_train(cfg, params, enc_out, tokens, collect_kv=True)
    k, v, ck, cv = kvs
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    if pad_to > S:
        pad = [(0, 0), (0, 0), (0, pad_to - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        kv_pos = jnp.concatenate(
            [kv_pos, jnp.full((pad_to - S,), -1, jnp.int32)])
    cache = {"k": k, "v": v, "cross_k": ck, "cross_v": cv,
             "kv_pos": kv_pos, "next_pos": jnp.asarray(S, jnp.int32)}
    return logits[:, -1:], cache


def serve_step(cfg: ModelConfig, params: dict, cache: dict,
               tokens: jax.Array) -> Tuple[jax.Array, dict]:
    """One decoder token against self-KV + precomputed cross-KV caches."""
    B, _ = tokens.shape
    D = cfg.d_model
    pos = cache["next_pos"]
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(pos[None].astype(jnp.float32), D).astype(x.dtype)[None]
    slot = pos
    kv_pos = cache["kv_pos"].at[slot].set(pos)
    mask = attention.decode_mask(pos, kv_pos)
    Fn = cache["cross_k"].shape[2]
    cmask = jnp.ones((1, Fn), bool)

    def step(h, layer_in):
        lp, k_l, v_l, ck_l, cv_l = layer_in
        hh = common.layer_norm(h, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        q, k, v = _proj_qkv(lp, "self", hh, hh)
        k_l, v_l = attention.update_layer_cache(k_l, v_l, k, v, slot)
        o = attention.attend(q, k_l, v_l, mask=mask)
        h = h + _attn_out(lp, "self", o)
        hh = common.layer_norm(h, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        cq = jnp.einsum("bsd,dnh->bsnh", hh, lp["cross_wq"]) + lp["cross_bq"]
        o = attention.attend(cq, ck_l, cv_l, mask=cmask)
        h = h + _attn_out(lp, "cross", o)
        hh = common.layer_norm(h, lp["ln3_g"], lp["ln3_b"], cfg.norm_eps)
        h = h + _mlp_post(cfg, lp, hh)
        return h, (k_l, v_l)

    x, (ks, vs) = common.scan(
        step, x, (params["dec"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = common.layer_norm(x, params["dec_ln_g"], params["dec_ln_b"],
                          cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "kv_pos": kv_pos,
                    "next_pos": pos + 1}
