"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA.

Block pattern ``(rec, rec, attn)`` repeated (2:1), every residual block
followed by a GeGLU MLP. The RG-LRU linear recurrence is evaluated with
``jax.lax.associative_scan`` (log-depth, shardable) for train/prefill and
a single-step update for decode — constant-size state + a fixed local
window make this arch natively ``long_500k``-capable.

Gate matrices are block-diagonal with ``n_heads`` blocks (as in Griffin),
which keeps them local under tensor parallelism over heads.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, dense
from repro.models.common import ParamDef

_LRU_C = 8.0


def layer_layout(cfg: ModelConfig):
    """-> (n_groups, remainder_pattern, n_rec, n_attn)."""
    pat = cfg.recurrent.block_pattern
    g, rem = divmod(cfg.n_layers, len(pat))
    rem_pat = pat[:rem]
    n_rec = g * pat.count("rec") + rem_pat.count("rec")
    n_attn = g * pat.count("attn") + rem_pat.count("attn")
    return g, rem_pat, n_rec, n_attn


def _rec_defs(cfg: ModelConfig, n: int) -> dict:
    D = cfg.d_model
    R = cfg.recurrent.lru_width or D
    W = cfg.recurrent.d_conv
    nb = cfg.n_heads                      # block-diagonal gate blocks
    rb = R // nb
    defs = {
        "norm": ParamDef((n, D), ("layers", "embed"), init="zeros"),
        "w_x": ParamDef((n, D, R), ("layers", "embed", "mlp")),
        "w_gin": ParamDef((n, D, R), ("layers", "embed", "mlp")),
        "conv_w": ParamDef((n, W, R), ("layers", None, "mlp"), scale=0.5),
        "w_a": ParamDef((n, nb, rb, rb), ("layers", "heads", None, None)),
        "b_a": ParamDef((n, R), ("layers", "mlp"), init="zeros"),
        "w_i": ParamDef((n, nb, rb, rb), ("layers", "heads", None, None)),
        "b_i": ParamDef((n, R), ("layers", "mlp"), init="zeros"),
        "lam": ParamDef((n, R), ("layers", "mlp"), init="lru_lambda",
                        dtype="float32"),
        "w_out": ParamDef((n, R, D), ("layers", "mlp", "embed")),
    }
    defs.update(dense.mlp_defs(cfg, n))
    return defs


def _attn_defs(cfg: ModelConfig, n: int) -> dict:
    defs = dense.attn_defs(cfg, n)
    defs.update(dense.mlp_defs(cfg, n))
    return defs


def param_defs(cfg: ModelConfig) -> dict:
    _, _, n_rec, n_attn = layer_layout(cfg)
    D, V = cfg.d_model, cfg.vocab
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=0.02),
        "final_norm": ParamDef((D,), ("embed",), init="zeros"),
        "rec": _rec_defs(cfg, n_rec),
        "attn": _attn_defs(cfg, n_attn),
    }
    if not cfg.tie_embeddings:
        defs["out_head"] = ParamDef((D, V), ("embed", "vocab"))
    return defs


def init(cfg: ModelConfig, rng: jax.Array):
    return common.materialize(param_defs(cfg), rng, cfg.dtype)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def lru_scan(a: jax.Array, b: jax.Array, h0=None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan. a, b (B, L, R) f32."""
    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(op, (a, b), axis=1)
    if h0 is not None:
        return Bc + A * h0[:, None]
    return Bc


def _block_diag_mm(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x (..., R) @ block-diag w (nb, rb, rb) + b."""
    nb, rb, _ = w.shape
    xs = x.reshape(x.shape[:-1] + (nb, rb))
    y = jnp.einsum("...nr,nrs->...ns", xs, w)
    return y.reshape(x.shape) + b


def _rg_lru_gates(lp: dict, xc: jax.Array):
    """-> (log_a (f32), gated input (f32)). xc (B, L/1, R)."""
    r = jax.nn.sigmoid(_block_diag_mm(xc, lp["w_a"], lp["b_a"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag_mm(xc, lp["w_i"], lp["b_i"])
                       .astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(lp["lam"]) * r
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * i * xc.astype(jnp.float32)
    return log_a, b


def rec_mixer(cfg: ModelConfig, lp: dict, x: jax.Array,
              conv_state=None, h_state=None, collect: bool = False):
    """Recurrent temporal-mixing sublayer. x (B, L, D)."""
    from repro.models.ssm import causal_conv
    from repro.sharding.constraints import BATCH, constrain
    h = common.rms_norm(x, lp["norm"], cfg.norm_eps)
    xb = jnp.einsum("bld,dr->blr", h, lp["w_x"])
    gate = jnp.einsum("bld,dr->blr", h, lp["w_gin"])
    # pin row-parallel layout: lru width sharded over the model axis
    # (without this, SPMD replicates the whole recurrent stack 16x)
    xb = constrain(xb, BATCH, None, "model")
    gate = constrain(gate, BATCH, None, "model")
    xc, conv_out = causal_conv(xb, lp["conv_w"], conv_state)
    log_a, b = _rg_lru_gates(lp, xc)
    hs = lru_scan(jnp.exp(log_a), b,
                  None if h_state is None else h_state.astype(jnp.float32))
    y = hs.astype(x.dtype) * jax.nn.gelu(gate)
    y = constrain(y, BATCH, None, "model")
    out = jnp.einsum("blr,rd->bld", y, lp["w_out"])
    out = constrain(out, BATCH, None, None)
    if collect:
        return out, (conv_out, hs[:, -1].astype(x.dtype))
    return out, None


def rec_mixer_step(cfg: ModelConfig, lp: dict, x: jax.Array,
                   conv_state: jax.Array, h_state: jax.Array):
    """One-token recurrent mixer. x (B, 1, D)."""
    from repro.models.ssm import conv_step
    h = common.rms_norm(x, lp["norm"], cfg.norm_eps)
    xb = jnp.einsum("bld,dr->blr", h, lp["w_x"])
    gate = jnp.einsum("bld,dr->blr", h, lp["w_gin"])
    xc1, conv_out = conv_step(xb[:, 0], lp["conv_w"], conv_state)
    log_a, b = _rg_lru_gates(lp, xc1)
    hf = h_state.astype(jnp.float32) * jnp.exp(log_a) + b
    y = hf[:, None].astype(x.dtype) * jax.nn.gelu(gate)
    out = jnp.einsum("blr,rd->bld", y, lp["w_out"])
    return out, (conv_out, hf.astype(x.dtype))


def _rec_block(cfg, lp, x, conv_state=None, h_state=None, collect=False):
    o, st = rec_mixer(cfg, lp, x, conv_state, h_state, collect)
    x = x + o
    x = x + dense.mlp_block(cfg, lp, x)
    return x, st


def _attn_block(cfg, lp, x, positions, mask, collect=False):
    a, kv = dense.attn_block(cfg, lp, x, positions, mask,
                             window=cfg.recurrent.local_window)
    x = x + a
    x = x + dense.mlp_block(cfg, lp, x)
    return x, kv if collect else None


# ---------------------------------------------------------------------------
# Full-sequence pass (scan over (rec, rec, attn) groups + remainder)
# ---------------------------------------------------------------------------

def _group_view(cfg: ModelConfig, params: dict):
    """Reshape stacked rec/attn params into (groups, per-group) + remainder."""
    g, rem_pat, n_rec, n_attn = layer_layout(cfg)
    pat = cfg.recurrent.block_pattern
    rpg = pat.count("rec")                # rec layers per group
    apg = pat.count("attn")
    grp_rec = jax.tree.map(
        lambda p: p[: g * rpg].reshape((g, rpg) + p.shape[1:]), params["rec"])
    grp_attn = jax.tree.map(
        lambda p: p[: g * apg].reshape((g, apg) + p.shape[1:]), params["attn"])
    rem_rec = jax.tree.map(lambda p: p[g * rpg:], params["rec"])
    rem_attn = jax.tree.map(lambda p: p[g * apg:], params["attn"])
    return grp_rec, grp_attn, rem_rec, rem_attn, rem_pat


def _run_sequence(cfg: ModelConfig, params: dict, x: jax.Array,
                  collect: bool):
    B, S, _ = x.shape
    positions = jnp.arange(S)
    mask = common.causal_mask(S, S, window=cfg.recurrent.local_window)
    pat = cfg.recurrent.block_pattern
    grp_rec, grp_attn, rem_rec, rem_attn, rem_pat = _group_view(cfg, params)

    def group_body(h, grp):
        rec_p, attn_p = grp
        states = {"conv": [], "h": [], "k": [], "v": []}
        ri = ai = 0
        for kind in pat:
            if kind == "rec":
                lp = jax.tree.map(lambda p: p[ri], rec_p)
                h, st = _rec_block(cfg, lp, h, collect=collect)
                if collect:
                    states["conv"].append(st[0])
                    states["h"].append(st[1])
                ri += 1
            else:
                lp = jax.tree.map(lambda p: p[ai], attn_p)
                h, kv = _attn_block(cfg, lp, h, positions, mask, collect)
                if collect:
                    states["k"].append(kv[0])
                    states["v"].append(kv[1])
                ai += 1
        out_state = None
        if collect:
            out_state = (jnp.stack(states["conv"]), jnp.stack(states["h"]),
                         jnp.stack(states["k"]), jnp.stack(states["v"]))
        return h, out_state

    body = dense._maybe_remat(cfg, group_body)
    x, grp_states = common.scan(lambda h, g: body(h, g), x,
                                (grp_rec, grp_attn))

    rem_states = {"conv": [], "h": [], "k": [], "v": []}
    for j, kind in enumerate(rem_pat):
        if kind == "rec":
            lp = jax.tree.map(lambda p: p[j], rem_rec)
            x, st = _rec_block(cfg, lp, x, collect=collect)
            if collect:
                rem_states["conv"].append(st[0])
                rem_states["h"].append(st[1])
        else:
            lp = jax.tree.map(lambda p: p[j], rem_attn)
            x, kv = _attn_block(cfg, lp, x, positions, mask, collect)
            if collect:
                rem_states["k"].append(kv[0])
                rem_states["v"].append(kv[1])
    return x, grp_states, rem_states


def _flatten_states(cfg, grp_states, rem_states):
    """-> cache arrays stacked over rec layers / attn layers."""
    g, rem_pat, n_rec, n_attn = layer_layout(cfg)
    pat = cfg.recurrent.block_pattern
    rpg, apg = pat.count("rec"), pat.count("attn")
    conv, hst, ks, vs = grp_states
    # (g, rpg, B, ...) -> (g*rpg, B, ...)
    conv = conv.reshape((g * rpg,) + conv.shape[2:])
    hst = hst.reshape((g * rpg,) + hst.shape[2:])
    ks = ks.reshape((g * apg,) + ks.shape[2:])
    vs = vs.reshape((g * apg,) + vs.shape[2:])
    if rem_states["conv"]:
        conv = jnp.concatenate([conv, jnp.stack(rem_states["conv"])])
        hst = jnp.concatenate([hst, jnp.stack(rem_states["h"])])
    if rem_states["k"]:
        ks = jnp.concatenate([ks, jnp.stack(rem_states["k"])])
        vs = jnp.concatenate([vs, jnp.stack(rem_states["v"])])
    return conv, hst, ks, vs


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = _embed(cfg, params, tokens)
    x, _, _ = _run_sequence(cfg, params, x, collect=False)
    return dense.unembed(cfg, params, x)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward(cfg, params, batch["tokens"])
    return common.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])


def init_decode_cache(cfg: ModelConfig, batch: int, context_len: int,
                      abstract: bool = False) -> dict:
    _, _, n_rec, n_attn = layer_layout(cfg)
    R = cfg.recurrent.lru_width or cfg.d_model
    W = cfg.recurrent.d_conv
    win = min(cfg.recurrent.local_window, context_len)
    dt = jnp.dtype(cfg.dtype)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract \
        else (lambda s, d: jnp.zeros(s, d))
    cache = {
        "conv": mk((n_rec, batch, W - 1, R), dt),
        "h": mk((n_rec, batch, R), dt),
        "k": mk((n_attn, batch, win, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": mk((n_attn, batch, win, cfg.n_kv_heads, cfg.head_dim), dt),
        "kv_pos": mk((win,), jnp.int32) if abstract
        else jnp.full((win,), -1, jnp.int32),
        "next_pos": mk((), jnp.int32),
    }
    return cache


def cache_logical_specs() -> dict:
    return {
        "conv": ("layers", "cache_batch", None, "mlp"),
        "h": ("layers", "cache_batch", "mlp"),
        "k": ("layers", "cache_batch", "cache_seq", "kv", "head_dim"),
        "v": ("layers", "cache_batch", "cache_seq", "kv", "head_dim"),
        "kv_pos": (None,),
        "next_pos": (),
    }


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            pad_to: int = 0) -> Tuple[jax.Array, dict]:
    B, S = tokens.shape
    x = _embed(cfg, params, tokens)
    x, grp_states, rem_states = _run_sequence(cfg, params, x, collect=True)
    logits = dense.unembed(cfg, params, x[:, -1:])
    conv, hst, ks, vs = _flatten_states(cfg, grp_states, rem_states)
    # Re-pack the last min(S, win) tokens into a ring cache of size win
    # (slot of absolute position p is p % win) so serve_step can continue.
    win = cfg.recurrent.local_window
    keep = min(S, win)
    sl = jnp.arange(S - keep, S)
    ring_slot = sl % win
    ks_w = jnp.zeros(ks.shape[:2] + (win,) + ks.shape[3:], ks.dtype)
    vs_w = jnp.zeros_like(ks_w)
    ks_w = ks_w.at[:, :, ring_slot].set(ks[:, :, sl])
    vs_w = vs_w.at[:, :, ring_slot].set(vs[:, :, sl])
    kv_pos = jnp.full((win,), -1, jnp.int32).at[ring_slot].set(sl)
    ks, vs = ks_w, vs_w
    cache = {"conv": conv, "h": hst, "k": ks, "v": vs, "kv_pos": kv_pos,
             "next_pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def serve_step(cfg: ModelConfig, params: dict, cache: dict,
               tokens: jax.Array) -> Tuple[jax.Array, dict]:
    x = _embed(cfg, params, tokens)
    pos = cache["next_pos"]
    win = cache["k"].shape[2]
    slot = pos % win
    kv_pos = cache["kv_pos"].at[slot].set(pos)
    mask = attention.decode_mask(pos, kv_pos,
                                 window=cfg.recurrent.local_window)
    pat = cfg.recurrent.block_pattern
    g, rem_pat, n_rec, n_attn = layer_layout(cfg)

    new_conv = cache["conv"]
    new_h = cache["h"]
    new_k = cache["k"]
    new_v = cache["v"]
    ri = ai = 0
    # decode is one token — a python loop over layers is fine for tracing
    # (layers are small; scan-over-groups buys nothing at Sq=1)
    full_pat = list(pat) * g + list(rem_pat)
    for kind in full_pat:
        if kind == "rec":
            lp = jax.tree.map(lambda p, i=ri: p[i], params["rec"])
            o, (cs, hs) = rec_mixer_step(cfg, lp, x, new_conv[ri], new_h[ri])
            x = x + o
            x = x + dense.mlp_block(cfg, lp, x)
            new_conv = new_conv.at[ri].set(cs)
            new_h = new_h.at[ri].set(hs)
            ri += 1
        else:
            lp = jax.tree.map(lambda p, i=ai: p[i], params["attn"])
            a, (k_l, v_l) = dense.attn_decode_block(
                cfg, lp, x, new_k[ai], new_v[ai], pos, slot, mask)
            x = x + a
            x = x + dense.mlp_block(cfg, lp, x)
            new_k = new_k.at[ai].set(k_l)
            new_v = new_v.at[ai].set(v_l)
            ai += 1
    logits = dense.unembed(cfg, params, x)
    return logits, {"conv": new_conv, "h": new_h, "k": new_k, "v": new_v,
                    "kv_pos": kv_pos, "next_pos": pos + 1}
