"""Activation sharding constraints (mesh-ambient, divisibility-safe).

XLA's SPMD propagation sometimes resolves under-constrained loop bodies
by REPLICATING tensor-parallel compute instead of inserting an
all-reduce (observed: recurrentgemma's scanned recurrent stack computed
full-width f32 matmuls on all 16 model shards). Pinning the activation
layout at the block boundaries forces the intended row/column-parallel
pattern.

``constrain(x, *spec)`` is a no-op when there is no ambient mesh, when a
named axis is absent, or when a dim is not divisible — so model code can
call it unconditionally (CPU smoke tests included).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

BATCH = "__batch__"      # placeholder: ("pod","data") axes when present
SEQ = "__seq__"          # sequence dim: sharded over "model" under tp_sp

_STRATEGY = "tp"         # process-global; set by the launcher per plan


def set_strategy(strategy: str) -> None:
    """"tp" (default): hidden dims pin to the model axis.
    "tp_sp": tp + Megatron sequence parallelism — residual-stream SEQ
    dims shard over the model axis (all-reduces become
    all-gather + reduce-scatter of equal volume, but stored activations
    shrink by the TP degree).
    "fsdp": no tensor parallelism — model-axis constraints are dropped
    and the batch dim spans ("pod","data","model")."""
    global _STRATEGY
    _STRATEGY = strategy


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:                                   # noqa: BLE001
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.shape_tuple:
            return m
    except Exception:                                   # noqa: BLE001
        pass
    return None


def _axis_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= dict(mesh.shape)[a]
    return n


def constrain(x: jax.Array, *spec) -> jax.Array:
    """Best-effort with_sharding_constraint.

    spec entries: None, a mesh axis name ("model"), or BATCH (expands to
    the ("pod","data") axes present in the ambient mesh).
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    parts = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            parts.append(None)
            continue
        if s == BATCH:
            batch_axes = ("pod", "data", "model") if _STRATEGY == "fsdp" \
                else ("pod", "data")
            axes = tuple(a for a in batch_axes if a in names)
        elif s == SEQ:
            axes = ("model",) if (_STRATEGY == "tp_sp"
                                  and "model" in names) else ()
        elif _STRATEGY == "fsdp" and s == "model":
            axes = ()
        else:
            axes = (s,) if s in names else ()
        if axes and dim % _axis_size(mesh, axes) == 0:
            parts.append(axes if len(axes) > 1 else axes[0])
        else:
            parts.append(None)
    if all(p is None for p in parts):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except Exception:                                   # noqa: BLE001
        return x
