"""Parallelism plans: logical param/cache axes -> mesh PartitionSpecs.

One rules table per (arch × input-shape kind), shape-aware and
divisibility-safe: a logical axis is sharded over a mesh axis only when
the dimension divides the axis size and the mesh axis is not already
used by another dim of the same tensor — otherwise it silently stays
replicated (e.g. kv=8 heads on a model=16 axis: KV projections
replicate, exactly like Megatron TP with kv < tp).

Plans:
  * train: batch over (pod, data); TP over model on heads/mlp/vocab/
    experts; FSDP (embed/weights over data axes too) + bf16 adam moments
    + microbatching for the >=100B archs.
  * prefill: like train, no FSDP-gradient concerns, no microbatching.
  * decode: weights TP over model + FSDP over (pod, data) when batch
    can't use them; KV cache batch over data, cache seq over model
    (flash-decode-style SPMD sequence parallelism).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import InputShape, ModelConfig

Axes = Tuple[str, ...]


@dataclass(frozen=True)
class Plan:
    """Resolved plan for one (arch × shape × mesh)."""
    rules: Dict[str, Axes]          # logical axis -> mesh axes
    batch_axes: Axes                # data-parallel axes for the batch dim
    microbatches: int = 1
    opt_dtype: str = "float32"
    remat: str = "full"
    strategy: str = "tp"            # tp | fsdp (see arch_plan)
    notes: str = ""


# archs that need FSDP-weight sharding + bf16 moments + grad accumulation
# to fit a pod: name -> (microbatches, moment dtype, remat)
# remat="dots" keeps matmul outputs: the FSDP backward then reuses the
# forward's weight all-gathers instead of re-gathering during recompute
# (§Perf hillclimb #3; ~1/3 of the gather traffic for +saved-dot memory).
_BIG = {"nemotron-4-340b": (16, "bfloat16", "full"),
        "mistral-large-123b": (4, "float32", "full"),
        "mixtral-8x22b": (4, "float32", "full"),
        "command-r-35b": (2, "float32", "full")}

# train-shape strategy override: models whose TP activation all-reduces
# dwarf their compute go pure-FSDP (ZeRO-3: batch over BOTH mesh axes,
# weights fully sharded, no tensor parallelism). Established by the
# §Perf hillclimb on recurrentgemma (322 GB/dev TP traffic -> FSDP).
# (fsdp was measured WORSE for mamba2/whisper — their SSD / cross-attn
# einsums replicate under batch-over-model partitioning; they stay tp.)
_TRAIN_STRATEGY = {"recurrentgemma-9b": "fsdp",
                   "stablelm-12b": "fsdp",
                   "internvl2-2b": "fsdp",
                   # Megatron SP: S-sharded residual stream cuts the
                   # scan-saved activation carries by the TP degree
                   "nemotron-4-340b": "tp_sp",
                   "mistral-large-123b": "tp_sp"}


def arch_plan(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> Plan:
    axes = mesh.axis_names
    dp: Axes = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None

    micro, opt_dtype, remat = _BIG.get(cfg.name, (1, "float32", cfg.remat))
    fsdp = cfg.name in _BIG

    rules: Dict[str, Axes] = {
        "heads": (tp,), "kv": (tp,), "mlp": (tp,), "vocab": (tp,),
        "experts": (tp,), "head_dim": (), "state": (), "layers": (),
        "embed": dp if fsdp else (),
        # cache axes
        "cache_batch": dp, "cache_seq": (tp,),
    }
    if shape.kind == "train":
        strategy = _TRAIN_STRATEGY.get(cfg.name, "tp")
        if strategy == "tp_sp":
            if shape.global_batch % _prod(mesh, dp) != 0:
                dp = dp[-1:]
            return Plan(rules=rules, batch_axes=dp, microbatches=micro,
                        opt_dtype=opt_dtype, remat=remat,
                        strategy="tp_sp", notes="megatron-sp")
        if strategy == "fsdp" and tp and \
                shape.global_batch % _prod(mesh, dp + (tp,)) == 0:
            # ZeRO-3: batch and weights sharded over ALL mesh axes; no TP.
            # Only when the batch spans the whole mesh — otherwise (e.g.
            # batch 256 on the 512-chip two-pod mesh) fall through to tp.
            all_axes = dp + (tp,)
            rules = dict(rules)
            rules.update({"embed": all_axes[:-1] or dp})
            return Plan(rules=rules, batch_axes=all_axes,
                        microbatches=micro, opt_dtype=opt_dtype,
                        remat=remat, strategy="fsdp", notes="zero3")
        if shape.global_batch % _prod(mesh, dp) != 0:
            dp = dp[-1:]                      # fall back to data only
        return Plan(rules=rules, batch_axes=dp, microbatches=micro,
                    opt_dtype=opt_dtype, remat=remat,
                    notes="fsdp" if fsdp else "tp+dp")
    if shape.kind == "prefill":
        return Plan(rules=rules, batch_axes=dp, microbatches=1,
                    opt_dtype=opt_dtype, remat=cfg.remat)
    # decode: batch may be tiny; weights lean on FSDP over unused dp axes.
    # When the batch DOES occupy the data axis, weights must be
    # model-sharded only — a data-axis weight shard would be re-gathered
    # on EVERY decode step (measured: 5.1 GB/step on command-r, §Perf).
    dp_batch = tuple(a for a in dp
                     if shape.global_batch % _prod(mesh, (a,)) == 0)
    rules = dict(rules)
    rules["embed"] = dp if shape.global_batch < _prod(mesh, dp) else ()
    rules["cache_batch"] = dp_batch
    return Plan(rules=rules, batch_axes=dp_batch, microbatches=1,
                opt_dtype=opt_dtype, remat="none")


def _prod(mesh: Mesh, axes: Axes) -> int:
    n = 1
    for a in axes:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def spec_from_logical(logical: Tuple[Optional[str], ...],
                      shape: Tuple[int, ...], plan: Plan,
                      mesh: Mesh) -> P:
    """Map one tensor's logical axes to a PartitionSpec, enforcing
    divisibility and no-mesh-axis-reuse."""
    used = set()
    parts = []
    for dim, name in zip(shape, logical):
        assigned = None
        if name is not None:
            want = plan.rules.get(name, ())
            cand = tuple(a for a in want
                         if a and a in mesh.axis_names and a not in used)
            if cand:
                n = _prod(mesh, cand)
                if dim % n == 0:
                    assigned = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                elif len(cand) == 1 and dim % mesh.shape[cand[0]] == 0:
                    assigned = cand[0]
                    used.add(cand[0])
        parts.append(assigned)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def _tree_sharding(specs_tree, shapes_tree, plan: Plan, mesh: Mesh):
    return jax.tree.map(
        lambda spec, arr: NamedSharding(
            mesh, spec_from_logical(spec, arr.shape, plan, mesh)),
        specs_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def param_sharding(cfg: ModelConfig, plan: Plan, mesh: Mesh):
    """NamedSharding tree matching models.abstract_params(cfg)."""
    specs = models.param_logical_specs(cfg)
    shapes = models.abstract_params(cfg)
    return _tree_sharding(specs, shapes, plan, mesh)


def train_state_sharding(cfg: ModelConfig, plan: Plan, mesh: Mesh,
                         abstract_state):
    ps = param_sharding(cfg, plan, mesh)
    return {
        "params": ps,
        "opt": {"m": ps, "v": ps,
                "step": NamedSharding(mesh, P())},
    }


def batch_sharding(batch_abstract: dict, plan: Plan, mesh: Mesh):
    """Shard every batch leaf on its leading (batch) dim."""
    ba = tuple(a for a in plan.batch_axes if a in mesh.axis_names)

    def leaf(x):
        if ba and x.shape and x.shape[0] % _prod(mesh, ba) == 0:
            spec = P(ba if len(ba) > 1 else ba[0])
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree.map(leaf, batch_abstract)


def cache_sharding(cfg: ModelConfig, plan: Plan, mesh: Mesh,
                   cache_abstract):
    specs = models.cache_logical_specs(cfg)
    return _tree_sharding(specs, cache_abstract, plan, mesh)
