"""Trial-axis sharding for the sweep fabric (DESIGN.md §11).

The sweep fabric's batches are embarrassingly parallel over the
leading TRIAL axis — stacked ``Jobs`` leaves, per-trial ``s``/``P``/
``seed`` vectors and every per-trial summary. These helpers pin that
convention down in one place: shard dimension 0 over the mesh's data
axis, replicate everything else. Model-parallel layouts for the
training stack live next door in ``sharding.plans``.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def trial_axis(mesh: Mesh) -> str:
    """The mesh axis trials shard over: ``"data"`` when present (the
    production meshes), else the mesh's first axis (the 1-D sweep
    meshes from ``mesh_for_sweep``)."""
    return "data" if "data" in mesh.axis_names else mesh.axis_names[0]


def trial_spec(mesh: Mesh) -> PartitionSpec:
    """PartitionSpec sharding the leading trial dimension only —
    trailing (per-job) dimensions stay replicated within a shard."""
    return PartitionSpec(trial_axis(mesh))


def trial_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, trial_spec(mesh))


def put_trial_sharded(mesh: Mesh, tree):
    """``device_put`` every leaf of ``tree`` with its leading (trial)
    axis sharded over the mesh — the explicit placement keeps jit from
    first replicating the full table onto every device."""
    shard = trial_sharding(mesh)
    return jax.tree.map(lambda x: jax.device_put(x, shard), tree)
