from repro.sharding.plans import (arch_plan, batch_sharding, cache_sharding,
                                  param_sharding, spec_from_logical,
                                  train_state_sharding)
from repro.sharding.trials import (put_trial_sharded, trial_axis,
                                   trial_sharding, trial_spec)

__all__ = ["arch_plan", "param_sharding", "batch_sharding", "cache_sharding",
           "train_state_sharding", "spec_from_logical",
           "put_trial_sharded", "trial_axis", "trial_sharding",
           "trial_spec"]
