from repro.sharding.plans import (arch_plan, batch_sharding, cache_sharding,
                                  param_sharding, spec_from_logical,
                                  train_state_sharding)

__all__ = ["arch_plan", "param_sharding", "batch_sharding", "cache_sharding",
           "train_state_sharding", "spec_from_logical"]
