"""Checkpointing: pytree save/restore + grace-period estimation.

This is the substrate behind checkpoint-based preemption (the paper's
grace period, §2): suspending a training job = flushing
(params, opt_state, step, data cursor) to storage; the GP a job should
request is ``state_bytes / storage_bandwidth`` plus serialization slack.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Tuple

import jax
import numpy as np

Pytree = Any
_SEP = "§"


def _flatten_with_paths(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(tree: Pytree, path: str) -> int:
    """Write a pytree to ``path`` (.npz). Returns bytes written."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, _ = _flatten_with_paths(tree)
    # bfloat16 has no numpy dtype serialization — view as uint16 + marker
    packed = {}
    meta = {}
    for k, v in arrays.items():
        if v.dtype == jax.numpy.bfloat16:
            packed[k] = v.view(np.uint16)
            meta[k] = "bfloat16"
        else:
            packed[k] = v
    np.savez(path, __meta__=json.dumps(meta), **packed)
    return os.path.getsize(path)


def load_pytree(template: Pytree, path: str) -> Pytree:
    """Restore a pytree saved by save_pytree; ``template`` fixes shape."""
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        arrays = {}
        for k in data.files:
            if k == "__meta__":
                continue
            v = data[k]
            if meta.get(k) == "bfloat16":
                v = v.view(jax.numpy.bfloat16)
            arrays[k] = v
    flat, treedef = _flatten_with_paths(template)
    missing = set(flat) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_tpl, tdef = jax.tree_util.tree_flatten(template)
    flat_paths, _ = jax.tree_util.tree_flatten_with_path(template)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat_paths]
    leaves = [jax.numpy.asarray(arrays[k]) for k in keys]
    return jax.tree_util.tree_unflatten(tdef, leaves)


def state_bytes(tree: Pytree) -> int:
    return int(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree)))


def estimate_grace_period(tree: Pytree, storage_bw_bytes_per_s: float = 2e9,
                          slack: float = 1.5) -> float:
    """Suggested grace period [minutes] for a job with this train state.

    The paper motivates long GPs by serialization + writeback of large
    states; we estimate GP = slack * bytes / bandwidth, floor
    one scheduler tick when nonzero.
    """
    b = state_bytes(tree)
    seconds = slack * b / storage_bw_bytes_per_s
    return max(math.ceil(seconds / 60.0), 1) if b else 0
