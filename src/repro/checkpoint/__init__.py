from repro.checkpoint.ckpt import (estimate_grace_period, load_pytree,
                                   save_pytree, state_bytes)

__all__ = ["save_pytree", "load_pytree", "state_bytes",
           "estimate_grace_period"]
