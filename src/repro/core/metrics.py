"""Slowdown-rate metrics and paper-table summarization."""
from __future__ import annotations

from typing import Dict, Iterable, Sequence

import numpy as np

from repro.core.types import SimResult
from repro.obs import schema as obs_schema


def assert_result_parity(a: SimResult, b: SimResult) -> None:
    """Bit-exactness check between two SimResults — the contract the
    event-driven advancement mode guarantees against tick stepping
    (DESIGN.md §4), also used for driver-vs-driver semantics tests.

    A preemption-stream divergence is reported as the FIRST diverging
    event index with both sides rendered in the canonical event
    vocabulary (``obs.schema``), not as a bare tuple dump."""
    np.testing.assert_array_equal(a.finish, b.finish)
    np.testing.assert_array_equal(a.preempt_count, b.preempt_count)
    np.testing.assert_array_equal(a.submit, b.submit)
    np.testing.assert_array_equal(a.exec_total, b.exec_total)
    np.testing.assert_array_equal(a.is_te, b.is_te)
    assert a.makespan == b.makespan, (a.makespan, b.makespan)
    for i, (ea, eb) in enumerate(zip(a.events, b.events)):
        if ea.as_tuple() != eb.as_tuple():
            raise AssertionError(
                f"preemption streams diverge at event {i}:\n"
                f"  a: {obs_schema.render_preemption(ea)}\n"
                f"  b: {obs_schema.render_preemption(eb)}")
    assert len(a.events) == len(b.events), \
        (f"preemption stream lengths differ: "
         f"{len(a.events)} vs {len(b.events)}")
    if a.trace is not None and b.trace is not None:
        assert_trace_parity(a.trace, b.trace)


def assert_trace_parity(a: Sequence, b: Sequence) -> None:
    """Exact equality of two canonical event streams
    (``obs.schema.Event`` lists — a traced reference run vs a decoded
    JAX ring, or the two time modes of one engine). On divergence,
    reports the first differing index with both events rendered."""
    for i, (ea, eb) in enumerate(zip(a, b)):
        if ea.as_tuple() != eb.as_tuple():
            raise AssertionError(
                f"traces diverge at event {i}:\n"
                f"  a: {ea.render()}\n  b: {eb.render()}")
    assert len(a) == len(b), \
        f"trace lengths differ: {len(a)} vs {len(b)}"


def sim_throughput(res: SimResult, seconds: float) -> float:
    """Jobs simulated per wall-clock second (engine benchmarks)."""
    return len(res.finish) / max(seconds, 1e-12)


def percentiles(x: np.ndarray, ps=(50, 95, 99)) -> Dict[str, float]:
    if len(x) == 0:
        return {f"p{p}": float("nan") for p in ps}
    return {f"p{p}": float(np.percentile(x, p)) for p in ps}


def slowdown_table(res: SimResult) -> Dict[str, Dict[str, float]]:
    """Table 1 / Table 5 row: slowdown percentiles for TE and BE."""
    sd = res.slowdown
    return {
        "TE": percentiles(sd[res.is_te]),
        "BE": percentiles(sd[~res.is_te]),
    }


def resched_table(res: SimResult) -> Dict[str, float]:
    """Table 2 row: re-scheduling interval percentiles [min]."""
    iv = res.resched_intervals
    return percentiles(iv, ps=(50, 75, 95, 99))


def merge_results(results: Iterable[SimResult]) -> Dict[str, np.ndarray]:
    """Pool per-job stats across workloads (paper pools 8 workloads)."""
    sd, te, pc, iv = [], [], [], []
    for r in results:
        sd.append(r.slowdown)
        te.append(r.is_te)
        pc.append(r.preempt_count)
        iv.append(r.resched_intervals)
    return {
        "slowdown": np.concatenate(sd),
        "is_te": np.concatenate(te),
        "preempt_count": np.concatenate(pc),
        "intervals": np.concatenate(iv) if iv else np.asarray([]),
    }


def pooled_tables(pool: Dict[str, np.ndarray]) -> Dict:
    """Empty classes (an all-TE or all-BE pool) yield explicit ``nan``
    entries — the same NaN-safety contract as the vmapped sweeps
    (``sweep._masked_pct``): nan-aware consumers drop them instead of
    averaging garbage."""
    sd, te = pool["slowdown"], pool["is_te"]
    pc = pool["preempt_count"][~te]
    n_be = len(pc) if len(pc) else float("nan")
    return {
        "TE": percentiles(sd[te]),
        "BE": percentiles(sd[~te]),
        "intervals": percentiles(pool["intervals"], ps=(50, 75, 95, 99)),
        "preempted_frac": float((pc > 0).mean()) if len(pc)
        else float("nan"),
        "preempt_counts": {
            "1": float((pc == 1).sum()) / n_be,
            "2": float((pc == 2).sum()) / n_be,
            ">=3": float((pc >= 3).sum()) / n_be,
        },
    }


def format_table(rows: Dict[str, Dict], title: str = "") -> str:
    """rows: policy -> {'TE': {p50..}, 'BE': {...}} -> aligned text."""
    lines = []
    if title:
        lines.append(title)
    hdr = f"{'policy':12s} | {'TE p50':>8s} {'p95':>8s} {'p99':>8s} | " \
          f"{'BE p50':>8s} {'p95':>8s} {'p99':>8s}"
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for name, r in rows.items():
        te, be = r["TE"], r["BE"]
        lines.append(
            f"{name:12s} | {te['p50']:8.2f} {te['p95']:8.2f} {te['p99']:8.2f}"
            f" | {be['p50']:8.2f} {be['p95']:8.2f} {be['p99']:8.2f}")
    return "\n".join(lines)
