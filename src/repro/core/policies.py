"""Preemption policies: FitGpp (the paper, Eq. 1-4), LRTP, RAND, FIFO.

A policy answers ONE question: given an incoming TE job that does not
fit anywhere, which running BE job(s) should be signalled to vacate?

All policies here operate on plain numpy views of the simulator state so
the reference simulator stays transparent; ``core/sim_jax.py`` mirrors
the same equations in jnp (and ``kernels/fitgpp_score.py`` is the
TPU-kernel version of the FitGpp score + masked argmin).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.engine.placement import FIT_EPS


def size_eq1(demand: np.ndarray, node_cap: np.ndarray) -> np.ndarray:
    """Eq. 1: scale-invariant demand size, ||D / capacity||_2.

    demand (..., 3); node_cap (3,).
    """
    return np.sqrt(np.sum((demand / node_cap) ** 2, axis=-1))


def fitgpp_scores(demand: np.ndarray, gp: np.ndarray, node_cap: np.ndarray,
                  s: float) -> np.ndarray:
    """Eq. 3 over the set of running BE jobs.

    Normalizers are max over ALL running BE jobs (the paper's J), not
    just the eligible subset.
    """
    sz = size_eq1(demand, node_cap)
    max_sz = max(sz.max(initial=0.0), 1e-12)
    max_gp = max(gp.max(initial=0), 1e-12)
    return sz / max_sz + s * (gp / max_gp)


def eligible_eq2(te_demand: np.ndarray, demand: np.ndarray,
                 node_free: np.ndarray) -> np.ndarray:
    """Eq. 2: D_TE <= D_j + N_free(node_j), element-wise, per job.

    demand (m, 3) of running BE jobs; node_free (m, 3) free vector of the
    node each candidate runs on. FIT_EPS-tolerant, like every other fit
    check (and like the JAX engine's eligibility mask).
    """
    return np.all(te_demand[None, :] <= demand + node_free + FIT_EPS, axis=1)


@dataclass
class Selection:
    """Victims to signal. Empty = policy could not free enough."""
    victims: List[int]


class Policy:
    name = "base"
    preemptive = True

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        """Return victim job indices (into the global job array).

        cand_* arrays cover ALL currently running BE jobs; ``under_cap``
        marks those with PreemptionCount < P. ``all_run_*`` equal cand_*
        (kept explicit: Eq. 3 normalizes over all running BE jobs).
        """
        raise NotImplementedError

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        """Per-candidate preemption-order key, LOWER = preempt first
        (used by the engine's gang selection; ``cand_demand`` arrives
        pre-scaled by gang width so Eq. 1 sees total demand)."""
        raise NotImplementedError


class FifoPolicy(Policy):
    name = "fifo"
    preemptive = False

    def select(self, *a, **k) -> List[int]:
        return []


class FitGppPolicy(Policy):
    """The paper's algorithm (Eq. 1-4)."""
    name = "fitgpp"

    def __init__(self, s: float = 4.0):
        self.s = s

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        if len(cand_ids) == 0:
            return []
        scores = fitgpp_scores(all_run_demand, all_run_gp, node_cap, self.s)
        elig = eligible_eq2(te_demand, cand_demand, cand_node_free)
        mask = elig & under_cap
        if mask.any():
            # Eq. 4: argmin score among eligible, under the P cap.
            masked = np.where(mask, scores, np.inf)
            return [int(cand_ids[int(np.argmin(masked))])]
        # Fallback (paper): preempt a random running BE job; the simulator
        # re-invokes the policy if that did not make enough room.
        pick = int(rng.integers(len(cand_ids)))
        return [int(cand_ids[pick])]

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        return fitgpp_scores(cand_demand, cand_gp, node_cap, self.s)


class LrtpPolicy(Policy):
    """Big-C's policy: Longest Remaining Time Preemption (oracle runtime).

    Keeps preempting, longest-remaining first, until some node could fit
    the TE job (free + signalled victims' demand on that node).
    """
    name = "lrtp"

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        return _preempt_until_fits(
            order=np.argsort(-cand_remaining, kind="stable"),
            te_demand=te_demand, cand_ids=cand_ids, cand_demand=cand_demand,
            cand_node=cand_node, under_cap=under_cap,
            free_by_node=free_by_node, rng=rng)

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        return -np.asarray(cand_remaining, float)


class RandPolicy(Policy):
    name = "rand"

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        return _preempt_until_fits(
            order=rng.permutation(len(cand_ids)),
            te_demand=te_demand, cand_ids=cand_ids, cand_demand=cand_demand,
            cand_node=cand_node, under_cap=under_cap,
            free_by_node=free_by_node, rng=rng)

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        return rng.random(len(cand_gp))


def _preempt_until_fits(order, te_demand, cand_ids, cand_demand, cand_node,
                        under_cap, free_by_node, rng) -> List[int]:
    """Walk candidates in ``order`` (P-capped first), accumulating pending
    frees per node, until the TE job fits on some node."""
    pending = free_by_node.copy()
    victims: List[int] = []
    # candidates under the cap first; over-cap ones as a last resort
    ordered = [i for i in order if under_cap[i]] + \
              [i for i in order if not under_cap[i]]
    for i in ordered:
        node = int(cand_node[i])
        pending[node] += cand_demand[i]
        victims.append(int(cand_ids[i]))
        if np.all(te_demand <= pending[node] + FIT_EPS):
            return victims
    return victims   # even preempting everyone wasn't enough


def make_policy(name: str, s: float = 4.0) -> Policy:
    if name == "fitgpp":
        return FitGppPolicy(s)
    return {"fifo": FifoPolicy, "rand": RandPolicy,
            "lrtp": LrtpPolicy}[name]()
