"""Preemption decision rules: FitGpp (the paper, Eq. 1-4) + baselines.

A policy answers ONE question: given an incoming TE job that does not
fit anywhere, which running BE job(s) should be signalled to vacate?

Every policy is a :class:`Policy` subclass registered ONCE under
``@register_policy`` (``core/policy_registry.py``) and declares every
backend it supports in that one place:

* **reference (numpy)** — ``select`` / ``rank_key``, operating on
  plain numpy views of the simulator state so the reference engines
  stay transparent;
* **JAX** — ``jax_kind`` names the engine contract the class fulfils:
  ``"rank"`` policies provide ``jax_rank`` (a per-job preemption-order
  value consumed by the engine's signal-until-the-TE-fits loop), and
  ``"score"`` policies provide ``jax_score`` (Eq. 4-shaped: masked
  argmin over eligible candidates, random fallback — the engine owns
  the masking and the fallback);
* **accelerated score backends** (optional) — ``score_backends``
  beyond the default ``"jnp"``, e.g. FitGpp's Pallas ``fitgpp_score``
  kernel as ``"pallas"``, selectable via ``SimConfig.score_backend``
  and dispatched through ``jax_score_accel``.

The jnp/jax imports inside the ``jax_*`` methods are deliberately
lazy: the reference engines never call them, so this module (and the
numpy simulator) stays importable without JAX.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.configs.base import PAPER_S
from repro.core.engine.placement import FIT_EPS
from repro.core.policy_registry import (RNG_ALWAYS, RNG_FALLBACK,
                                        register_policy)


def size_eq1(demand: np.ndarray, node_cap: np.ndarray) -> np.ndarray:
    """Eq. 1: scale-invariant demand size, ||D / capacity||_2.

    demand (..., 3); node_cap (3,).
    """
    return np.sqrt(np.sum((demand / node_cap) ** 2, axis=-1))


def fitgpp_scores(demand: np.ndarray, gp: np.ndarray, node_cap: np.ndarray,
                  s: float) -> np.ndarray:
    """Eq. 3 over the set of running BE jobs.

    Normalizers are max over ALL running BE jobs (the paper's J), not
    just the eligible subset.
    """
    sz = size_eq1(demand, node_cap)
    max_sz = max(sz.max(initial=0.0), 1e-12)
    max_gp = max(gp.max(initial=0), 1e-12)
    return sz / max_sz + s * (gp / max_gp)


def eligible_eq2(te_demand: np.ndarray, demand: np.ndarray,
                 node_free: np.ndarray) -> np.ndarray:
    """Eq. 2: D_TE <= D_j + N_free(node_j), element-wise, per job.

    demand (m, 3) of running BE jobs; node_free (m, 3) free vector of the
    node each candidate runs on. FIT_EPS-tolerant, like every other fit
    check (and like the JAX engine's eligibility mask).
    """
    return np.all(te_demand[None, :] <= demand + node_free + FIT_EPS, axis=1)


def _jax_size_eq1(demand, node_cap):
    """Eq. 1 in jnp — the one jnp mirror of :func:`size_eq1`, shared by
    every score policy's ``jax_score`` (single source for the norm)."""
    import jax.numpy as jnp
    return jnp.sqrt(jnp.sum((demand / node_cap) ** 2, axis=-1))


class Policy:
    """Base decision rule; subclasses declare their backends (module
    docstring) and register via ``@register_policy``.

    Reference contract — ``select`` returns victim job indices (into
    the global job array); ``rank_key`` returns a per-candidate
    preemption-order key, LOWER = preempt first (used by the engine's
    gang selection; ``cand_demand`` arrives pre-scaled by gang width so
    Eq. 1 sees total demand).

    JAX contract (``st``/``jobs`` are ``sim_jax.State``/``Jobs``):

    * ``jax_kind = "rank"`` → ``jax_rank(st, jobs) -> (st, rank)``:
      rank (N,) float32, HIGHER = preempt first; may consume
      ``st.rng`` (return the advanced state).
    * ``jax_kind = "score"`` → ``jax_score(jobs, cand, node_cap, s)
      -> (N,)`` scores, LOWER = better victim (``cand`` masks running
      BE jobs for any normalizers). The engine applies Eq. 2
      eligibility (against each victim's BEST assigned node — the
      gang-aware ``best_victim_node`` reduction), the P cap, the
      masked argmin and the paper's random fallback. For gang TEs the
      engine re-evaluates the score over TOTAL gang demand
      (``demand * width``) and runs the gang-select strategy instead.
    * extra ``score_backends`` → ``jax_score_accel(backend, jobs, te,
      free, assign, cand, under, node_cap, s, pending_free=...,
      queue_key=..., be_q=...) -> victim index or -1`` (the whole
      schedule pass — score, best-node Eq. 2 reduction, masked
      argmin, gang-fit tiles and BE queue scan — fused on ONE
      accelerated kernel invocation; ``free``/``pending_free`` are
      the (nodes, 3) cluster matrices and ``assign`` the
      (jobs, nodes) placement-mask tile).
    """
    name = "base"
    preemptive = True
    jax_kind: str = None                    # None | "rank" | "score"
    argmin_select = False                   # Eq. 4-style single victim
    score_backends: Tuple[str, ...] = ("jnp",)

    def __init__(self, s: float = PAPER_S):
        self.s = float(s)

    # -- reference (numpy) backend ------------------------------------------

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        """Return victim job indices (into the global job array).

        cand_* arrays cover ALL currently running BE jobs; ``under_cap``
        marks those with PreemptionCount < P. ``all_run_*`` equal cand_*
        (kept explicit: Eq. 3 normalizes over all running BE jobs).
        """
        raise NotImplementedError

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        raise NotImplementedError

    # -- JAX backend declarations (lazy jnp; see class docstring) -----------

    def jax_rank(self, st, jobs):
        raise NotImplementedError(f"{self.name}: no jax_rank declared")

    def jax_score(self, jobs, cand, node_cap, s):
        raise NotImplementedError(f"{self.name}: no jax_score declared")

    def jax_score_accel(self, backend, jobs, te, free, assign, cand,
                        under, node_cap, s, *, pending_free=None,
                        queue_key=None, be_q=None):
        raise NotImplementedError(
            f"{self.name}: no accelerated score backend {backend!r}")


@register_policy("fifo", description="Non-preemptive FIFO baseline "
                                     "(TE and BE share one queue)")
class FifoPolicy(Policy):
    preemptive = False

    def select(self, *a, **k) -> List[int]:
        return []


def _argmin_score_select(rng, cand_ids, scores, elig, under_cap) -> List[int]:
    """Eq. 4 shape shared by the score policies: argmin score among
    eligible under-P-cap candidates; fallback (paper): preempt a random
    running BE job — the simulator re-invokes the policy if that did
    not make enough room."""
    mask = elig & under_cap
    if mask.any():
        masked = np.where(mask, scores, np.inf)
        return [int(cand_ids[int(np.argmin(masked))])]
    pick = int(rng.integers(len(cand_ids)))
    return [int(cand_ids[pick])]


@register_policy("fitgpp", rng=RNG_FALLBACK,
                 description="The paper's algorithm (Eq. 1-4): smallest "
                             "sufficient victim, GP-weighted")
class FitGppPolicy(Policy):
    """The paper's algorithm (Eq. 1-4)."""
    jax_kind = "score"
    argmin_select = True
    score_backends = ("jnp", "pallas")

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        if len(cand_ids) == 0:
            return []
        scores = fitgpp_scores(all_run_demand, all_run_gp, node_cap, self.s)
        elig = eligible_eq2(te_demand, cand_demand, cand_node_free)
        return _argmin_score_select(rng, cand_ids, scores, elig, under_cap)

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        return fitgpp_scores(cand_demand, cand_gp, node_cap, self.s)

    def jax_score(self, jobs, cand, node_cap, s):
        import jax.numpy as jnp
        sz = _jax_size_eq1(jobs.demand, node_cap)
        max_sz = jnp.maximum(jnp.max(jnp.where(cand, sz, 0.0)), 1e-12)
        max_gp = jnp.maximum(jnp.max(jnp.where(cand, jobs.gp, 0)), 1e-12)
        return sz / max_sz + s * (jobs.gp / max_gp)

    def jax_score_accel(self, backend, jobs, te, free, assign, cand,
                        under, node_cap, s, *, pending_free=None,
                        queue_key=None, be_q=None):
        """The whole Eq. 1-4 pass fused on the Pallas ``schedule_step``
        kernel over the (jobs, nodes) tile — score, best-node Eq. 2
        reduction, masked argmin, gang-fit counts and the BE queue
        scan in one invocation (bit-parity-tested vs ``jax_score``;
        requires static ``s`` — it is baked into the kernel). The
        victim selection consumes only ``.victim``."""
        assert backend == "pallas", backend
        import jax.numpy as jnp
        from repro.kernels import ops as kops
        J = jobs.gp.shape[0]
        M = free.shape[0]
        if pending_free is None:
            pending_free = jnp.zeros((M, 3), jnp.float32)
        if queue_key is None:
            queue_key = jnp.full((J,), jnp.inf, jnp.float32)
        if be_q is None:
            be_q = jnp.zeros((J,), bool)
        ps = kops.schedule_step(
            jobs.demand, jobs.gp.astype(jnp.float32), jobs.width,
            queue_key, assign, free, pending_free, cand, under, be_q,
            jobs.demand[te], node_cap, s=s)
        return ps.victim


@register_policy("lrtp", description="Big-C baseline: longest remaining "
                                     "time preempted first (oracle runtime)")
class LrtpPolicy(Policy):
    """Big-C's policy: Longest Remaining Time Preemption (oracle runtime).

    Keeps preempting, longest-remaining first, until some node could fit
    the TE job (free + signalled victims' demand on that node).
    """
    jax_kind = "rank"

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        return _preempt_until_fits(
            order=np.argsort(-cand_remaining, kind="stable"),
            te_demand=te_demand, cand_ids=cand_ids, cand_demand=cand_demand,
            cand_node=cand_node, under_cap=under_cap,
            free_by_node=free_by_node, rng=rng)

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        return -np.asarray(cand_remaining, float)

    def jax_rank(self, st, jobs):
        import jax.numpy as jnp
        return st, st.remaining.astype(jnp.float32)


@register_policy("srtp", description="BEYOND-PAPER: shortest remaining "
                                     "time preempted first (cheap victims, "
                                     "oracle runtime)")
class SrtpPolicy(Policy):
    """Shortest Remaining Time Preemption: the LRTP mirror — victims
    nearest to completion vacate first, minimizing lost work per
    preemption at the cost of delaying almost-done jobs."""
    jax_kind = "rank"

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        return _preempt_until_fits(
            order=np.argsort(cand_remaining, kind="stable"),
            te_demand=te_demand, cand_ids=cand_ids, cand_demand=cand_demand,
            cand_node=cand_node, under_cap=under_cap,
            free_by_node=free_by_node, rng=rng)

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        return np.asarray(cand_remaining, float)

    def jax_rank(self, st, jobs):
        import jax.numpy as jnp
        return st, -st.remaining.astype(jnp.float32)


@register_policy("rand", rng=RNG_ALWAYS,
                 description="Random running BE victims until the TE fits")
class RandPolicy(Policy):

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        return _preempt_until_fits(
            order=rng.permutation(len(cand_ids)),
            te_demand=te_demand, cand_ids=cand_ids, cand_demand=cand_demand,
            cand_node=cand_node, under_cap=under_cap,
            free_by_node=free_by_node, rng=rng)

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        return rng.random(len(cand_gp))

    jax_kind = "rank"

    def jax_rank(self, st, jobs):
        import jax
        rng, sub = jax.random.split(st.rng)
        return (st._replace(rng=rng),
                jax.random.uniform(sub, st.remaining.shape))


@register_policy("minsize", rng=RNG_FALLBACK,
                 description="BEYOND-PAPER: Eq. 1-only FitGpp ablation "
                             "(smallest sufficient victim, GP-blind)")
class MinSizePolicy(Policy):
    """FitGpp with the grace-period term removed: argmin of the Eq. 1
    size among Eq. 2-eligible candidates. Isolates how much of FitGpp's
    win comes from demand-sufficiency alone vs the GP weighting."""
    jax_kind = "score"
    argmin_select = True

    def select(self, rng, te_demand, cand_ids, cand_demand, cand_node_free,
               cand_gp, cand_remaining, under_cap, all_run_demand,
               all_run_gp, node_cap, free_by_node, cand_node) -> List[int]:
        if len(cand_ids) == 0:
            return []
        scores = size_eq1(all_run_demand, node_cap)
        elig = eligible_eq2(te_demand, cand_demand, cand_node_free)
        return _argmin_score_select(rng, cand_ids, scores, elig, under_cap)

    def rank_key(self, rng, cand_demand, cand_gp, cand_remaining,
                 node_cap) -> np.ndarray:
        return size_eq1(cand_demand, node_cap)

    def jax_score(self, jobs, cand, node_cap, s):
        return _jax_size_eq1(jobs.demand, node_cap)


def _preempt_until_fits(order, te_demand, cand_ids, cand_demand, cand_node,
                        under_cap, free_by_node, rng) -> List[int]:
    """Walk candidates in ``order`` (P-capped first), accumulating pending
    frees per node, until the TE job fits on some node."""
    pending = free_by_node.copy()
    victims: List[int] = []
    # candidates under the cap first; over-cap ones as a last resort
    ordered = [i for i in order if under_cap[i]] + \
              [i for i in order if not under_cap[i]]
    for i in ordered:
        node = int(cand_node[i])
        pending[node] += cand_demand[i]
        victims.append(int(cand_ids[i]))
        if np.all(te_demand <= pending[node] + FIT_EPS):
            return victims
    return victims   # even preempting everyone wasn't enough


def make_policy(name: str, s: float = PAPER_S) -> Policy:
    """Deprecated shim: use ``repro.core.policy_registry.make``."""
    import warnings
    warnings.warn(
        "policies.make_policy is deprecated; use "
        "repro.core.policy_registry.make(name, s=...)",
        DeprecationWarning, stacklevel=2)
    from repro.core.policy_registry import make
    return make(name, s=s)
