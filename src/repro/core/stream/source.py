"""JobSource: the host side of the streaming macro-round engine.

A *job source* is any iterator of submit-sorted :class:`JobSet`
chunks whose submit times are non-decreasing ACROSS chunks too — the
chunked synthetic generator (``core/workload.stream_chunks``), the
streaming trace readers (``scenarios/traces.iter_trace_csv``) and
:func:`from_jobset` all qualify. :class:`JobSource` wraps one with
the two operations the engine's pack loop needs — ``take(k)`` (pull
up to k jobs) and ``peek_submit()`` (the round boundary) — holding at
most one chunk in memory, and validates the ordering contract loudly
at the boundary where it would otherwise silently corrupt queue keys.

``scan`` and ``materialize`` consume a source whole: ``scan`` in one
O(chunk)-memory pass (the CLI ``describe`` path for trace scenarios),
``materialize`` into a monolithic ``JobSet`` (the registry adapter —
and the definition of "the same workload" the parity-window tests
compare the streamed engine against).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Iterator, List, Optional

import numpy as np

from repro.core.types import JobSet

_FIELDS = ("submit", "exec_total", "demand", "is_te", "gp", "n_nodes")


class JobSource:
    """Buffered pull interface over an iterator of JobSet chunks.

    ``stats`` is an optional passthrough for reader-side accounting
    (e.g. ``scenarios.traces.TraceStats`` drop counters) so one-pass
    consumers can report it without a second read.
    """

    def __init__(self, chunks: Iterable[JobSet], stats=None):
        self._it: Optional[Iterator[JobSet]] = iter(chunks)
        self._head: Optional[JobSet] = None
        self._off = 0
        self._last_submit: Optional[int] = None
        self.stats = stats
        self.n_taken = 0

    def _refill(self) -> bool:
        """Ensure the head chunk has an unread row; False = exhausted."""
        while self._head is None or self._off >= self._head.n:
            if self._it is None:
                return False
            try:
                js = next(self._it)
            except StopIteration:
                self._it, self._head = None, None
                return False
            if js.n == 0:
                continue
            if not (np.diff(js.submit) >= 0).all():
                raise ValueError("JobSource chunk is not submit-sorted")
            if (self._last_submit is not None
                    and int(js.submit[0]) < self._last_submit):
                raise ValueError(
                    "JobSource submit times decrease across chunks "
                    f"({self._last_submit} -> {int(js.submit[0])}); the "
                    "stream contract requires globally non-decreasing "
                    "submits")
            self._last_submit = int(js.submit[-1])
            self._head, self._off = js, 0
        return True

    @property
    def exhausted(self) -> bool:
        return not self._refill()

    def peek_submit(self) -> Optional[int]:
        """Submit tick of the next un-taken job; None when exhausted.
        This is the streaming engine's round boundary."""
        if not self._refill():
            return None
        return int(self._head.submit[self._off])

    def take(self, k: int) -> Optional[JobSet]:
        """Pull up to ``k`` jobs (in stream order) as one JobSet;
        None when the source is exhausted."""
        parts: List[tuple] = []
        got = 0
        while got < k and self._refill():
            js, off = self._head, self._off
            n = min(k - got, js.n - off)
            parts.append((js, off, off + n))
            self._off = off + n
            got += n
        if got == 0:
            return None
        self.n_taken += got

        def cat(f):
            return np.concatenate(
                [getattr(js, f)[a:b] for js, a, b in parts])

        return JobSet(**{f: cat(f) for f in _FIELDS})

    def take_due(self, t: int) -> Optional[JobSet]:
        """Pull every job whose submit time is ``<= t`` (in stream
        order) as one JobSet; None when no job is due. The engine's
        spill path: arrivals already overdue that the slot pool cannot
        hold move to an explicit host queue, preserving stream order
        (DESIGN.md §10)."""
        parts: List[tuple] = []
        got = 0
        while self._refill():
            js, off = self._head, self._off
            # chunks are submit-sorted, so the due prefix is a slice
            n = int(np.searchsorted(js.submit[off:], t, side="right"))
            if n == 0:
                break
            parts.append((js, off, off + n))
            self._off = off + n
            got += n
            if self._off < js.n:
                break                     # first not-yet-due job reached
        if got == 0:
            return None
        self.n_taken += got
        return JobSet(**{
            f: np.concatenate([getattr(js, f)[a:b] for js, a, b in parts])
            for f in _FIELDS})


@dataclass
class ScanStats:
    """One-pass stream summary (CLI ``describe`` on trace scenarios)."""
    n_jobs: int = 0
    n_te: int = 0
    n_gang: int = 0
    first_submit: int = -1
    last_submit: int = -1
    total_exec_min: int = 0
    stats: object = field(default=None, repr=False)   # reader accounting

    @property
    def n_be(self) -> int:
        return self.n_jobs - self.n_te

    @property
    def horizon(self) -> int:
        return max(self.last_submit - max(self.first_submit, 0), 0)


def scan(source: JobSource, chunk: int = 8192) -> ScanStats:
    """Consume ``source`` in one bounded-memory pass and summarize."""
    out = ScanStats()
    while True:
        js = source.take(chunk)
        if js is None:
            break
        if out.n_jobs == 0:
            out.first_submit = int(js.submit[0])
        out.last_submit = int(js.submit[-1])
        out.n_jobs += js.n
        out.n_te += int(js.is_te.sum())
        out.n_gang += int((np.asarray(js.n_nodes) > 1).sum())
        out.total_exec_min += int(js.exec_total.sum())
    out.stats = source.stats
    return out


def materialize(source: JobSource, chunk: int = 65536) -> JobSet:
    """Concatenate a whole source into one monolithic JobSet."""
    parts: List[JobSet] = []
    while True:
        js = source.take(chunk)
        if js is None:
            break
        parts.append(js)
    if not parts:
        raise ValueError("materialize() of an empty job source")
    return JobSet(**{
        f: np.concatenate([getattr(js, f) for js in parts])
        for f in _FIELDS})


def from_jobset(js: JobSet, chunk: int = 4096) -> JobSource:
    """A JobSource over an already-materialized JobSet (chunked views;
    no copies) — how a registered trace fixture replays streamed."""
    def gen():
        for a in range(0, js.n, int(chunk)):
            b = min(a + int(chunk), js.n)
            yield JobSet(**{f: getattr(js, f)[a:b] for f in _FIELDS})

    return JobSource(gen())
