"""Streaming closed-loop admission (paper §4.2, DESIGN.md §10).

The paper's headline experiments submit jobs "at such a rate that the
cluster load ... would be kept at 2.0 if they were scheduled by FIFO".
Monolithically that is ``workload.closed_loop_submit_times``: a full
FIFO simulation over the whole jobset whose admit ticks become every
policy's open-loop submit times. This module is the same arrival
process as a *source transformer*: :class:`ClosedLoopAdmission` wraps
any job stream (the input submit times are ignored — the stream is an
arrival ORDER plus job data), runs an incremental FIFO backlog
simulation over a recycled slot pool, and yields submit-sorted chunks
whose ``submit`` fields are the closed-loop admit ticks. Memory is
O(live FIFO backlog + chunk), which the closed loop itself bounds —
independent of the stream length — so the load-2.0 regime streams at
10^5-10^6 jobs.

Bit-exactness contract (the reason this file mirrors
``core/simulator.py`` so closely): the admit ticks must equal the
monolithic ``closed_loop_submit_times`` output EXACTLY on any
materializable stream. That pins down

  * the load fractions (:func:`repro.core.simulator.admission_fraction`
    — row-wise, so chunked evaluation is bitwise equal to whole-array
    evaluation) and the :class:`AdmissionGate` float accumulator the
    two drivers share;
  * the per-tick phase order (admit -> expire_grace -> schedule ->
    run-minute -> tick_clocks), copied from ``Simulator.step``;
  * finish processing in GLOBAL arrival order: the monolithic sim
    finishes jobs in sorted job-index order, so the pool driver sorts
    finishing slots by their global id before calling ``finish`` —
    both the gate's float subtraction order and the cluster free-vector
    accumulation order depend on it;
  * the event-mode fast-forward rule, copied from
    ``Simulator._fast_forward`` (admission due / next finish / next
    grace expiry).

FIFO is non-preemptive (no TE lane, no grace, no rng draws), which is
what makes the slot recycling safe and the mirror small; backfill
(``cfg.backfill``) carries over exactly as it does monolithically,
because both drivers delegate the schedule pass to the same
:class:`SchedulerCore`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.configs.cluster import SimConfig
from repro.core import policy_registry
from repro.core.engine import ClusterState, SchedulerCore
from repro.core.simulator import AdmissionGate, admission_fraction
from repro.core.stream.source import JobSource
from repro.core.types import NOT_ARRIVED, JobSet

_INITIAL_POOL = 256


class ClosedLoopAdmission:
    """Iterable of submit-sorted JobSet chunks whose submit times are
    closed-loop admit ticks (module docstring).

    ``source``: the inner job stream (any ``JobSource`` or chunk
    iterator); its submit times are IGNORED — jobs are admitted in
    stream order. ``target`` is the FIFO-normalized backlog target
    (default ``cfg.workload.load``); ``chunk`` the pending-buffer /
    output chunk size. Iterating runs the embedded FIFO simulation
    lazily; ``n_admitted`` / ``max_live`` / ``pool_capacity`` report
    progress and the realized backlog bound afterwards.
    """

    def __init__(self, cfg: SimConfig, source, target: float = None,
                 chunk: int = 1024, max_ticks: int = 10_000_000):
        # same FIFO re-pointing as workload.closed_loop_submit_times:
        # only the policy changes, so cfg.backfill etc. carry over
        self.cfg = dataclasses.replace(cfg, policy="fifo")
        self.target = float(cfg.workload.load if target is None
                            else target)
        if self.target <= 0:
            raise ValueError(
                f"closed-loop admission needs a positive load target, "
                f"got {self.target}")
        self.source = (source if isinstance(source, JobSource)
                       else JobSource(source))
        self.chunk = int(chunk)
        self.max_ticks = int(max_ticks)
        self.n_admitted = 0
        self.max_live = 0
        self.pool_capacity = 0

    # -- recycled slot pool -------------------------------------------

    def _grow(self, core: SchedulerCore) -> None:
        """Double the slot pool (driver arrays + core arrays together);
        freed capacity is pushed onto the free stack."""
        old = core.state.size
        new = max(_INITIAL_POOL, old * 2)
        core.grow_to(new)
        k = new - old
        self._gp = np.concatenate([self._gp, np.zeros(k, np.int64)])
        self._remaining = np.concatenate(
            [self._remaining, np.zeros(k, np.int64)])
        self._frac = np.concatenate([self._frac, np.zeros(k)])
        self._gid = np.concatenate([self._gid, np.full(k, -1, np.int64)])
        self._free.extend(range(old, new))
        self.pool_capacity = new

    def _admit(self, core: SchedulerCore, js: JobSet, i: int,
               frac: np.ndarray) -> None:
        """Recycle (or grow) a slot for stream job ``i`` of the pending
        chunk and enqueue it."""
        if not self._free:
            self._grow(core)
        s = self._free.pop()
        core.demand[s] = js.demand[i]
        core.is_te[s] = bool(js.is_te[i])
        core.width[s] = int(js.n_nodes[i])
        core.state[s] = NOT_ARRIVED
        core.node[s] = -1
        core.preempt_count[s] = 0
        core.grace_left[s] = 0
        core.victim_of[s] = -1
        core.te_pending[s] = 0
        self._gp[s] = int(js.gp[i])
        self._remaining[s] = int(js.exec_total[i])
        self._frac[s] = frac[i]
        self._gid[s] = self.n_admitted
        core.enqueue(s)
        self.n_admitted += 1
        live = core.state.size - len(self._free)
        if live > self.max_live:
            self.max_live = live

    # -- the embedded FIFO simulation ---------------------------------

    def _fast_forward(self, core: SchedulerCore, gate: AdmissionGate,
                      t: int) -> int:
        """``Simulator._fast_forward`` for the pool driver: un-admitted
        jobs always exist at the call site, so the admission-due check
        reduces to the gate."""
        if core.schedule_would_act():
            return t
        if gate.wants_next():
            return t                          # admission due next tick
        nxt = None
        run = None
        if core.running:
            run = np.fromiter(core.running, np.int64,
                              count=len(core.running))
            nxt = t - 1 + int(self._remaining[run].min())
        g = core.min_grace_left()
        if g is not None:
            ev = t + g
            nxt = ev if nxt is None else min(nxt, ev)
        if nxt is None:
            raise RuntimeError(
                "closed-loop admission stalled: backlog at target but "
                "nothing is running or in grace — a queued job cannot "
                "fit the cluster at all")
        if nxt <= t:
            return t
        if nxt >= self.max_ticks:
            raise RuntimeError(
                f"closed-loop admission did not converge in "
                f"{self.max_ticks} ticks")
        k = nxt - t
        if run is not None:
            self._remaining[run] -= k
        core.tick_clocks(k)
        return nxt

    def __iter__(self) -> Iterator[JobSet]:
        cfg = self.cfg
        node_cap = np.asarray(cfg.cluster.node.as_tuple(), np.float64)
        n_nodes = cfg.cluster.n_nodes
        gate = AdmissionGate(self.target)
        core = SchedulerCore(
            cluster=ClusterState(n_nodes, node_cap),
            policy=policy_registry.make(cfg.policy, s=cfg.s),
            max_preemptions=cfg.max_preemptions,
            rng=np.random.default_rng(cfg.seed + 104729),
            gp_of=lambda ids: self._gp[ids],
            remaining_of=lambda ids: self._remaining[ids],
            backfill=cfg.backfill,
            backfill_depth=cfg.backfill_depth,
        )
        self._gp = np.zeros(0, np.int64)
        self._remaining = np.zeros(0, np.int64)
        self._frac = np.zeros(0)
        self._gid = np.zeros(0, np.int64)
        self._free: List[int] = []
        self._grow(core)

        t = 0
        pending: Optional[JobSet] = None
        pi = 0
        pfrac = padmit = None
        while True:
            if pending is None or pi == pending.n:
                if pending is not None:
                    yield JobSet(submit=padmit,
                                 exec_total=pending.exec_total,
                                 demand=pending.demand,
                                 is_te=pending.is_te, gp=pending.gp,
                                 n_nodes=pending.n_nodes)
                pending = self.source.take(self.chunk)
                if pending is None:
                    return                    # every job admitted
                pi = 0
                pfrac = admission_fraction(
                    np.asarray(pending.demand, np.float64),
                    pending.n_nodes, node_cap, n_nodes)
                padmit = np.zeros(pending.n, np.int64)
            # one Simulator.step, phase for phase ----------------------
            while pi < pending.n and gate.wants_next():
                self._admit(core, pending, pi, pfrac)
                gate.admit(pfrac[pi])
                padmit[pi] = t
                pi += 1
            if pi == pending.n:
                continue       # refill and keep admitting at this tick
            core.expire_grace(t)               # FIFO: structural no-op
            core.schedule(t)
            if core.running:
                run = np.fromiter(core.running, np.int64,
                                  count=len(core.running))
                self._remaining[run] -= 1
                fin = run[self._remaining[run] <= 0]
                # finish in GLOBAL arrival order — the monolithic sim
                # finishes by sorted job index, and both the gate and
                # the cluster free vector accumulate in that order
                for s in fin[np.argsort(self._gid[fin])]:
                    s = int(s)
                    core.finish(s, t + 1)
                    gate.release(self._frac[s])
                    self._free.append(s)
            core.tick_clocks()
            t += 1
            if t >= self.max_ticks:
                raise RuntimeError(
                    f"closed-loop admission did not converge in "
                    f"{self.max_ticks} ticks")
            t = self._fast_forward(core, gate, t)


def closed_loop_source(cfg: SimConfig, n_jobs: int = None,
                       chunk: int = 1024, seed: int = None) -> JobSource:
    """The paper-synthetic workload with streamed closed-loop arrivals:
    ``workload.stream_chunks`` job data (its open-loop submit times
    discarded) re-stamped with admit ticks holding the FIFO-normalized
    backlog at ``cfg.workload.load``. The streamed twin of
    ``workload.generate``'s arrival process, O(chunk + backlog) memory.
    """
    from repro.core import workload
    inner = JobSource(workload.stream_chunks(cfg, n_jobs, chunk=chunk,
                                             seed=seed))
    return JobSource(ClosedLoopAdmission(cfg, inner, chunk=chunk))


def verify_admission_parity(cfg: SimConfig, n_jobs: int = 400,
                            chunk: int = 64) -> List[str]:
    """The admission bit-exactness contract, executable: stream a
    synthetic prefix through :class:`ClosedLoopAdmission` AND compute
    the monolithic ``closed_loop_submit_times`` on the materialized
    job data; return the names of any fields that differ (empty list
    == bit-exact). Job data must pass through unchanged; admit times
    must match the monolithic FIFO simulation exactly."""
    from repro.core import workload
    from repro.core.stream.source import materialize
    streamed = materialize(JobSource(ClosedLoopAdmission(
        cfg, JobSource(workload.stream_chunks(cfg, n_jobs, chunk=chunk)),
        chunk=chunk)))
    data = materialize(JobSource(
        workload.stream_chunks(cfg, n_jobs, chunk=chunk)))
    expect = workload.closed_loop_submit_times(cfg, data)
    diff = [f for f in ("exec_total", "demand", "is_te", "gp", "n_nodes")
            if not np.array_equal(getattr(streamed, f),
                                  getattr(data, f))]
    if not np.array_equal(streamed.submit, expect):
        diff.append("admit_time")
    return diff
