"""Streaming macro-round engine (DESIGN.md §10).

Bounded-memory replay of 10^5-10^6-job traces: a fixed-capacity slot
pool over ``sim_jax`` (``StreamEngine``), fed by chunked
:class:`JobSource` iterators (synthetic ``workload.stream_chunks``,
streaming trace readers, or any jobset via ``from_jobset``), with
per-round event/result draining. Memory scales with ``capacity``
(in-flight jobs), not trace length; results are bit-identical to the
monolithic engine (``verify_prefix_parity``). Closed-loop arrivals
(paper §4.2, load 2.0) stream through the same pool via
``ClosedLoopAdmission`` / ``StreamEngine(..., admission=True)``
(``verify_closed_loop_parity``).

    from repro.core import stream, workload
    src = stream.JobSource(workload.stream_chunks(cfg, 100_000))
    res = stream.StreamEngine(cfg, src, capacity=512).run()
    res.summary()["BE"]["p95"], res.rounds, res.max_live
"""
from repro.core.stream.admission import (ClosedLoopAdmission,
                                         closed_loop_source,
                                         verify_admission_parity)
from repro.core.stream.engine import (AKEY_GID_LIMIT,
                                      DEFAULT_SLOTS_PER_NODE,
                                      StreamEngine, StreamResult,
                                      default_capacity,
                                      verify_closed_loop_parity,
                                      verify_prefix_parity)
from repro.core.stream.source import (JobSource, ScanStats, from_jobset,
                                      materialize, scan)

__all__ = [
    "AKEY_GID_LIMIT", "ClosedLoopAdmission", "DEFAULT_SLOTS_PER_NODE",
    "JobSource", "ScanStats", "StreamEngine", "StreamResult",
    "closed_loop_source", "default_capacity", "from_jobset",
    "materialize", "scan", "verify_admission_parity",
    "verify_closed_loop_parity", "verify_prefix_parity",
]
