"""Streaming macro-round engine (DESIGN.md §10).

Bounded-memory replay of 10^5-10^6-job traces: a fixed-capacity slot
pool over ``sim_jax`` (``StreamEngine``), fed by chunked
:class:`JobSource` iterators (synthetic ``workload.stream_chunks``,
streaming trace readers, or any jobset via ``from_jobset``), with
per-round event/result draining. Memory scales with ``capacity``
(in-flight jobs), not trace length; results are bit-identical to the
monolithic engine (``verify_prefix_parity``).

    from repro.core import stream, workload
    src = stream.JobSource(workload.stream_chunks(cfg, 100_000))
    res = stream.StreamEngine(cfg, src, capacity=512).run()
    res.summary()["BE"]["p95"], res.rounds, res.max_live
"""
from repro.core.stream.engine import (DEFAULT_SLOTS_PER_NODE,
                                      StreamEngine, StreamResult,
                                      default_capacity,
                                      verify_prefix_parity)
from repro.core.stream.source import (JobSource, ScanStats, from_jobset,
                                      materialize, scan)

__all__ = [
    "DEFAULT_SLOTS_PER_NODE", "JobSource", "ScanStats", "StreamEngine",
    "StreamResult", "default_capacity", "from_jobset", "materialize",
    "scan", "verify_prefix_parity",
]
