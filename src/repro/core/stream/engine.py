"""StreamEngine: bounded-memory replay of arbitrarily long traces.

The macro-round idiom (DESIGN.md §10): a fixed-capacity slot pool of
``sim_jax`` state — ``capacity`` rows, O(capacity x nodes) memory, one
compilation — driven by an outer host loop that alternates

  1. *pack*: pull the next arrivals from a :class:`JobSource` and
     scatter them into recycled DONE slots (jitted ``_pack``),
     stamping each job's global sequence number into ``Jobs.akey`` so
     queue keys, requeue ranks and victim tie-breaks keep GLOBAL
     arrival order despite arbitrary slot placement;
  2. *run*: one jitted macro-round (``sim_jax.run_round``) — the
     existing fused ``_Pass``/event-jump loop — until every pool job
     is DONE or ``t`` reaches the round boundary (the earliest submit
     NOT yet packed, folded into the engine's next-arrival cache so no
     event jump can overshoot it);
  3. *drain*: decode the per-round ring buffer (sized off CAPACITY,
     ``obs.ring.round_capacity``), remap slot ids to global job ids,
     and stream events/results out (callback sinks or accumulation).

State (``t``, rng, ``top_key``, free vectors, ``fallback_count``, the
live rows) carries across rounds untouched, which is what makes the
streamed run BIT-IDENTICAL to the monolithic engine on the same
workload — the parity-window contract, checked by
:func:`verify_prefix_parity` (deterministic policies on the jnp score
backend; ``fallback_count`` must stay 0).

A slot is recyclable when its job is DONE and no in-grace victim
still references it (``victim_of`` points at TE slots; vacates
decrement ``te_pending`` through it, so a referenced slot must
survive until the grace period resolves). When the pool is full and
an unpacked arrival is overdue, the overdue jobs SPILL to an explicit
host-side FIFO (:class:`_SpillQueue`, order preserved) and rounds
shrink to one tick until slots free up — saturated load degrades
gracefully instead of aborting. Spilling is NOT silent and NOT
parity-preserving: a spilled job is packed later than it arrived, so
the scheduler could not have considered it in between; ``n_spilled``
is surfaced on the result and :func:`verify_prefix_parity` rejects
spilled runs (DESIGN.md §10).

Closed-loop arrivals (``admission=``): the source is wrapped in
``admission.ClosedLoopAdmission``, which discards the stream's submit
times and re-stamps closed-loop admit ticks — the paper's §4.2
load-2.0 regime in bounded memory (:func:`verify_closed_loop_parity`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cluster import SimConfig
from repro.core import sim_jax, workload
from repro.core.stream.source import _FIELDS, JobSource, materialize
from repro.core.types import JobSet
from repro.obs import ring as obs_ring
from repro.obs import schema as obs_schema

# Default pool size: K slots per (node x preemption-budget) unit —
# enough for the queue depths the repo's open-loop loads produce.
DEFAULT_SLOTS_PER_NODE = 32

_MAX_TICKS = 1 << 22       # must match sim_jax's stall terminal

# ``Jobs.akey`` carries the global sequence number as float32, whose
# exact-integer range ends at 2^24: the next gid would round onto the
# previous one, silently breaking queue-key / requeue / victim
# tie-break global arrival order. Packing past this limit raises.
AKEY_GID_LIMIT = 1 << 24

# aux carries a TE job id (not a count) on these codes — remapped
# slot->gid at drain time like the job column itself
_AUX_JOB_CODES = (obs_schema.PREEMPT_SIGNAL, obs_schema.VACATE)

_RESULT_COLS = ("submit", "exec_total", "is_te", "width", "finish",
                "preempt_count", "last_signal", "last_vacate",
                "last_resume")


def default_capacity(cfg: SimConfig, P: Optional[int] = None) -> int:
    P = cfg.max_preemptions if P is None else P
    return max(64, DEFAULT_SLOTS_PER_NODE * cfg.cluster.n_nodes
               * max(int(P), 1))


def _empty_pool(capacity: int, n_nodes: int) -> sim_jax.Jobs:
    """An all-sentinel pool: every slot invalid (born DONE), ready to
    be recycled by the first pack."""
    return sim_jax.Jobs(
        submit=jnp.zeros((capacity,), jnp.int32),
        exec_total=jnp.ones((capacity,), jnp.int32),
        demand=jnp.zeros((capacity, 3), jnp.float32),
        is_te=jnp.zeros((capacity,), bool),
        gp=jnp.zeros((capacity,), jnp.int32),
        width=jnp.ones((capacity,), jnp.int32),
        valid=jnp.zeros((capacity,), bool),
        akey=jnp.full((capacity,), jnp.inf, jnp.float32),
    )


@jax.jit
def _pack(jobs: sim_jax.Jobs, st: sim_jax.State, slots: jax.Array,
          chunk: sim_jax.Jobs, n_new: jax.Array):
    """Scatter ``chunk`` (padded to a fixed width) into the DONE slots
    ``slots`` (padding rows point at ``capacity`` and drop), resetting
    every per-slot State field the previous tenant touched. All shapes
    are fixed by ``capacity``, so every round of a replay reuses this
    one compilation. The recycled slots were DONE, so un-DONE-ing
    ``n_new`` of them is the only ``n_done`` adjustment needed."""
    def put(arr, val):
        return arr.at[slots].set(val, mode="drop")

    jobs = jobs._replace(
        submit=put(jobs.submit, chunk.submit),
        exec_total=put(jobs.exec_total, chunk.exec_total),
        demand=put(jobs.demand, chunk.demand),
        is_te=put(jobs.is_te, chunk.is_te),
        gp=put(jobs.gp, chunk.gp),
        width=put(jobs.width, chunk.width),
        valid=put(jobs.valid, True),
        akey=put(jobs.akey, chunk.akey),
    )
    st = st._replace(
        state=put(st.state, sim_jax.NOT_ARRIVED),
        remaining=put(st.remaining, chunk.exec_total),
        assign=put(st.assign, False),
        preempt_count=put(st.preempt_count, 0),
        grace_left=put(st.grace_left, 0),
        queue_key=put(st.queue_key, jnp.inf),
        finish=put(st.finish, -1),
        te_pending=put(st.te_pending, 0),
        victim_of=put(st.victim_of, -1),
        last_signal=put(st.last_signal, -1),
        last_vacate=put(st.last_vacate, -1),
        last_resume=put(st.last_resume, -1),
        awaiting_resume=put(st.awaiting_resume, False),
        n_done=st.n_done - n_new.astype(jnp.int32),
    )
    return jobs, st


class _SpillQueue:
    """Host-side FIFO for arrivals that are due while every slot is
    occupied (module docstring): jobs move here from the source in
    stream order and are packed back out spill-first, so the global
    arrival order — and therefore the gid sequence — is preserved
    exactly. ``n`` is the current depth, ``peak``/``total`` the
    high-water mark and the lifetime spill count surfaced on
    :class:`StreamResult`."""

    def __init__(self):
        self._chunks: List[JobSet] = []
        self._off = 0
        self.n = 0
        self.peak = 0
        self.total = 0

    def push(self, js: JobSet) -> None:
        self._chunks.append(js)
        self.n += js.n
        self.total += js.n
        if self.n > self.peak:
            self.peak = self.n

    def peek_submit(self) -> Optional[int]:
        if not self._chunks:
            return None
        return int(self._chunks[0].submit[self._off])

    def take(self, k: int) -> Optional[JobSet]:
        parts: List[tuple] = []
        got = 0
        while got < k and self._chunks:
            js = self._chunks[0]
            n = min(k - got, js.n - self._off)
            parts.append((js, self._off, self._off + n))
            self._off += n
            got += n
            if self._off == js.n:
                self._chunks.pop(0)
                self._off = 0
        if got == 0:
            return None
        self.n -= got
        return JobSet(**{
            f: np.concatenate([getattr(js, f)[a:b] for js, a, b in parts])
            for f in _FIELDS})


def _np_masked_percentiles(vals, mask, ps) -> Dict[str, float]:
    """numpy twin of ``sim_jax.masked_percentiles`` (same NaN-safe
    empty-class semantics, same linear interpolation)."""
    if not mask.any():
        return {f"p{p}": float("nan") for p in ps}
    v = np.where(mask, vals, np.nan).astype(np.float64)
    return {f"p{p}": float(np.nanpercentile(v, p)) for p in ps}


@dataclass
class StreamResult:
    """Per-job results of a streamed replay, gid-ordered (gid = global
    arrival sequence number). ``summary()`` mirrors
    ``sim_jax.result_summary`` so downstream table formatting is
    engine-agnostic. ``events`` is the remapped canonical stream when
    tracing without an ``event_sink``, else None."""
    n_jobs: int
    capacity: int
    rounds: int
    makespan: int
    fallback_count: int
    trace_overflow: int
    max_live: int
    final_rng: np.ndarray = field(repr=False)
    submit: np.ndarray = field(repr=False)
    exec_total: np.ndarray = field(repr=False)
    is_te: np.ndarray = field(repr=False)
    width: np.ndarray = field(repr=False)
    finish: np.ndarray = field(repr=False)
    preempt_count: np.ndarray = field(repr=False)
    last_signal: np.ndarray = field(repr=False)
    last_vacate: np.ndarray = field(repr=False)
    last_resume: np.ndarray = field(repr=False)
    events: Optional[List] = field(repr=False, default=None)
    # jobs that were due while the pool was full and waited in the
    # host spill queue (lifetime count / high-water depth). Nonzero
    # means the run left the bit-parity domain — the backlog outgrew
    # the pool and packing was delayed (module docstring).
    n_spilled: int = 0
    spill_peak: int = 0

    def slowdown(self) -> np.ndarray:
        waiting = self.finish - self.submit - self.exec_total
        return 1.0 + waiting / self.exec_total

    def summary(self) -> dict:
        sd = self.slowdown()
        te, be = self.is_te, ~self.is_te
        out = {"TE": _np_masked_percentiles(sd, te, (50, 95, 99)),
               "BE": _np_masked_percentiles(sd, be, (50, 95, 99))}
        out["preempted_frac"] = (
            float((self.preempt_count[be] > 0).mean()) if be.any()
            else float("nan"))
        iv = (self.last_resume - self.last_signal).astype(np.float64)
        out["intervals"] = _np_masked_percentiles(
            iv, self.last_resume >= 0, (50, 75, 95, 99))
        out["fallback_count"] = self.fallback_count
        out["trace_overflow"] = self.trace_overflow
        out["n_spilled"] = self.n_spilled
        return out


class StreamEngine:
    """Host driver for the macro-round loop (module docstring).

    ``event_sink`` / ``result_sink``: optional per-round callbacks
    (``sink(list_of_events)`` / ``sink(dict_of_np_arrays)``). With a
    sink, the corresponding stream is NOT accumulated — true
    O(capacity) memory end to end; without one, results (a few scalars
    per job) and traced events are collected into the result.

    ``admission``: closed-loop arrival mode (paper §4.2). A float is
    the FIFO-normalized backlog target; ``True`` uses
    ``cfg.workload.load``. The source is wrapped in
    ``admission.ClosedLoopAdmission`` — its submit times are discarded
    and re-stamped as closed-loop admit ticks, bit-exact with the
    monolithic ``workload.closed_loop_submit_times``. ``None``/``0``
    keeps the open-loop path.
    """

    def __init__(self, cfg: SimConfig, source: JobSource,
                 capacity: Optional[int] = None,
                 time_mode: Optional[str] = None,
                 trace: bool = False,
                 trace_capacity: Optional[int] = None,
                 event_sink: Optional[Callable] = None,
                 result_sink: Optional[Callable] = None,
                 admission=None):
        self.cfg = cfg
        self.admission: Optional[float] = None
        if admission:
            from repro.core.stream.admission import ClosedLoopAdmission
            target = (cfg.workload.load if admission is True
                      else float(admission))
            self.admission = target
            source = JobSource(
                ClosedLoopAdmission(cfg, source, target=target))
        self.source = source
        self.capacity = int(capacity if capacity is not None
                            else default_capacity(cfg))
        self.time_mode = cfg.time_mode if time_mode is None else time_mode
        self.trace = bool(trace)
        self.trace_capacity = (
            int(trace_capacity) if trace_capacity is not None
            else obs_ring.round_capacity(self.capacity,
                                         cfg.max_preemptions))
        self.event_sink = event_sink
        self.result_sink = result_sink

    # -- host-side round phases --------------------------------------

    def _reset(self) -> None:
        """Fresh per-run host state (factored out of ``run`` so tests
        can interpose — e.g. forging ``_n_seen`` to hit the akey
        limit without packing 2^24 jobs)."""
        self._slot_gid = np.full(self.capacity, -1, np.int64)
        self._harvested = np.zeros(self.capacity, bool)
        self._n_seen = 0
        self._overflow = 0
        self._events: List = []
        self._batches: List[dict] = []
        self._spill = _SpillQueue()

    def _take_arrivals(self, k: int) -> Optional[JobSet]:
        """Pull up to ``k`` jobs, spill queue first: spilled jobs
        arrived before anything still in the source, so draining them
        first keeps the gid sequence in global arrival order."""
        parts: List[JobSet] = []
        got = 0
        js = self._spill.take(k)
        if js is not None:
            parts.append(js)
            got = js.n
        if got < k:
            js = self.source.take(k - got)
            if js is not None:
                parts.append(js)
        if not parts:
            return None
        if len(parts) == 1:
            return parts[0]
        return JobSet(**{
            f: np.concatenate([getattr(js, f) for js in parts])
            for f in _FIELDS})

    def _pack_round(self, jobs, st, state_h):
        """Recycle free slots with the next arrivals (spill queue
        first); returns the updated pool and the round boundary (next
        unpacked submit)."""
        cap = self.capacity
        # a DONE TE slot referenced by an in-grace victim is NOT
        # recyclable: its vacate still decrements te_pending there
        ref = np.zeros(cap, bool)
        grace = state_h == sim_jax.GRACE
        if grace.any():
            vo = np.asarray(st.victim_of)[grace]
            ref[vo[vo >= 0]] = True
        free = np.flatnonzero((state_h == sim_jax.DONE) & ~ref)
        n_packed = 0
        if free.size:
            js = self._take_arrivals(int(free.size))
            if js is not None:
                n_packed = js.n
                if self._n_seen + n_packed > AKEY_GID_LIMIT:
                    raise RuntimeError(
                        f"stream gid would pass {AKEY_GID_LIMIT} "
                        f"(2^24), the float32 akey exact-integer "
                        "limit: queue keys would collide and global "
                        "arrival order would silently break. Split "
                        "the replay at this boundary.")
                slots = np.full(cap, cap, np.int32)    # cap = dropped
                slots[:n_packed] = free[:n_packed]
                gids = np.arange(self._n_seen,
                                 self._n_seen + n_packed, dtype=np.int64)
                chunk = sim_jax.Jobs(
                    submit=self._pad(js.submit, np.int32),
                    exec_total=self._pad(js.exec_total, np.int32),
                    demand=self._pad(js.demand, np.float32),
                    is_te=self._pad(js.is_te, bool),
                    gp=self._pad(js.gp, np.int32),
                    width=self._pad(js.n_nodes, np.int32),
                    valid=jnp.ones((cap,), bool),
                    akey=self._pad(gids, np.float32),
                )
                jobs, st = _pack(jobs, st, jnp.asarray(slots), chunk,
                                 jnp.asarray(n_packed, jnp.int32))
                self._slot_gid[free[:n_packed]] = gids
                self._harvested[free[:n_packed]] = False
                self._n_seen += n_packed
        nxt = self._spill.peek_submit()
        if nxt is None:
            nxt = self.source.peek_submit()
        if (nxt is not None and nxt <= int(st.t)
                and free.size - n_packed == 0):
            # saturated: arrivals are overdue and every slot is busy.
            # Move the whole due prefix to the host spill queue (stream
            # order preserved) and shrink the round to one tick so the
            # next pack sees freshly freed slots as soon as possible.
            moved = self.source.take_due(int(st.t))
            if moved is not None:
                self._spill.push(moved)
            nxt = int(st.t) + 1
        return jobs, st, nxt

    def _pad(self, a, dtype):
        out = np.zeros((self.capacity,) + np.shape(a)[1:], dtype)
        out[:len(a)] = a
        return out

    def _drain_events(self, st):
        """Decode + slot->gid remap this round's ring; returns the
        State with ``ev_n`` reset for the next round."""
        if not self.trace:
            return st
        events, overflow = obs_ring.decode_ring(st.ev_buf, st.ev_n)
        self._overflow += int(overflow)
        gid = self._slot_gid
        remapped = [
            obs_schema.Event(
                t=e.t, code=e.code, job=int(gid[e.job]),
                aux=(int(gid[e.aux])
                     if e.code in _AUX_JOB_CODES and e.aux >= 0
                     else e.aux),
                nodes=e.nodes)
            for e in events]
        if self.event_sink is not None:
            self.event_sink(remapped)
        else:
            self._events.extend(remapped)
        return st._replace(ev_n=jnp.zeros((), jnp.int32))

    def _harvest(self, jobs, st, state_h):
        """Collect per-job results for newly finished slots."""
        done = ((state_h == sim_jax.DONE) & np.asarray(jobs.valid)
                & ~self._harvested)
        idx = np.flatnonzero(done)
        if idx.size == 0:
            return 0
        self._harvested[idx] = True
        batch = {"gid": self._slot_gid[idx]}
        pool = {"submit": jobs.submit, "exec_total": jobs.exec_total,
                "is_te": jobs.is_te, "width": jobs.width,
                "finish": st.finish, "preempt_count": st.preempt_count,
                "last_signal": st.last_signal,
                "last_vacate": st.last_vacate,
                "last_resume": st.last_resume}
        for k, arr in pool.items():
            batch[k] = np.asarray(arr)[idx]
        if self.result_sink is not None:
            self.result_sink(batch)
        else:
            self._batches.append(batch)
        return idx.size

    # -- the macro-round loop ----------------------------------------

    def run(self) -> StreamResult:
        cfg, cap = self.cfg, self.capacity
        n_nodes = cfg.cluster.n_nodes
        jobs = _empty_pool(cap, n_nodes)
        st = sim_jax.init_state(
            jobs, n_nodes, cfg.cluster.node.as_tuple(), cfg.seed,
            trace_capacity=self.trace_capacity if self.trace else 0)
        self._reset()
        rounds, n_done, max_live = 0, 0, 0

        while True:
            state_h = np.asarray(st.state)
            jobs, st, nxt = self._pack_round(jobs, st, state_h)
            live = cap - int(st.n_done)
            max_live = max(max_live, live)
            if live == 0 and nxt is None:
                break                      # drained: nothing left anywhere
            before = (int(st.t), n_done, self._n_seen)
            st = sim_jax.run_round(cfg, jobs, st, round_end=nxt,
                                   time_mode=self.time_mode,
                                   trace=self.trace)
            rounds += 1
            if int(st.t) >= _MAX_TICKS:
                raise RuntimeError(
                    f"streamed run stalled: t reached the {_MAX_TICKS}"
                    "-tick terminal with jobs unfinished")
            st = self._drain_events(st)
            state_h = np.asarray(st.state)
            n_done += self._harvest(jobs, st, state_h)
            if (int(st.t), n_done, self._n_seen) == before:
                raise RuntimeError(
                    "streamed run made no progress in a round "
                    f"(t={int(st.t)}, done={n_done}) — engine bug")

        return self._finalize(st, rounds, n_done, max_live)

    def _finalize(self, st, rounds, n_done, max_live) -> StreamResult:
        if self.result_sink is None:
            gids = np.concatenate([b["gid"] for b in self._batches]) \
                if self._batches else np.zeros(0, np.int64)
            order = np.argsort(gids)
            gids = gids[order]
            if not (gids == np.arange(len(gids))).all():
                raise RuntimeError(
                    "slot recycling lost or duplicated global job ids")
            cols = {k: np.concatenate([b[k] for b in self._batches])[order]
                    if self._batches else np.zeros(0, np.int64)
                    for k in _RESULT_COLS}
        else:
            cols = {k: np.zeros(0, np.int64) for k in _RESULT_COLS}
        return StreamResult(
            n_jobs=n_done, capacity=self.capacity, rounds=rounds,
            makespan=int(st.t), fallback_count=int(st.fallback_count),
            trace_overflow=self._overflow, max_live=max_live,
            final_rng=np.asarray(jax.random.key_data(st.rng)),
            events=(self._events if self.trace
                    and self.event_sink is None else None),
            n_spilled=self._spill.total, spill_peak=self._spill.peak,
            **cols)


def _reject_spilled(res: StreamResult) -> None:
    """Spilled jobs were packed later than they arrived, so the
    scheduler could not have considered them in between — the run left
    the bit-parity domain (module docstring). Checked BEFORE the
    monolithic comparison run: a spilled saturated run often also
    stalls or diverges monolithically."""
    if res.n_spilled:
        raise ValueError(
            f"parity window does not cover spilled runs: "
            f"{res.n_spilled} jobs waited in the host spill queue "
            f"(peak depth {res.spill_peak}) because the pool was "
            "full while they were due; raise capacity")


def _diff_vs_monolithic(cfg: SimConfig, res: StreamResult, js: JobSet,
                        time_mode: Optional[str]) -> List[str]:
    """Run ``js`` through the monolithic ``sim_jax`` engine and return
    the names of any per-job/result fields that differ from the
    streamed result ``res`` (empty list == bit-exact parity)."""
    from repro.core import policy_registry
    jobs = sim_jax.jobs_from_jobset(js)
    st = sim_jax.run_jit(cfg, jobs, cfg.seed, time_mode=time_mode)
    # Score policies' random fallback draws from a pool-size-dependent
    # categorical — any such draw leaves the parity domain. Rank
    # policies' fallback counter (over-P-cap last resort) is
    # deterministic and stays inside it.
    if policy_registry.get_policy(cfg.policy).jax_kind == "score" and (
            res.fallback_count or int(st.fallback_count)):
        raise ValueError(
            "parity window needs fallback_count == 0 for score "
            "policies (the random fallback draw is pool-size "
            f"dependent); got stream={res.fallback_count} "
            f"monolithic={int(st.fallback_count)}")
    mono = {"finish": st.finish, "preempt_count": st.preempt_count,
            "last_signal": st.last_signal,
            "last_vacate": st.last_vacate,
            "last_resume": st.last_resume}
    diff = [k for k, v in mono.items()
            if not (np.asarray(v) == getattr(res, k)).all()]
    if res.makespan != int(st.t):
        diff.append("t")
    if not (res.final_rng
            == np.asarray(jax.random.key_data(st.rng))).all():
        diff.append("rng")
    return diff


def verify_prefix_parity(cfg: SimConfig, n_jobs: int = 512,
                         capacity: int = 160, chunk: int = 128,
                         time_mode: Optional[str] = None) -> List[str]:
    """The parity-window contract, executable: stream a synthetic
    prefix through the macro-round engine AND run the identical
    materialized jobset through the monolithic ``sim_jax`` engine;
    return the names of any per-job/result fields that differ (empty
    list == bit-exact parity). Raises if either run leaves the
    deterministic domain (``fallback_count != 0``) or the streamed run
    spilled. Used by the bench parity rows, the CI smoke and the
    stream test suite."""
    src = JobSource(workload.stream_chunks(cfg, n_jobs, chunk=chunk))
    res = StreamEngine(cfg, src, capacity=capacity,
                       time_mode=time_mode).run()
    _reject_spilled(res)
    js = materialize(JobSource(
        workload.stream_chunks(cfg, n_jobs, chunk=chunk)))
    return _diff_vs_monolithic(cfg, res, js, time_mode)


def verify_closed_loop_parity(cfg: SimConfig, n_jobs: int = 400,
                              capacity: int = 160, chunk: int = 64,
                              time_mode: Optional[str] = None
                              ) -> List[str]:
    """Closed-loop twin of :func:`verify_prefix_parity`: stream a
    synthetic prefix through the engine with ``admission=True`` AND
    run the monolithic pipeline (``closed_loop_submit_times`` to stamp
    admit ticks, then ``sim_jax.run_jit``) on the same job data;
    return the names of any differing fields. Checks the admit ticks
    themselves (``"admit_time"``) on top of the scheduler outcome, so
    an empty list proves the whole streamed closed-loop path —
    admission controller AND macro-round engine — is bit-exact."""
    src = JobSource(workload.stream_chunks(cfg, n_jobs, chunk=chunk))
    res = StreamEngine(cfg, src, capacity=capacity, time_mode=time_mode,
                       admission=True).run()
    _reject_spilled(res)
    data = materialize(JobSource(
        workload.stream_chunks(cfg, n_jobs, chunk=chunk)))
    data.submit = workload.closed_loop_submit_times(cfg, data)
    diff = ([] if np.array_equal(res.submit, data.submit)
            else ["admit_time"])
    return diff + _diff_vs_monolithic(cfg, res, data, time_mode)
