"""Mesh-distributed simulation sweeps — thin wrappers over the
device-parallel sweep fabric (``core/sweep_fabric.py``, DESIGN.md §11).

The sensitivity studies (Figs. 4-7) are hundreds of independent
simulations (policy × s × P × workload seed). Each one is a pure-JAX
program (core/sim_jax.py, victim selection registry-dispatched per
``cfg.policy`` — any registered dual-backend policy sweeps with zero
edits here), so a sweep is a trial table that the fabric
``shard_map``s over the local device mesh (``mesh_for_sweep``) —
sentinel-padded for uneven grids, bit-identical to the single-device
vmap, compiled once per config however many times the seeds change.

These wrappers keep the classic dict-of-arrays return shape; new code
wanting per-job pooling or explicit meshes should use
``sweep_fabric.run_table`` directly. Callers reach both through the
``repro.api`` facade (``api.sensitivity_grid`` / ``api.scenario_sweep``
/ ``api.run_sweep`` / ``api.run_table``, DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.cluster import SimConfig
from repro.core import sweep_fabric, workload
from repro.core.sweep_fabric import (_masked_frac, _masked_pct,  # noqa: F401
                                     pad_jobs, stack_jobsets)
from repro.core.sweep_fabric import _trial_percentiles as _trial_result  # noqa: F401,E501


def run_sweep(cfg: SimConfig, jobs, s_vals, P_vals, seeds,
              mesh: Optional[Mesh] = None,
              trial_axes: Sequence[str] = ("data",),
              time_mode: Optional[str] = None,
              devices: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Run T independent trials; trial t uses jobs[t], s_vals[t], ...

    A thin wrapper over ``sweep_fabric.run_table``: the trials shard
    over ``mesh``'s data axis when given, else over
    ``mesh_for_sweep(T, devices)`` (every local device by default —
    single-device runs behave exactly as before; under a forced or
    real multi-device runtime the same call scales out, sentinel-
    padded when T doesn't divide the device count). ``time_mode``
    (default ``cfg.time_mode``) selects tick-stepped vs
    event-compressed advancement; results are bit-identical across
    meshes and modes. The caller keeps ownership of ``jobs`` (no
    donation through this wrapper). ``trial_axes`` is honored via the
    mesh's data axis (``sharding.trial_axis``).
    """
    table = sweep_fabric.table_from_stacked(jobs, s_vals, P_vals, seeds)
    res = sweep_fabric.run_table(cfg, table, mesh=mesh, devices=devices,
                                 time_mode=time_mode, donate=False)
    return res.stats


def sensitivity_grid(cfg: SimConfig, n_jobs: int, s_vals: Sequence[float],
                     seeds: Sequence[int],
                     mesh: Optional[Mesh] = None,
                     time_mode: Optional[str] = None,
                     devices: Optional[int] = None
                     ) -> Dict[str, np.ndarray]:
    """Fig. 4-style grid: all (s, seed) pairs on shared per-seed
    workloads, flattened into ONE fabric table (the whole s-axis is
    traced, so the grid compiles once per config).

    Returns arrays of shape (len(s_vals), len(seeds), ...).
    """
    wl = dataclasses.replace(cfg.workload, n_jobs=n_jobs)
    base = dataclasses.replace(cfg, workload=wl)
    jobsets = [workload.generate(base, seed=sd) for sd in seeds]
    stacked = stack_jobsets(jobsets)

    ns, nt = len(s_vals), len(seeds)
    rep = jax.tree.map(lambda x: jnp.tile(x, (ns,) + (1,) * (x.ndim - 1)),
                       stacked)
    s_flat = np.repeat(np.asarray(s_vals, np.float32), nt)
    P_flat = np.full(ns * nt, base.max_preemptions, np.int32)
    seed_flat = np.tile(np.asarray(seeds, np.uint32), ns)
    out = run_sweep(base, rep, s_flat, P_flat, seed_flat, mesh=mesh,
                    time_mode=time_mode, devices=devices)
    return jax.tree.map(lambda x: x.reshape((ns, nt) + x.shape[1:]), out)


def scenario_sweep(cfg: SimConfig, names: Sequence[str],
                   seeds: Sequence[int],
                   mesh: Optional[Mesh] = None,
                   time_mode: Optional[str] = None,
                   devices: Optional[int] = None
                   ) -> Dict[str, np.ndarray]:
    """Ragged multi-scenario grid: all (scenario, seed) trials in ONE
    fabric batch, even when the scenarios produce different job counts
    (sentinel padding, ``stack_jobsets``) or gang (multi-node) jobs —
    widths ride through the padding (DESIGN.md §7). ``devices`` caps
    the trial mesh (the CLI's ``sweep --devices``).

    Returns arrays of shape (len(names), len(seeds), ...).
    """
    from repro import scenarios

    jobsets = [scenarios.build(name, dataclasses.replace(cfg, seed=sd))
               for name in names for sd in seeds]
    stacked = stack_jobsets(jobsets)

    nn, nt = len(names), len(seeds)
    s_flat = np.full(nn * nt, cfg.s, np.float32)
    P_flat = np.full(nn * nt, cfg.max_preemptions, np.int32)
    seed_flat = np.tile(np.asarray(seeds, np.uint32), nn)
    out = run_sweep(cfg, stacked, s_flat, P_flat, seed_flat, mesh=mesh,
                    time_mode=time_mode, devices=devices)
    return jax.tree.map(lambda x: x.reshape((nn, nt) + x.shape[1:]), out)


# ``sweep.run`` — the one entry point callers batch everything through.
run = run_sweep
