"""Mesh-distributed simulation sweeps.

The sensitivity studies (Figs. 4-7) are hundreds of independent
simulations (policy × s × P × workload seed). Each one is a pure-JAX
program (core/sim_jax.py, victim selection registry-dispatched per
``cfg.policy`` — any registered dual-backend policy sweeps with zero
edits here), so a sweep is a vmapped batch that ``shard_map``s over
the ``data`` axis of the production mesh — the scheduler study itself
runs as a multi-pod data-parallel workload.

Callers reach these through the ``repro.api`` facade
(``api.sensitivity_grid`` / ``api.scenario_sweep`` / ``api.run_sweep``,
DESIGN.md §6), alongside single-run ``api.run_experiment``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.cluster import SimConfig
from repro.core import sim_jax, workload
from repro.core.types import JobSet


def pad_jobs(jobs: sim_jax.Jobs, n_max: int) -> sim_jax.Jobs:
    """Pad a Jobs struct to ``n_max`` rows with sentinel jobs.

    Sentinels carry zero demand, unit execution, ``width=1`` and
    ``valid=False``; ``sim_jax.init_state`` births them DONE so they
    never arrive, queue, run or appear as preemption candidates, and
    every percentile in ``_trial_result`` masks them out (the
    sentinel-padding contract, DESIGN.md §5). Real rows keep their
    gang widths through the padding."""
    pad = n_max - jobs.submit.shape[0]
    if pad < 0:
        raise ValueError(f"cannot pad {jobs.submit.shape[0]} jobs "
                         f"down to {n_max}")
    if pad == 0:
        return jobs

    def ext(x, fill):
        tail = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
        return jnp.concatenate([x, tail])

    return sim_jax.Jobs(
        submit=ext(jobs.submit, 0), exec_total=ext(jobs.exec_total, 1),
        demand=ext(jobs.demand, 0.0), is_te=ext(jobs.is_te, False),
        gp=ext(jobs.gp, 0), width=ext(jobs.width, 1),
        valid=ext(jobs.valid, False))


def stack_jobsets(jobsets: Sequence[JobSet]) -> sim_jax.Jobs:
    """Stack workloads over a leading trial axis.

    Equal-``n`` jobsets stack directly (the original fast path). Ragged
    collections — heterogeneous scenarios, trace replays — are padded to
    the max ``n`` with masked sentinel jobs (``pad_jobs``), so one
    vmapped/shard_mapped sweep can span them all. Gang widths
    (``JobSet.n_nodes`` → ``Jobs.width``) ride through both paths;
    sentinel rows stay width-1."""
    js = [sim_jax.jobs_from_jobset(j) for j in jobsets]
    n_max = max(j.submit.shape[0] for j in js)
    if any(j.submit.shape[0] != n_max for j in js):
        js = [pad_jobs(j, n_max) for j in js]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *js)


def _masked_pct(vals, mask, ps):
    """Stacked percentiles of ``vals[mask]`` — explicit ``nan`` when
    the mask selects nothing (a trial with zero valid TE or BE jobs
    after sentinel padding): the trial then drops out of every
    nan-aware pooled table instead of contributing garbage."""
    v = jnp.where(mask, vals, jnp.nan)
    some = mask.any()
    return jnp.stack([jnp.where(some, jnp.nanpercentile(v, p), jnp.nan)
                      for p in ps])


def _masked_frac(mask, hit):
    """Fraction of ``mask`` rows with ``hit`` set; nan for an empty
    class (same NaN-safety contract as :func:`_masked_pct`)."""
    frac = jnp.nanmean(jnp.where(mask, hit.astype(jnp.float32), jnp.nan))
    return jnp.where(mask.any(), frac, jnp.nan)


def _trial_result(cfg: SimConfig, jobs: sim_jax.Jobs, s, P_, seed,
                  time_mode: Optional[str] = None):
    st = sim_jax.run(cfg, jobs, seed=seed, s=s, P=P_, time_mode=time_mode)
    sd = sim_jax.slowdown(jobs, st)
    te = jobs.is_te & jobs.valid

    iv = (st.last_resume - st.last_signal).astype(jnp.float32)
    iv_mask = (st.last_resume >= 0) & jobs.valid
    pc = st.preempt_count
    be = ~jobs.is_te & jobs.valid
    return {
        "te_slowdown": _masked_pct(sd, te, (50, 95, 99)),
        "be_slowdown": _masked_pct(sd, be, (50, 95, 99)),
        "intervals": _masked_pct(iv, iv_mask, (50, 75, 95, 99)),
        "preempted_frac": _masked_frac(be, pc > 0),
        "preempt_1": _masked_frac(be, pc == 1),
        "preempt_2": _masked_frac(be, pc == 2),
        "preempt_3plus": _masked_frac(be, pc >= 3),
        "makespan": st.t,
    }


def run_sweep(cfg: SimConfig, jobs: sim_jax.Jobs, s_vals, P_vals, seeds,
              mesh: Optional[Mesh] = None,
              trial_axes: Sequence[str] = ("data",),
              time_mode: Optional[str] = None) -> Dict[str, np.ndarray]:
    """Run T independent trials; trial t uses jobs[t], s_vals[t], ...

    With ``mesh``, trials are sharded over ``trial_axes`` via device_put
    of the batched inputs (pjit partitions the vmapped program); without,
    they run locally. T must be a multiple of the mesh axis size.
    ``time_mode`` (default ``cfg.time_mode``) selects tick-stepped vs
    event-compressed advancement; the event jump is computed inside the
    vmapped program, so each trial lane fast-forwards at its own pace
    (ragged padding and heterogeneous horizons included) with results
    bit-identical to tick mode.
    """
    s_vals = jnp.asarray(s_vals, jnp.float32)
    P_vals = jnp.asarray(P_vals, jnp.int32)
    seeds = jnp.asarray(seeds, jnp.uint32)

    def one(jobs_t, s, P_, seed):
        return _trial_result(cfg, jobs_t, s, P_, jax.random.key(seed),
                             time_mode=time_mode)

    batched = jax.vmap(one)
    if mesh is not None:
        spec = P(*trial_axes)
        shard = NamedSharding(mesh, spec)
        jobs = jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(
                mesh, P(*(trial_axes + (None,) * (x.ndim - 1))))), jobs)
        s_vals = jax.device_put(s_vals, shard)
        P_vals = jax.device_put(P_vals, shard)
        seeds = jax.device_put(seeds, shard)
        with mesh:
            out = jax.jit(batched)(jobs, s_vals, P_vals, seeds)
    else:
        out = jax.jit(batched)(jobs, s_vals, P_vals, seeds)
    return jax.tree.map(np.asarray, out)


def sensitivity_grid(cfg: SimConfig, n_jobs: int, s_vals: Sequence[float],
                     seeds: Sequence[int],
                     mesh: Optional[Mesh] = None,
                     time_mode: Optional[str] = None
                     ) -> Dict[str, np.ndarray]:
    """Fig. 4-style grid: all (s, seed) pairs on shared per-seed workloads.

    Returns arrays of shape (len(s_vals), len(seeds), ...).
    """
    wl = dataclasses.replace(cfg.workload, n_jobs=n_jobs)
    base = dataclasses.replace(cfg, workload=wl)
    jobsets = [workload.generate(base, seed=sd) for sd in seeds]
    stacked = stack_jobsets(jobsets)

    ns, nt = len(s_vals), len(seeds)
    rep = jax.tree.map(lambda x: jnp.tile(x, (ns,) + (1,) * (x.ndim - 1)),
                       stacked)
    s_flat = np.repeat(np.asarray(s_vals, np.float32), nt)
    P_flat = np.full(ns * nt, base.max_preemptions, np.int32)
    seed_flat = np.tile(np.asarray(seeds, np.uint32), ns)
    out = run_sweep(base, rep, s_flat, P_flat, seed_flat, mesh=mesh,
                    time_mode=time_mode)
    return jax.tree.map(lambda x: x.reshape((ns, nt) + x.shape[1:]), out)


def scenario_sweep(cfg: SimConfig, names: Sequence[str],
                   seeds: Sequence[int],
                   mesh: Optional[Mesh] = None,
                   time_mode: Optional[str] = None
                   ) -> Dict[str, np.ndarray]:
    """Ragged multi-scenario grid: all (scenario, seed) trials in ONE
    vmapped batch, even when the scenarios produce different job counts
    (sentinel padding, ``stack_jobsets``) or gang (multi-node) jobs —
    widths ride through the padding (DESIGN.md §7).

    Returns arrays of shape (len(names), len(seeds), ...).
    """
    from repro import scenarios

    jobsets = [scenarios.build(name, dataclasses.replace(cfg, seed=sd))
               for name in names for sd in seeds]
    stacked = stack_jobsets(jobsets)

    nn, nt = len(names), len(seeds)
    s_flat = np.full(nn * nt, cfg.s, np.float32)
    P_flat = np.full(nn * nt, cfg.max_preemptions, np.int32)
    seed_flat = np.tile(np.asarray(seeds, np.uint32), nn)
    out = run_sweep(cfg, stacked, s_flat, P_flat, seed_flat, mesh=mesh,
                    time_mode=time_mode)
    return jax.tree.map(lambda x: x.reshape((nn, nt) + x.shape[1:]), out)


# ``sweep.run`` — the one entry point callers batch everything through.
run = run_sweep
