"""The shared scheduling state machine: :class:`SchedulerCore`.

The paper's contribution (FitGpp, Eq. 1-4) is a *decision rule*; the
surrounding tick/queue/preemption machinery is policy-independent and
used to be duplicated across the reference simulator, the JAX engine
and the live-training controller. This class is the single owner of
that machinery (DESIGN.md §2):

  * queue lanes — TE priority FIFO + BE FIFO, lazy-deletion heaps,
    victims requeued on TOP (``engine/queues.py``);
  * placement — first-fit and gang (all-or-nothing) fitting with the
    shared ``FIT_EPS`` tolerance (``engine/placement.py``);
  * the grace-period preemption lifecycle — signal → grace countdown →
    vacate → requeue-on-top → resume — including the pending-free
    accounting that gates re-triggering;
  * the policy-invocation protocol — candidate marshalling, Eq. 2 best
    node per victim, under-P-cap-first ordering, gang selection
    (``engine/preemption.py``).

Drivers own TIME and WORK: what a tick means (a simulated minute vs. a
batch of real train steps), when a job is done, and how results are
recorded (via :class:`CoreHooks`). ``core/simulator.py`` and
``core/controller.py`` are both thin drivers over this class.

Event-driven support: :meth:`schedule_would_act` reports whether a
schedule pass right now could start or preempt anything. When it
cannot, and no arrival/finish/grace-expiry is due, every intervening
tick is a pure countdown — drivers may jump the clock and bulk-apply
the countdowns (:meth:`tick_clocks` with ``k > 1``) with bit-identical
semantics (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Set

import numpy as np

from repro.core.engine import preemption as pre
from repro.core.engine.placement import ClusterState
from repro.core.engine.queues import QueueLanes
from repro.core.types import (DONE, GRACE, NOT_ARRIVED, QUEUED, RUNNING,
                              STATE_NAMES)

Hook = Callable[..., None]


@dataclass
class CoreHooks:
    """Driver callbacks, invoked AFTER the core state transition.

    on_start(j, nodes, t)      — job placed (fresh start or resume)
    on_signal(j, te, t)        — preemption signalled (grace begins)
    on_vacate(j, t)            — grace over, resources freed, requeued
    on_finish(j, t)            — job completed
    on_backfill(j, skipped, t) — job placed past ``skipped`` blocked
                                 jobs (fires after on_start, backfill
                                 passes only)
    """
    on_start: Optional[Hook] = None
    on_signal: Optional[Hook] = None
    on_vacate: Optional[Hook] = None
    on_finish: Optional[Hook] = None
    on_backfill: Optional[Hook] = None


class SchedulerCore:
    """Policy-independent scheduling state over integer job ids.

    Static job attributes (``demand``/``is_te``/``width``) are arrays,
    passed up front (simulator) or appended via :meth:`add_job`
    (controller). ``gp_of``/``remaining_of`` are accessors taking a
    scalar id or an id array — the controller's grace periods are
    *live* quantities (sized from checkpoint state bytes), so they
    cannot be a static array.
    """

    def __init__(self, *, cluster: ClusterState, policy,
                 max_preemptions: int, rng: np.random.Generator,
                 gp_of: Callable, remaining_of: Callable,
                 demand: Optional[np.ndarray] = None,
                 is_te: Optional[np.ndarray] = None,
                 width: Optional[np.ndarray] = None,
                 backfill: bool = False, backfill_depth: int = 64,
                 hooks: Optional[CoreHooks] = None) -> None:
        self.cluster = cluster
        self.policy = policy
        self.max_preemptions = int(max_preemptions)
        self.rng = rng
        self.gp_of = gp_of
        self.remaining_of = remaining_of
        self.backfill = backfill
        self.backfill_depth = backfill_depth
        self.hooks = hooks or CoreHooks()

        self.demand = (np.zeros((0, cluster.node_cap.size))
                       if demand is None else np.asarray(demand, np.float64))
        n = self.demand.shape[0]
        self.is_te = (np.zeros(n, bool) if is_te is None
                      else np.asarray(is_te, bool))
        self.width = (np.ones(n, np.int64) if width is None
                      else np.asarray(width, np.int64))

        self.state = np.full(n, NOT_ARRIVED, np.int8)
        self.node = np.full(n, -1, np.int64)
        self.preempt_count = np.zeros(n, np.int64)
        self.grace_left = np.zeros(n, np.int64)
        self.victim_of = np.full(n, -1, np.int64)
        self.te_pending = np.zeros(n, np.int64)   # victims still in grace

        self.job_nodes: Dict[int, np.ndarray] = {}   # (gang) placements
        self.running: Set[int] = set()
        self.running_be: Set[int] = set()
        self.grace: Set[int] = set()
        self.n_done = 0
        self.lanes = QueueLanes(lambda j: self.state[j] == QUEUED)

    # -- dynamic workloads (controller) -------------------------------------

    def grow_to(self, n: int) -> None:
        """Grow every per-job array to at least ``n`` slots in one step
        (amortized O(1) per slot, vs :meth:`add_job`'s O(n) copy per
        call). Slot-pool drivers (``core/stream/admission.py``) recycle
        ids and grow by doubling; the new slots are inert — NOT_ARRIVED
        and never queued, invisible to scheduling until a driver
        initializes and enqueues them."""
        cur = self.state.size
        if n <= cur:
            return
        k = int(n) - cur

        def pad(arr, fill):
            ext = np.full((k,) + arr.shape[1:], fill, arr.dtype)
            return np.concatenate([arr, ext])

        self.demand = pad(self.demand, 0.0)
        self.is_te = pad(self.is_te, False)
        self.width = pad(self.width, 1)
        self.state = pad(self.state, NOT_ARRIVED)
        self.node = pad(self.node, -1)
        self.preempt_count = pad(self.preempt_count, 0)
        self.grace_left = pad(self.grace_left, 0)
        self.victim_of = pad(self.victim_of, -1)
        self.te_pending = pad(self.te_pending, 0)

    def add_job(self, demand, is_te: bool, width: int = 1) -> int:
        """Register one more job; returns its id."""
        j = self.demand.shape[0]
        self.demand = np.vstack([self.demand,
                                 np.asarray(demand, np.float64)[None, :]])
        self.is_te = np.append(self.is_te, bool(is_te))
        self.width = np.append(self.width, int(width))
        self.state = np.append(self.state, np.int8(NOT_ARRIVED))
        self.node = np.append(self.node, -1)
        self.preempt_count = np.append(self.preempt_count, 0)
        self.grace_left = np.append(self.grace_left, 0)
        self.victim_of = np.append(self.victim_of, -1)
        self.te_pending = np.append(self.te_pending, 0)
        return j

    # -- lifecycle -----------------------------------------------------------

    def _te_lane(self, j: int) -> bool:
        return self.policy.preemptive and bool(self.is_te[j])

    def enqueue(self, j: int) -> None:
        """Arrival: the job enters the tail of its lane."""
        self.state[j] = QUEUED
        self.lanes.push_back(int(j), self._te_lane(j))

    def fits_job(self, j: int) -> Optional[np.ndarray]:
        return self.cluster.fits_job(self.demand[j], int(self.width[j]))

    def start(self, j: int, nodes, t: int) -> None:
        nodes = np.atleast_1d(np.asarray(nodes))
        self.state[j] = RUNNING
        self.node[j] = int(nodes[0])
        self.job_nodes[j] = nodes
        self.cluster.alloc(nodes, self.demand[j])
        self.running.add(j)
        if not self.is_te[j]:
            self.running_be.add(j)
        if self.hooks.on_start:
            self.hooks.on_start(j, nodes, t)

    def signal_preemption(self, j: int, te: int, t: int) -> None:
        """Move a running BE job into its grace period (GP=0 vacates
        the same tick, matching the paper's immediate-kill limit)."""
        assert self.state[j] == RUNNING and not self.is_te[j], (
            f"victim {j} must be a running BE job, is "
            f"{STATE_NAMES[int(self.state[j])]}"
            f"{' (TE)' if self.is_te[j] else ''}")
        gp = int(self.gp_of(j))
        self.state[j] = GRACE
        self.grace_left[j] = gp
        self.preempt_count[j] += 1
        self.victim_of[j] = te
        self.te_pending[te] += 1
        self.running.discard(j)
        self.running_be.discard(j)
        self.cluster.promise(self.job_nodes[j], self.demand[j])
        if self.hooks.on_signal:
            self.hooks.on_signal(j, te, t)
        if gp <= 0:
            self.vacate(j, t)
        else:
            self.grace.add(j)

    def vacate(self, j: int, t: int) -> None:
        """Grace over: free the resources, requeue ON TOP of the lane."""
        nodes = self.job_nodes.pop(j)
        self.cluster.release(nodes, self.demand[j])
        self.cluster.unpromise(nodes, self.demand[j])
        self.node[j] = -1
        self.state[j] = QUEUED
        self.grace.discard(j)
        self.lanes.requeue_top(j, self._te_lane(j))
        te = int(self.victim_of[j])
        if te >= 0:
            self.te_pending[te] -= 1
            self.victim_of[j] = -1
        if self.hooks.on_vacate:
            self.hooks.on_vacate(j, t)

    def finish(self, j: int, t: int) -> None:
        nodes = self.job_nodes.pop(j)
        self.cluster.release(nodes, self.demand[j])
        self.node[j] = -1
        self.state[j] = DONE
        self.running.discard(j)
        self.running_be.discard(j)
        self.n_done += 1
        if self.hooks.on_finish:
            self.hooks.on_finish(j, t)

    def expire_grace(self, t: int) -> None:
        """Vacate every grace-expired job (job-index order: JAX-engine
        parity)."""
        for j in sorted(j for j in self.grace if self.grace_left[j] <= 0):
            self.vacate(j, t)

    def tick_clocks(self, k: int = 1) -> None:
        """Count ``k`` minutes of grace down (end-of-tick; ``k > 1``
        only when the driver fast-forwards over no-op ticks)."""
        if self.grace:
            g = np.fromiter(self.grace, np.int64, count=len(self.grace))
            self.grace_left[g] -= k

    # -- victim selection ----------------------------------------------------

    def _be_candidates(self) -> np.ndarray:
        return np.sort(np.fromiter(self.running_be, np.int64,
                                   count=len(self.running_be)))

    def try_preempt_for(self, te: int, t: int) -> None:
        """Invoke the policy and signal its victims for TE job ``te``."""
        cand = self._be_candidates()
        if len(cand) == 0:
            return
        te_d = self.demand[te]
        cand_gp = np.asarray(self.gp_of(cand), np.float64)
        cand_rem = np.asarray(self.remaining_of(cand), np.float64)
        under = self.preempt_count[cand] < self.max_preemptions
        if int(self.width[te]) > 1:
            victims = pre.gang_select(
                policy=self.policy, rng=self.rng, te_demand=te_d,
                width=int(self.width[te]), free=self.cluster.free,
                cand_ids=cand,
                cand_nodes=[self.job_nodes[int(j)] for j in cand],
                cand_demand=self.demand[cand], cand_width=self.width[cand],
                cand_gp=cand_gp, cand_remaining=cand_rem, under_cap=under,
                node_cap=self.cluster.node_cap)
        else:
            cand_node = np.asarray([
                pre.best_victim_node(self.job_nodes[int(j)],
                                     self.cluster.free,
                                     self.demand[int(j)], te_d)
                for j in cand])
            victims = self.policy.select(
                rng=self.rng,
                te_demand=te_d,
                cand_ids=cand,
                cand_demand=self.demand[cand],
                cand_node_free=self.cluster.free[cand_node],
                cand_gp=cand_gp,
                cand_remaining=cand_rem,
                under_cap=under,
                all_run_demand=self.demand[cand],
                all_run_gp=cand_gp,
                node_cap=self.cluster.node_cap,
                free_by_node=self.cluster.free,
                cand_node=cand_node,
            )
        for v in victims:
            self.signal_preemption(int(v), te, t)

    def _should_trigger(self, j: int) -> bool:
        """Preempt only if the TE would not fit even counting resources
        already promised by in-flight grace periods ("the resource is
        insufficient", §2) — an imminent vacate is incoming supply, not
        a shortage — and no victim this TE already signalled is still
        in grace (defensive; rare)."""
        return (self.te_pending[j] == 0 and
                not self.cluster.fits_with_pending(self.demand[j],
                                                   int(self.width[j])))

    # -- the schedule pass ---------------------------------------------------

    def schedule(self, t: int) -> None:
        # 1) TE priority lane (preemptive policies only)
        if self.policy.preemptive:
            blocked: List[int] = []
            while True:
                j = self.lanes.pop(True)
                if j < 0:
                    break
                nodes = self.fits_job(j)
                if nodes is not None:
                    self.start(j, nodes, t)
                    continue
                if self._should_trigger(j):
                    self.try_preempt_for(j, t)
                    # GP=0 victims vacate inline: place the TE NOW,
                    # before the BE pass can reclaim the freed node.
                    nodes = self.fits_job(j)
                    if nodes is not None:
                        self.start(j, nodes, t)
                        continue
                blocked.append(j)
            for j in blocked:                # keep FIFO order among TE
                self.lanes.reinsert(j, True)
        # 2) BE queue (all jobs under vanilla FIFO): strict head-of-line,
        # or bounded first-fit backfill (beyond-paper, cfg.backfill)
        if not self.backfill:
            while True:
                head = self.lanes.peek(False)
                if head < 0:
                    break
                nodes = self.fits_job(head)
                if nodes is None:
                    break                     # head-of-line blocking
                self.lanes.pop(False)
                self.start(head, nodes, t)
        else:
            skipped: List[int] = []
            scanned = 0
            while scanned < self.backfill_depth:
                head = self.lanes.pop(False)
                if head < 0:
                    break
                nodes = self.fits_job(head)
                if nodes is not None:
                    self.start(head, nodes, t)
                    if scanned and self.hooks.on_backfill:
                        self.hooks.on_backfill(head, scanned, t)
                else:
                    skipped.append(head)
                    scanned += 1
            for j in skipped:                 # keep original keys
                self.lanes.reinsert(j, False)

    # -- event-driven support ------------------------------------------------

    def schedule_would_act(self) -> bool:
        """Could a schedule pass RIGHT NOW start or preempt anything?

        False means the next tick's schedule is a provable no-op (free
        and the queues cannot change before the next arrival / finish /
        grace-expiry event), so a driver may fast-forward the clock.
        Conservative by construction: any tick on which the policy
        would be (re-)invoked — even fruitlessly — reports True, so
        RNG-consuming policies (rand, fitgpp's random fallback) stay
        bit-exact under fast-forward (DESIGN.md §4).
        """
        if self.policy.preemptive:
            for j in self.lanes.valid_jobs(True):
                if self.fits_job(j) is not None:
                    return True
                if self.running_be and self._should_trigger(j):
                    return True
        if not self.backfill:
            head = self.lanes.peek(False)
            if head >= 0 and self.fits_job(head) is not None:
                return True
        else:
            popped: List[int] = []
            act = False
            while len(popped) < self.backfill_depth:
                head = self.lanes.pop(False)
                if head < 0:
                    break
                popped.append(head)
                if self.fits_job(head) is not None:
                    act = True
                    break
            for j in popped:
                self.lanes.reinsert(j, False)
            if act:
                return True
        return False

    def min_grace_left(self) -> Optional[int]:
        """Minutes until the next grace expiry, or None."""
        if not self.grace:
            return None
        g = np.fromiter(self.grace, np.int64, count=len(self.grace))
        return int(self.grace_left[g].min())
