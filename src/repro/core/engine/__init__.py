"""Shared scheduling core (DESIGN.md §2).

One state machine — queue lanes, first-fit/gang placement, the
grace-period preemption lifecycle, and the policy-invocation protocol
— driven by both the reference simulator (``core/simulator.py``) and
the live-training controller (``core/controller.py``), and mirrored
array-wise by the JAX engine (``core/sim_jax.py``).
"""
from repro.core.engine.core import CoreHooks, SchedulerCore
from repro.core.engine.placement import FIT_EPS, ClusterState
from repro.core.engine.preemption import (best_victim_node, gang_select,
                                          ranked_order)
from repro.core.engine.queues import QueueLanes

__all__ = [
    "FIT_EPS", "ClusterState", "QueueLanes", "SchedulerCore", "CoreHooks",
    "best_victim_node", "gang_select", "ranked_order",
]
