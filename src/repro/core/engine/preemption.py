"""Victim-selection marshalling shared by every driver.

The *decision rule* lives in ``core/policies.py`` (Eq. 1-4 and the
baselines); this module owns the glue the paper leaves implicit:
which node a multi-node victim is evaluated against (Eq. 2), the
under-P-cap-first ordering, and the gang (multi-node TE) selection
strategy. Pure functions over arrays — no scheduler state is mutated
here; the :class:`~repro.core.engine.core.SchedulerCore` signals the
returned victims.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.engine.placement import FIT_EPS


def best_victim_node(nodes: np.ndarray, free: np.ndarray,
                     victim_demand: np.ndarray,
                     te_demand: np.ndarray) -> int:
    """Node of a victim with the most slack for ``te_demand`` (Eq. 2 is
    evaluated against the victim's best node; single-node jobs keep
    their only node, preserving the paper's exact semantics)."""
    if len(nodes) == 1:
        return int(nodes[0])
    slack = np.min(free[nodes] + victim_demand[None, :]
                   - te_demand[None, :], axis=1)
    return int(nodes[int(np.argmax(slack))])


def ranked_order(policy, rng, cand_demand, cand_gp, cand_remaining,
                 under_cap, node_cap) -> np.ndarray:
    """Candidate positions in the policy's preemption order:
    under-P-cap candidates first, then by the policy's rank key."""
    key = policy.rank_key(rng=rng, cand_demand=cand_demand,
                          cand_gp=cand_gp, cand_remaining=cand_remaining,
                          node_cap=node_cap)
    return np.lexsort((key, ~under_cap))


def gang_select(*, policy, rng, te_demand: np.ndarray, width: int,
                free: np.ndarray, cand_ids: np.ndarray,
                cand_nodes: Sequence[np.ndarray], cand_demand: np.ndarray,
                cand_width: np.ndarray, cand_gp: np.ndarray,
                cand_remaining: np.ndarray,
                under_cap: np.ndarray, node_cap: np.ndarray) -> List[int]:
    """Multi-node TE (paper future work): Eq. 2/4 generalized — prefer
    the min-score SINGLE victim whose eviction alone yields >= width
    satisfying nodes (the paper's minimize-preemption-count strategy);
    otherwise accumulate victims in policy order until the gang fits.
    Returns victim job ids to signal ([] when nothing would suffice —
    signalling then would burn preemption budget for no gain)."""
    if len(cand_ids) == 0:
        return []

    def n_fit(fr: np.ndarray) -> int:
        return int(np.all(fr >= te_demand[None, :] - FIT_EPS, axis=1).sum())

    order = ranked_order(policy, rng,
                         cand_demand * cand_width[:, None],
                         cand_gp, cand_remaining, under_cap, node_cap)
    if policy.argmin_select:                 # Eq. 4-style score policies
        pool = [i for i in order if under_cap[i]] or list(order)
        for i in pool:                       # Eq. 4: min score first
            trial = free.copy()
            trial[cand_nodes[i]] += cand_demand[i]
            if n_fit(trial) >= width:
                return [int(cand_ids[i])]
    pending = free.copy()
    victims: List[int] = []
    for i in order:
        if n_fit(pending) >= width:
            break
        pending[cand_nodes[i]] += cand_demand[i]
        victims.append(int(cand_ids[i]))
    return victims if n_fit(pending) >= width else []
