"""Node-level placement: first-fit and gang (all-or-nothing) fitting.

``FIT_EPS`` is THE epsilon for every resource-fit comparison in the
repo — the reference engine, the JAX engine (``core/sim_jax.py``) and
the policies (``core/policies.py``) all import it from here. Demands
are floats and repeated alloc/release accumulates dust, so every
"does it fit" test is slack-tolerant: an exact-fit job still fits its
node after round-trips through the free vector.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

FIT_EPS = 1e-9


class ClusterState:
    """Per-node free / pending-free resource vectors plus fit queries.

    ``free`` is what is allocatable right now; ``pending_free`` is
    demand already promised back by in-flight grace periods (signalled
    victims that have not vacated yet) — incoming supply, not current
    supply. The distinction drives the preemption trigger: a TE only
    preempts when even ``free + pending_free`` cannot fit it (§2 of the
    paper: "the resource is insufficient").
    """

    def __init__(self, n_nodes: int, node_cap) -> None:
        self.node_cap = np.asarray(node_cap, np.float64)
        self.n_nodes = int(n_nodes)
        self.free = np.tile(self.node_cap, (self.n_nodes, 1))
        self.pending_free = np.zeros((self.n_nodes, self.node_cap.size))

    # -- queries -------------------------------------------------------------

    def fitting_nodes(self, demand: np.ndarray) -> np.ndarray:
        """Indices of nodes whose free vector fits ``demand``."""
        fits = np.all(self.free >= demand[None, :] - FIT_EPS, axis=1)
        return np.flatnonzero(fits)

    def first_fit(self, demand: np.ndarray) -> int:
        """First node fitting ``demand``, or -1."""
        idx = self.fitting_nodes(demand)
        return int(idx[0]) if len(idx) else -1

    def fits_job(self, demand: np.ndarray, width: int = 1
                 ) -> Optional[np.ndarray]:
        """First ``width`` nodes that each fit the PER-NODE ``demand``
        (gang: all-or-nothing), or None. ``width`` == 1 is first-fit."""
        idx = self.fitting_nodes(demand)
        return idx[:width] if len(idx) >= width else None

    def fits_with_pending(self, demand: np.ndarray, width: int = 1) -> bool:
        """Would the job fit counting resources already promised by
        in-flight grace periods? (Preemption-trigger test.)"""
        promised = self.free + self.pending_free
        fits = np.all(promised >= demand[None, :] - FIT_EPS, axis=1)
        return int(fits.sum()) >= width

    # -- mutations -----------------------------------------------------------

    def alloc(self, nodes: np.ndarray, demand: np.ndarray) -> None:
        self.free[nodes] -= demand

    def release(self, nodes: np.ndarray, demand: np.ndarray) -> None:
        self.free[nodes] += demand

    def promise(self, nodes: np.ndarray, demand: np.ndarray) -> None:
        """Record a signalled victim's demand as incoming supply."""
        self.pending_free[nodes] += demand

    def unpromise(self, nodes: np.ndarray, demand: np.ndarray) -> None:
        """The victim vacated: its supply is real now (in ``free``)."""
        self.pending_free[nodes] -= demand
