"""Queue lanes: TE-priority FIFO + BE FIFO as lazy-deletion heaps.

Entries are ``(key, job)`` tuples; lower key = closer to the head.
Arrival pushes take keys from a monotonically increasing tail counter
(FIFO); preemption victims re-enter at the TOP via a monotonically
decreasing ``top_key`` (the paper's requeue-on-top rule). A job's
current key lives in ``self.key``; heap entries whose key disagrees
(or whose job is no longer queued) are stale and skipped on pop —
lazy deletion keeps every operation O(log queue).
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Tuple


class QueueLanes:
    def __init__(self, is_queued: Callable[[int], bool]) -> None:
        self._is_queued = is_queued
        self.te_heap: List[Tuple[float, int]] = []
        self.be_heap: List[Tuple[float, int]] = []
        self.key: Dict[int, float] = {}    # job -> its live queue key
        self.top_key = -1.0                # next "top of queue" key
        self._tail_key = 0.0               # next arrival (FIFO) key

    def _heap(self, te: bool) -> List[Tuple[float, int]]:
        return self.te_heap if te else self.be_heap

    def _valid(self, key: float, j: int) -> bool:
        return self._is_queued(j) and self.key.get(j) == key

    # -- pushes --------------------------------------------------------------

    def push(self, j: int, key: float, te: bool) -> None:
        self.key[j] = key
        heapq.heappush(self._heap(te), (key, j))

    def push_back(self, j: int, te: bool) -> float:
        """Append at the tail (arrival order)."""
        key = self._tail_key
        self._tail_key += 1.0
        self.push(j, key, te)
        return key

    def requeue_top(self, j: int, te: bool) -> float:
        """Preemption-victim rule: re-enter at the TOP of the lane."""
        key = self.top_key
        self.top_key -= 1.0
        self.push(j, key, te)
        return key

    def reinsert(self, j: int, te: bool) -> None:
        """Re-push a popped-but-blocked job with its existing key."""
        heapq.heappush(self._heap(te), (self.key[j], j))

    # -- pops ----------------------------------------------------------------

    def peek(self, te: bool) -> int:
        """Valid head without removing it (stale entries are dropped),
        or -1 when the lane is empty."""
        heap = self._heap(te)
        while heap:
            key, j = heap[0]
            if self._valid(key, j):
                return j
            heapq.heappop(heap)
        return -1

    def pop(self, te: bool) -> int:
        """Remove and return the valid head, or -1."""
        j = self.peek(te)
        if j >= 0:
            heapq.heappop(self._heap(te))
        return j

    def valid_jobs(self, te: bool) -> List[int]:
        """All currently queued jobs in the lane (unordered)."""
        return [j for key, j in self._heap(te) if self._valid(key, j)]
