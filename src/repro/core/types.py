"""Core scheduler types: jobs, cluster state, events, results."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

# Job states
NOT_ARRIVED = 0
QUEUED = 1
RUNNING = 2
GRACE = 3      # preemption signalled; performing suspension processing
DONE = 4

TE = 1
BE = 0


@dataclass
class JobSet:
    """Static workload description (struct-of-arrays over n jobs).

    demand[:, r] for r in (CPU, RAM, GPU); times in integer minutes.
    ``n_nodes`` is the gang width for multi-node (distributed-DL) jobs —
    the paper's stated future work; ``demand`` is PER NODE and the job
    needs all its nodes simultaneously (gang scheduling).
    """
    submit: np.ndarray          # (n,) int
    exec_total: np.ndarray      # (n,) int >= 1
    demand: np.ndarray          # (n, 3) float
    is_te: np.ndarray           # (n,) bool
    gp: np.ndarray              # (n,) int grace period, minutes
    n_nodes: np.ndarray = None  # (n,) int >= 1; None -> all single-node

    def __post_init__(self):
        if self.n_nodes is None:
            self.n_nodes = np.ones(len(self.submit), np.int64)

    @property
    def n(self) -> int:
        return len(self.submit)

    def validate(self, node_cap: np.ndarray) -> None:
        assert (self.exec_total >= 1).all()
        assert (self.demand >= 0).all()
        assert (self.demand <= node_cap[None, :]).all(), \
            "job demand must fit on a single node"
        assert (self.gp >= 0).all()
        assert (np.diff(self.submit) >= 0).all(), "jobs sorted by submit time"


# Human-readable state names (engine assertion messages).
STATE_NAMES = {NOT_ARRIVED: "not_arrived", QUEUED: "queued",
               RUNNING: "running", GRACE: "grace", DONE: "done"}


@dataclass
class PreemptionEvent:
    job: int
    te_job: int                 # the TE arrival that triggered it
    signal_time: int            # grace period start
    vacate_time: int = -1
    resume_time: int = -1

    def as_tuple(self):
        """Canonical comparison key (engine-parity tests)."""
        return (self.job, self.te_job, self.signal_time,
                self.vacate_time, self.resume_time)


@dataclass
class SimResult:
    """Everything needed for the paper's tables/figures.

    ``trace`` is the canonical scheduler-event stream
    (``obs.schema.Event`` rows) when the run was traced
    (``Simulator(trace=True)`` / ``simulate(trace=True)``), else None.
    """
    finish: np.ndarray            # (n,) completion tick
    exec_total: np.ndarray
    submit: np.ndarray
    is_te: np.ndarray
    preempt_count: np.ndarray     # (n,)
    events: List[PreemptionEvent] = field(default_factory=list)
    makespan: int = 0
    trace: Optional[List] = None  # List[obs.schema.Event]

    @property
    def slowdown(self) -> np.ndarray:
        """Eq. 5: 1 + Waiting/Execution, Waiting = turnaround - execution."""
        waiting = self.finish - self.submit - self.exec_total
        return 1.0 + waiting / self.exec_total

    @property
    def resched_intervals(self) -> np.ndarray:
        """Minutes between the preemption signal and resuming (Table 2).

        Includes the grace period — that is the point: FitGpp picks
        short-GP victims, so its intervals are structurally shorter.
        """
        iv = [e.resume_time - e.signal_time for e in self.events
              if e.resume_time >= 0]
        return np.asarray(iv, dtype=np.float64)

    def preempted_fraction(self) -> float:
        """Proportion of BE jobs preempted at least once (Table 3);
        explicit ``nan`` (not a numpy empty-slice warning) for an
        all-TE jobset."""
        be = ~self.is_te
        if not be.any():
            return float("nan")
        return float((self.preempt_count[be] > 0).mean())

    def preempt_count_fractions(self) -> Dict[str, float]:
        """Proportion preempted exactly 1 / 2 / >=3 times (Table 4)."""
        be = ~self.is_te
        c = self.preempt_count[be]
        n = max(len(c), 1)
        return {"1": float((c == 1).sum()) / n,
                "2": float((c == 2).sum()) / n,
                ">=3": float((c >= 3).sum()) / n}
