"""Online preemption controller: FitGpp driving REAL JAX training jobs.

The simulator (core/simulator.py) reproduces the paper's numbers; this
module proves the *mechanism* on live jobs. A small in-process cluster
runs actual train steps for every RUNNING job each tick; preempting a
victim triggers its grace period, during which the job's train state
(params + optimizer + data cursor) is checkpointed via ``repro.checkpoint``
— the grace period is sized from the state bytes, closing the loop with
the paper's observation that big DL jobs need long GPs. Resumed jobs
continue bit-exactly (property-tested: the loss trajectory matches an
uninterrupted run).

Scheduling semantics are not mirrored by hand any more — they are the
simulator's semantics, literally: both drive the same
:class:`~repro.core.engine.SchedulerCore` (DESIGN.md §2), which owns
the strict-FIFO BE queue with head-of-line blocking, the TE priority
lane, requeue-on-top for victims, the per-job preemption cap P,
pending-grace-aware triggering, and gang (multi-node) placement. This
driver owns only the real-training concerns: initializing/step-ping
train states, checkpoint flush on vacate, restore on resume, and
sizing grace periods from live state bytes.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import numpy as np

from repro import trainer
from repro.checkpoint import (estimate_grace_period, load_pytree,
                              save_pytree)
from repro.configs.base import PAPER_P, PAPER_S, ModelConfig
from repro.core import policy_registry
from repro.core.engine import ClusterState, CoreHooks, SchedulerCore
from repro.core.types import DONE, GRACE, QUEUED, RUNNING
from repro.core.types import NOT_ARRIVED as PENDING
from repro.data import make_batch
from repro.optim import AdamWConfig


@dataclass
class JobSpec:
    name: str
    cfg: ModelConfig                  # smoke-scale model config
    is_te: bool
    demand: np.ndarray                # (cpu, ram, gpu) PER NODE
    total_steps: int
    batch: int = 4
    seq_len: int = 32
    submit_tick: int = 0
    n_nodes: int = 1                  # gang width (all-or-nothing)
    opt: AdamWConfig = field(default_factory=lambda: AdamWConfig(
        lr=1e-3, warmup_steps=2, total_steps=1000))
    gp_ticks: Optional[int] = None    # None -> estimated from state size


@dataclass
class Job:
    spec: JobSpec
    status: int = PENDING
    steps_done: int = 0
    node: int = -1
    preempt_count: int = 0
    grace_left: int = 0
    state: Optional[dict] = None      # live train state (when scheduled)
    ckpt_path: Optional[str] = None
    losses: List[float] = field(default_factory=list)
    submit_time: int = -1
    finish_time: int = -1
    run_ticks: int = 0
    _step_fn: Optional[Callable] = None

    @property
    def gp(self) -> int:
        if self.spec.gp_ticks is not None:
            return self.spec.gp_ticks
        if self.state is None:
            return 1
        return estimate_grace_period(self.state,
                                     storage_bw_bytes_per_s=2e9)


class Controller:
    def __init__(self, *, n_nodes: int = 2,
                 node_cap=(32.0, 256.0, 8.0),
                 policy: str = "fitgpp", s: float = PAPER_S,
                 max_preemptions: int = PAPER_P,
                 steps_per_tick: int = 2,
                 workdir: str = "/tmp/repro_ctl",
                 seed: int = 0):
        self.node_cap = np.asarray(node_cap, float)
        self.policy = policy_registry.make(policy, s=s)
        self.P = max_preemptions
        self.steps_per_tick = steps_per_tick
        self.workdir = workdir
        self.rng = np.random.default_rng(seed)
        self.jobs: List[Job] = []
        self.t = 0
        self.events: List[dict] = []
        self.core = SchedulerCore(
            cluster=ClusterState(n_nodes, self.node_cap),
            policy=self.policy,
            max_preemptions=max_preemptions,
            rng=self.rng,
            gp_of=self._gp_of,
            remaining_of=self._remaining_of,
            hooks=CoreHooks(on_start=self._on_start,
                            on_signal=self._on_signal,
                            on_vacate=self._on_vacate,
                            on_finish=self._on_finish),
        )
        os.makedirs(workdir, exist_ok=True)

    # -- core accessors: live quantities the core cannot own -----------------

    def _gp_of(self, ids):
        if np.ndim(ids) == 0:
            return self.jobs[int(ids)].gp
        return np.asarray([self.jobs[int(i)].gp for i in np.asarray(ids)],
                          float)

    def _remaining_of(self, ids):
        return np.asarray(
            [self.jobs[int(i)].spec.total_steps - self.jobs[int(i)].steps_done
             for i in np.atleast_1d(np.asarray(ids))], float)

    # -- job lifecycle -------------------------------------------------------

    def submit(self, spec: JobSpec) -> Job:
        job = Job(spec=spec)
        self.jobs.append(job)
        self.core.add_job(spec.demand, spec.is_te, spec.n_nodes)
        return job

    def _init_state(self, job: Job) -> None:
        if job.ckpt_path is not None:
            template = trainer.init_train_state(
                job.spec.cfg, job.spec.opt, jax.random.key(0))
            job.state = load_pytree(template, job.ckpt_path)
        elif job.state is None:
            job.state = trainer.init_train_state(
                job.spec.cfg, job.spec.opt,
                jax.random.key(hash(job.spec.name) % (1 << 31)))
        if job._step_fn is None:
            job._step_fn = jax.jit(trainer.make_train_step(
                job.spec.cfg, job.spec.opt))

    # -- core hooks: the real-training side of each transition ---------------

    def _on_start(self, j: int, nodes: np.ndarray, t: int) -> None:
        job = self.jobs[j]
        job.status = RUNNING
        job.node = int(nodes[0])
        self._init_state(job)
        self.events.append({"t": t, "ev": "start", "job": job.spec.name})

    def _on_signal(self, j: int, te: int, t: int) -> None:
        job = self.jobs[j]
        job.status = GRACE
        job.preempt_count = int(self.core.preempt_count[j])
        job.grace_left = int(self.core.grace_left[j])
        self.events.append({"t": t, "ev": "preempt",
                            "job": job.spec.name,
                            "for": self.jobs[te].spec.name,
                            "gp": job.grace_left})

    def _on_vacate(self, j: int, t: int) -> None:
        # grace period over: the checkpoint is flushed and memory freed
        job = self.jobs[j]
        job.ckpt_path = os.path.join(
            self.workdir, f"{job.spec.name}.{job.preempt_count}.npz")
        save_pytree(job.state, job.ckpt_path)
        job.state = None
        job.node = -1
        job.status = QUEUED
        self.events.append({"t": t, "ev": "vacate",
                            "job": job.spec.name,
                            "ckpt": job.ckpt_path})

    def _on_finish(self, j: int, t: int) -> None:
        job = self.jobs[j]
        job.node = -1
        job.status = DONE
        job.finish_time = t
        self.events.append({"t": t, "ev": "done", "job": job.spec.name})

    # -- one tick ------------------------------------------------------------

    def tick(self) -> None:
        t = self.t
        core = self.core
        # arrivals
        for j, job in enumerate(self.jobs):
            if job.status == PENDING and job.spec.submit_tick <= t:
                core.enqueue(j)
                job.status = QUEUED
                job.submit_time = t
        # grace expiry, then the shared schedule pass (TE lane + BE FIFO)
        core.expire_grace(t)
        core.schedule(t)
        # run real train steps for every RUNNING job
        for j, job in enumerate(self.jobs):
            if job.status != RUNNING:
                continue
            for _ in range(self.steps_per_tick):
                if job.steps_done >= job.spec.total_steps:
                    break
                batch = make_batch(job.spec.cfg, job.spec.batch,
                                   job.spec.seq_len, seed=1,
                                   step=job.steps_done)
                job.state, m = job._step_fn(job.state, batch)
                job.losses.append(float(m["loss"]))
                job.steps_done += 1
            job.run_ticks += 1
            if job.steps_done >= job.spec.total_steps:
                core.finish(j, t)
        core.tick_clocks()
        for j in core.grace:
            self.jobs[j].grace_left = int(core.grace_left[j])
        self.t += 1

    def run(self, max_ticks: int = 10_000) -> None:
        while any(j.status != DONE for j in self.jobs):
            self.tick()
            if self.t > max_ticks:
                raise RuntimeError("controller did not converge")

    # -- metrics --------------------------------------------------------------

    def slowdown(self, job: Job) -> float:
        turnaround = job.finish_time - job.spec.submit_tick
        exec_ticks = max(job.run_ticks, 1)
        return 1.0 + max(turnaround - exec_ticks, 0) / exec_ticks
