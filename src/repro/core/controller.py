"""Online preemption controller: FitGpp driving REAL JAX training jobs.

The simulator (core/simulator.py) reproduces the paper's numbers; this
module proves the *mechanism* on live jobs. A small in-process cluster
runs actual train steps for every RUNNING job each tick; preempting a
victim triggers its grace period, during which the job's train state
(params + optimizer + data cursor) is checkpointed via ``repro.checkpoint``
— the grace period is sized from the state bytes, closing the loop with
the paper's observation that big DL jobs need long GPs. Resumed jobs
continue bit-exactly (property-tested: the loss trajectory matches an
uninterrupted run).

Scheduling semantics mirror the simulator: strict-FIFO BE queue with
head-of-line blocking, TE priority lane, victims re-queued on top,
per-job preemption cap P, pending-grace-aware triggering.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro import trainer
from repro.checkpoint import (estimate_grace_period, load_pytree,
                              save_pytree, state_bytes)
from repro.configs.base import ModelConfig
from repro.core import policies as pol
from repro.data import make_batch
from repro.optim import AdamWConfig

PENDING, QUEUED, RUNNING, GRACE, DONE = range(5)


@dataclass
class JobSpec:
    name: str
    cfg: ModelConfig                  # smoke-scale model config
    is_te: bool
    demand: np.ndarray                # (cpu, ram, gpu)
    total_steps: int
    batch: int = 4
    seq_len: int = 32
    submit_tick: int = 0
    opt: AdamWConfig = field(default_factory=lambda: AdamWConfig(
        lr=1e-3, warmup_steps=2, total_steps=1000))
    gp_ticks: Optional[int] = None    # None -> estimated from state size


@dataclass
class Job:
    spec: JobSpec
    status: int = PENDING
    steps_done: int = 0
    node: int = -1
    preempt_count: int = 0
    grace_left: int = 0
    queue_key: float = 0.0
    state: Optional[dict] = None      # live train state (when scheduled)
    ckpt_path: Optional[str] = None
    losses: List[float] = field(default_factory=list)
    submit_time: int = -1
    finish_time: int = -1
    run_ticks: int = 0
    _step_fn: Optional[Callable] = None

    @property
    def gp(self) -> int:
        if self.spec.gp_ticks is not None:
            return self.spec.gp_ticks
        if self.state is None:
            return 1
        return estimate_grace_period(self.state,
                                     storage_bw_bytes_per_s=2e9)


class Controller:
    def __init__(self, *, n_nodes: int = 2,
                 node_cap=(32.0, 256.0, 8.0),
                 policy: str = "fitgpp", s: float = 4.0,
                 max_preemptions: int = 1,
                 steps_per_tick: int = 2,
                 workdir: str = "/tmp/repro_ctl",
                 seed: int = 0):
        self.node_cap = np.asarray(node_cap, float)
        self.free = np.tile(self.node_cap, (n_nodes, 1))
        self.pending_free = np.zeros_like(self.free)
        self.policy = pol.make_policy(policy, s)
        self.P = max_preemptions
        self.steps_per_tick = steps_per_tick
        self.workdir = workdir
        self.rng = np.random.default_rng(seed)
        self.jobs: List[Job] = []
        self.t = 0
        self.top_key = -1.0
        self._next_key = 0.0
        self.events: List[dict] = []
        os.makedirs(workdir, exist_ok=True)

    # -- job lifecycle -----------------------------------------------------

    def submit(self, spec: JobSpec) -> Job:
        job = Job(spec=spec)
        self.jobs.append(job)
        return job

    def _init_state(self, job: Job) -> None:
        if job.ckpt_path is not None:
            template = trainer.init_train_state(
                job.spec.cfg, job.spec.opt, jax.random.key(0))
            job.state = load_pytree(template, job.ckpt_path)
        elif job.state is None:
            job.state = trainer.init_train_state(
                job.spec.cfg, job.spec.opt,
                jax.random.key(hash(job.spec.name) % (1 << 31)))
        if job._step_fn is None:
            job._step_fn = jax.jit(trainer.make_train_step(
                job.spec.cfg, job.spec.opt))

    def _start(self, job: Job, node: int) -> None:
        job.status = RUNNING
        job.node = node
        self.free[node] -= job.spec.demand
        self._init_state(job)
        self.events.append({"t": self.t, "ev": "start",
                            "job": job.spec.name})

    def _signal(self, job: Job, te: Job) -> None:
        job.status = GRACE
        job.grace_left = job.gp
        job.preempt_count += 1
        self.pending_free[job.node] += job.spec.demand
        self.events.append({"t": self.t, "ev": "preempt",
                            "job": job.spec.name, "for": te.spec.name,
                            "gp": job.grace_left})
        if job.grace_left == 0:
            self._vacate(job)

    def _vacate(self, job: Job) -> None:
        # grace period over: the checkpoint is flushed and memory freed
        job.ckpt_path = os.path.join(
            self.workdir, f"{job.spec.name}.{job.preempt_count}.npz")
        save_pytree(job.state, job.ckpt_path)
        job.state = None
        self.pending_free[job.node] -= job.spec.demand
        self.free[job.node] += job.spec.demand
        job.node = -1
        job.status = QUEUED
        job.queue_key = self.top_key
        self.top_key -= 1.0
        self.events.append({"t": self.t, "ev": "vacate",
                            "job": job.spec.name,
                            "ckpt": job.ckpt_path})

    def _finish(self, job: Job) -> None:
        self.free[job.node] += job.spec.demand
        job.node = -1
        job.status = DONE
        job.finish_time = self.t
        self.events.append({"t": self.t, "ev": "done",
                            "job": job.spec.name})

    # -- scheduling ---------------------------------------------------------

    def _first_fit(self, demand) -> int:
        fits = np.all(self.free >= demand[None, :] - 1e-9, axis=1)
        idx = np.flatnonzero(fits)
        return int(idx[0]) if len(idx) else -1

    def _queued(self, te: bool) -> List[Job]:
        js = [j for j in self.jobs if j.status == QUEUED
              and j.spec.is_te == te]
        return sorted(js, key=lambda j: j.queue_key)

    def _try_preempt(self, te: Job) -> None:
        cands = [j for j in self.jobs
                 if j.status == RUNNING and not j.spec.is_te]
        if not cands:
            return
        cand_node = np.asarray([j.node for j in cands])
        victims = self.policy.select(
            rng=self.rng,
            te_demand=te.spec.demand,
            cand_ids=np.arange(len(cands)),
            cand_demand=np.stack([j.spec.demand for j in cands]),
            cand_node_free=self.free[cand_node],
            cand_gp=np.asarray([j.gp for j in cands], float),
            cand_remaining=np.asarray(
                [j.spec.total_steps - j.steps_done for j in cands], float),
            under_cap=np.asarray([j.preempt_count < self.P for j in cands]),
            all_run_demand=np.stack([j.spec.demand for j in cands]),
            all_run_gp=np.asarray([j.gp for j in cands], float),
            node_cap=self.node_cap,
            free_by_node=self.free,
            cand_node=cand_node,
        )
        for v in victims:
            self._signal(cands[int(v)], te)

    def tick(self) -> None:
        # arrivals
        for job in self.jobs:
            if job.status == PENDING and job.spec.submit_tick <= self.t:
                job.status = QUEUED
                job.queue_key = self._next_key
                self._next_key += 1.0
                job.submit_time = self.t
        # grace expiry
        for job in [j for j in self.jobs
                    if j.status == GRACE and j.grace_left <= 0]:
            self._vacate(job)
        # TE lane
        if self.policy.preemptive:
            for job in self._queued(te=True):
                node = self._first_fit(job.spec.demand)
                if node >= 0:
                    self._start(job, node)
                else:
                    promised = self.free + self.pending_free
                    fits_pending = np.all(
                        promised >= job.spec.demand[None, :] - 1e-9,
                        axis=1).any()
                    if not fits_pending:
                        self._try_preempt(job)
        # BE queue, strict FIFO
        queue = self._queued(te=False) if self.policy.preemptive else \
            sorted([j for j in self.jobs if j.status == QUEUED],
                   key=lambda j: j.queue_key)
        for job in queue:
            node = self._first_fit(job.spec.demand)
            if node < 0:
                break                     # head-of-line blocking
            self._start(job, node)
        # run real train steps for every RUNNING job
        for job in self.jobs:
            if job.status == RUNNING:
                for _ in range(self.steps_per_tick):
                    if job.steps_done >= job.spec.total_steps:
                        break
                    batch = make_batch(job.spec.cfg, job.spec.batch,
                                       job.spec.seq_len, seed=1,
                                       step=job.steps_done)
                    job.state, m = job._step_fn(job.state, batch)
                    job.losses.append(float(m["loss"]))
                    job.steps_done += 1
                job.run_ticks += 1
                if job.steps_done >= job.spec.total_steps:
                    self._finish(job)
            elif job.status == GRACE:
                job.grace_left -= 1
        self.t += 1

    def run(self, max_ticks: int = 10_000) -> None:
        while any(j.status != DONE for j in self.jobs):
            self.tick()
            if self.t > max_ticks:
                raise RuntimeError("controller did not converge")

    # -- metrics --------------------------------------------------------------

    def slowdown(self, job: Job) -> float:
        turnaround = job.finish_time - job.spec.submit_tick
        exec_ticks = max(job.run_ticks, 1)
        return 1.0 + max(turnaround - exec_ticks, 0) / exec_ticks
