"""Backend-aware policy registry: one namespace for every decision rule.

Mirrors ``scenarios/registry.py``: the paper's contribution (FitGpp,
Eq. 1-4) is a *decision rule*, and adding or varying one used to mean
editing three unrelated surfaces (the numpy ``Policy`` classes, a
policy-name string chain inside ``sim_jax.make_tick``, and the Pallas
kernel wiring). A policy now registers ONCE:

    @register_policy("srtp", description="...")
    class SrtpPolicy(Policy):
        jax_kind = "rank"
        def select(...): ...          # reference (numpy) victim choice
        def rank_key(...): ...        # reference preemption-order key
        def jax_rank(self, st, jobs): ...   # JAX engine declaration

and every engine discovers it from here: the reference simulator and
the live controller instantiate it via :func:`make`, and
``sim_jax.make_tick`` builds its victim-selection trigger from the
class's JAX declaration (``jax_kind`` = ``"rank"`` or ``"score"``; see
``core/policies.Policy`` for the exact contracts). Score policies may
additionally declare accelerated score backends (``score_backends``,
e.g. the Pallas ``fitgpp_score`` kernel as ``"pallas"``), selectable
per run through ``SimConfig.score_backend``.

``SimConfig.__post_init__`` calls :func:`validate_config`, so an
unknown policy (or an unknown score-backend name, or nonsense ``s`` /
``P``) fails at construction time with the registered names in the
error — not deep inside an engine. DESIGN.md §6.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

# How a policy consumes randomness — drives the auto-generated
# reference-vs-JAX parity matrix (tests/test_engine_parity.py):
RNG_NONE = "none"          # deterministic: exact parity on any workload
RNG_FALLBACK = "fallback"  # rng only on the no-eligible-victim fallback
RNG_ALWAYS = "always"      # every selection draws (statistical parity only)
_RNG_KINDS = (RNG_NONE, RNG_FALLBACK, RNG_ALWAYS)

_JAX_KINDS = (None, "rank", "score")


@dataclass(frozen=True)
class PolicySpec:
    name: str
    cls: type                          # Policy subclass (numpy + JAX decls)
    description: str                   # one line, shown by the CLI
    preemptive: bool
    jax_kind: Optional[str]            # None | "rank" | "score"
    rng: str                           # RNG_NONE | RNG_FALLBACK | RNG_ALWAYS
    score_backends: Tuple[str, ...]    # always includes "jnp"

    @property
    def dual_backend(self) -> bool:
        """Runs on the JAX engine too (non-preemptive policies need no
        victim-selection code there)."""
        return (not self.preemptive) or self.jax_kind is not None

    def make(self, s: Optional[float] = None):
        """Instantiate the decision rule (``s`` = Eq. 3 GP weight)."""
        from repro.configs.base import PAPER_S
        return self.cls(PAPER_S if s is None else float(s))


_REGISTRY: Dict[str, PolicySpec] = {}
_populated = False


def _ensure_populated() -> None:
    """Importing ``core/policies`` registers the built-in policies.

    The flag is set only AFTER a successful import: a failing first
    import must surface its real error on every call, not poison the
    registry into misleading "registered: <none>" messages."""
    global _populated
    if not _populated:
        import repro.core.policies        # noqa: F401
        _populated = True


def register_policy(name: str, *, description: str = "",
                    rng: str = RNG_NONE):
    """Class decorator registering a ``Policy`` subclass as ``name``.

    The class itself carries the backend declarations (``preemptive``,
    ``jax_kind``, ``score_backends``, the ``jax_*`` methods);
    ``description`` defaults to the first line of the docstring.
    """
    if rng not in _RNG_KINDS:
        raise ValueError(f"rng must be one of {_RNG_KINDS}, got {rng!r}")

    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        jax_kind = getattr(cls, "jax_kind", None)
        if jax_kind not in _JAX_KINDS:
            raise ValueError(f"{name!r}: jax_kind must be one of "
                             f"{_JAX_KINDS}, got {jax_kind!r}")
        doc = (cls.__doc__ or "").strip().splitlines()
        desc = description or (doc[0] if doc else "")
        if not desc:
            raise ValueError(f"policy {name!r} needs a description (pass "
                             "description=... or give the class a docstring)")
        backends = tuple(getattr(cls, "score_backends", ("jnp",)))
        if "jnp" not in backends:
            raise ValueError(f"{name!r}: score_backends must include 'jnp'")
        cls.name = name
        _REGISTRY[name] = PolicySpec(
            name=name, cls=cls, description=desc,
            preemptive=bool(getattr(cls, "preemptive", True)),
            jax_kind=jax_kind, rng=rng, score_backends=backends)
        return cls

    return deco


def get_policy(name: str) -> PolicySpec:
    _ensure_populated()
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY)) or "<none>"
        raise KeyError(f"unknown policy {name!r}; registered: {known}") \
            from None


def policy_names() -> List[str]:
    _ensure_populated()
    return sorted(_REGISTRY)


def all_policies() -> List[PolicySpec]:
    return [_REGISTRY[n] for n in policy_names()]


def score_backend_names() -> List[str]:
    """Union of score backends over all registered policies (the CLI's
    ``--score-backend`` choices and the validation set)."""
    _ensure_populated()
    return sorted({b for sp in _REGISTRY.values()
                   for b in sp.score_backends})


def make(name: str, s: Optional[float] = None):
    """Instantiate the named decision rule (registry-dispatched
    replacement for the deprecated ``policies.make_policy``)."""
    return get_policy(name).make(s)


def validate_config(policy: str, s, P, score_backend: str = "jnp") -> None:
    """Fail fast (ValueError) on a config no engine could run.

    Called from ``SimConfig.__post_init__`` so typos surface at
    construction time with the registered names, instead of a KeyError
    deep inside ``make_policy``/``make_tick``.
    """
    _ensure_populated()
    if policy not in _REGISTRY:
        raise ValueError(
            f"unknown policy {policy!r}; known policies: "
            f"{', '.join(sorted(_REGISTRY))}")
    try:
        s_ok = math.isfinite(float(s)) and float(s) >= 0.0
    except (TypeError, ValueError):
        s_ok = False
    if not s_ok:
        raise ValueError(
            f"s (Eq. 3 grace-period weight) must be a finite float >= 0, "
            f"got {s!r}")
    try:
        p_ok = int(P) == P and int(P) >= 0
    except (TypeError, ValueError):
        p_ok = False
    if not p_ok:
        raise ValueError(
            f"max_preemptions (the paper's P cap) must be an integer >= 0, "
            f"got {P!r}")
    # Backend validation is name-level only: configs are re-pointed
    # across policies all the time (dataclasses.replace(cfg, policy=...)
    # — sweeps, workload.generate's internal FIFO admission pass), so an
    # inert score_backend on a rank/non-preemptive policy is fine; the
    # JAX engine falls back to "jnp" for policies without the backend.
    known = score_backend_names()
    if score_backend not in known:
        raise ValueError(
            f"unknown score backend {score_backend!r}; registered: "
            f"{', '.join(known)}")
