"""Reference discrete-time cluster simulator (paper §4.1 semantics).

Faithful, transparent implementation used to reproduce Tables 1-5 and
Figures 3-7, and as the parity oracle for the JAX engine.

Mechanics (choices documented in DESIGN.md §3):
  * 1-minute ticks; allocation decided every tick.
  * Strict FIFO for the BE queue (no backfill -> head-of-line blocking).
  * TE jobs: under preemptive policies they live in a TE-priority FIFO
    served before the BE queue; under vanilla FIFO they share the queue.
  * Preemption: victims get a grace period (GP); resources free when the
    GP expires (GP=0 vacates the same tick); the victim re-enters the
    TOP of the BE queue with its remaining execution time intact.
  * A TE that triggered preemption re-triggers victim selection only
    after all victims it signalled have vacated (defensive; rare).

This module is a thin DRIVER over the shared scheduling core
(``repro.core.engine``, DESIGN.md §2): the :class:`SchedulerCore` owns
the queues, placement, the grace lifecycle and policy invocation; this
driver owns the workload (arrivals / closed-loop admission), the clock
and result assembly.

Time advancement (DESIGN.md §4): the default ``mode="event"`` jumps the
clock straight to the next event (arrival, finish, grace expiry)
whenever a schedule pass provably cannot start or preempt anything —
the skipped ticks are pure countdowns, bulk-applied, so the result is
bit-for-bit identical to ``mode="tick"`` (property-tested, including
the RNG-consuming policies). On sparse / long-horizon workloads this
drops wall-clock by an order of magnitude.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs.cluster import SimConfig
from repro.core import policy_registry
from repro.core.engine import ClusterState, CoreHooks, SchedulerCore
from repro.core.types import JobSet, PreemptionEvent, SimResult
from repro.obs import schema as obs_schema


def admission_fraction(demand: np.ndarray, n_nodes: np.ndarray,
                       node_cap: np.ndarray,
                       cluster_nodes: int) -> np.ndarray:
    """Per-job FIFO-normalized load fraction (DESIGN.md §3): the mean
    of the three cluster-normalized resources times the gang width.
    One definition, shared by the monolithic closed-loop Simulator and
    the streaming admission controller (``core/stream/admission.py``)
    — both must accumulate bit-identical fractions for their admit
    times to agree exactly. Row-wise, so chunked evaluation equals the
    whole-jobset evaluation bit for bit."""
    cluster_cap = node_cap * cluster_nodes
    return (demand / cluster_cap[None, :]).mean(axis=1) * n_nodes


class AdmissionGate:
    """Closed-loop admission state (paper §4.2): a scalar backlog
    accumulator over :func:`admission_fraction` values. ``admit`` /
    ``release`` are the ONLY mutations, and both drivers (monolithic
    and streamed) call them in the same global order — admits in job
    index order, releases in finish-tick-then-index order — so the
    float accumulation (and therefore every ``wants_next`` decision)
    is bit-identical between them."""

    def __init__(self, target: float):
        self.target = float(target)
        self.load = 0.0

    @property
    def active(self) -> bool:
        return self.target > 0

    def wants_next(self) -> bool:
        """Is the backlog below target, i.e. is an admission due?"""
        return self.load < self.target

    def admit(self, frac) -> None:
        self.load += frac

    def release(self, frac) -> None:
        self.load -= frac


class Simulator:
    def __init__(self, cfg: SimConfig, jobs: JobSet,
                 admission_target: float = 0.0, trace: bool = False):
        """``admission_target`` > 0 switches to closed-loop admission:
        ``jobs.submit`` is ignored and the next job (in index order) is
        admitted whenever the backlog load (cluster-normalized demand of
        all admitted, unfinished jobs) is below the target. Used once,
        under FIFO, to realize the paper's "load kept at 2.0 if scheduled
        by FIFO" arrival process; the recorded admit times then serve as
        open-loop submit times for every policy.

        ``trace`` records the canonical event stream (``obs.schema``)
        into ``SimResult.trace`` — the reference half of the
        cross-engine trace-parity contract (DESIGN.md §8)."""
        self.cfg = cfg
        self.jobs = jobs
        self.admission_target = admission_target
        self.gate = AdmissionGate(admission_target)
        self.trace_events = [] if trace else None
        self.admit_time = np.full(jobs.n, -1, np.int64)
        self.policy = policy_registry.make(cfg.policy, s=cfg.s)
        self.node_cap = np.asarray(cfg.cluster.node.as_tuple(), np.float64)
        self.n_nodes = cfg.cluster.n_nodes
        self.rng = np.random.default_rng(cfg.seed + 104729)

        n = jobs.n
        self.remaining = jobs.exec_total.astype(np.int64).copy()
        self.finish = np.full(n, -1, np.int64)
        self.vacated_at = np.full(n, -1, np.int64)
        self.events: List[PreemptionEvent] = []
        self.open_events: Dict[int, PreemptionEvent] = {}

        self.core = SchedulerCore(
            cluster=ClusterState(self.n_nodes, self.node_cap),
            policy=self.policy,
            max_preemptions=cfg.max_preemptions,
            rng=self.rng,
            demand=jobs.demand,
            is_te=jobs.is_te,
            width=jobs.n_nodes,
            gp_of=lambda ids: jobs.gp[ids],
            remaining_of=lambda ids: self.remaining[ids],
            backfill=cfg.backfill,
            backfill_depth=cfg.backfill_depth,
            hooks=CoreHooks(on_start=self._on_start,
                            on_signal=self._on_signal,
                            on_vacate=self._on_vacate,
                            on_finish=self._on_finish,
                            on_backfill=self._on_backfill),
        )

        order = np.argsort(jobs.submit, kind="stable")
        self.arrival_order = order
        self._next_arrival = 0
        self.frac = admission_fraction(jobs.demand, jobs.n_nodes,
                                       self.node_cap, self.n_nodes)

    # -- result bookkeeping (driver-side, via core hooks) --------------------

    def _emit(self, t: int, code: int, j: int, aux: int = -1,
              nodes=()) -> None:
        if self.trace_events is not None:
            self.trace_events.append(obs_schema.Event(
                t=int(t), code=code, job=int(j), aux=int(aux),
                nodes=tuple(int(n) for n in nodes)))

    def _on_start(self, j: int, nodes: np.ndarray, t: int) -> None:
        resumed = self.vacated_at[j] >= 0
        self._emit(t, obs_schema.RESUME if resumed else obs_schema.START,
                   j, nodes=np.atleast_1d(np.asarray(nodes)))
        if resumed:
            ev = self.open_events.pop(j, None)
            if ev is not None:
                ev.resume_time = t
            self.vacated_at[j] = -1

    def _on_signal(self, j: int, te: int, t: int) -> None:
        self._emit(t, obs_schema.PREEMPT_SIGNAL, j, aux=te)
        ev = PreemptionEvent(job=j, te_job=te, signal_time=t)
        self.events.append(ev)
        self.open_events[j] = ev

    def _on_vacate(self, j: int, t: int) -> None:
        if self.trace_events is not None:
            # a GP=0 victim vacates inline at signal time without ever
            # entering grace — no GRACE_EXPIRE row for it
            if int(self.jobs.gp[j]) > 0:
                self._emit(t, obs_schema.GRACE_EXPIRE, j)
            ev = self.open_events.get(j)
            self._emit(t, obs_schema.VACATE, j,
                       aux=ev.te_job if ev is not None else -1)
            self._emit(t, obs_schema.REQUEUE, j)
        self.vacated_at[j] = t
        if j in self.open_events:
            self.open_events[j].vacate_time = t

    def _on_finish(self, j: int, t: int) -> None:
        self._emit(t, obs_schema.FINISH, j)

    def _on_backfill(self, j: int, skipped: int, t: int) -> None:
        self._emit(t, obs_schema.BACKFILL, j, aux=skipped)

    # -- state views (tests and subclasses introspect these) ----------------

    @property
    def free(self) -> np.ndarray:
        return self.core.cluster.free

    @property
    def pending_free(self) -> np.ndarray:
        return self.core.cluster.pending_free

    @property
    def state(self) -> np.ndarray:
        return self.core.state

    @property
    def node(self) -> np.ndarray:
        return self.core.node

    @property
    def preempt_count(self) -> np.ndarray:
        return self.core.preempt_count

    @property
    def grace_left(self) -> np.ndarray:
        return self.core.grace_left

    @property
    def job_nodes(self) -> Dict[int, np.ndarray]:
        return self.core.job_nodes

    @property
    def running(self):
        return self.core.running

    @property
    def running_be(self):
        return self.core.running_be

    @property
    def grace(self):
        return self.core.grace

    @property
    def n_done(self) -> int:
        return self.core.n_done

    # -- one tick ------------------------------------------------------------

    def step(self, t: int) -> None:
        jobs = self.jobs
        core = self.core
        # arrivals
        if self.gate.active:
            # closed-loop: admit next jobs while backlog < target
            while (self._next_arrival < jobs.n and
                   self.gate.wants_next()):
                j = self._next_arrival
                core.enqueue(j)
                self._emit(t, obs_schema.SUBMIT, j)
                self.admit_time[j] = t
                self.gate.admit(self.frac[j])
                self._next_arrival += 1
        else:
            while (self._next_arrival < jobs.n and
                   jobs.submit[self.arrival_order[self._next_arrival]] <= t):
                j = int(self.arrival_order[self._next_arrival])
                core.enqueue(j)
                self._emit(t, obs_schema.SUBMIT, j)
                self._next_arrival += 1
        # grace countdown -> vacate, then allocate
        core.expire_grace(t)
        core.schedule(t)
        # run for one minute
        if core.running:
            run = np.fromiter(core.running, np.int64, count=len(core.running))
            self.remaining[run] -= 1
            for j in np.sort(run[self.remaining[run] <= 0]):
                j = int(j)
                core.finish(j, t + 1)
                self.finish[j] = t + 1
                self.gate.release(self.frac[j])
        core.tick_clocks()

    # -- event-driven time advancement (DESIGN.md §4) ------------------------

    def _fast_forward(self, t: int, max_ticks: int) -> int:
        """Return the next tick that must actually execute, bulk-applying
        the countdowns of the skipped (provably no-op) ticks."""
        core = self.core
        if core.schedule_would_act():
            return t
        nxt = None
        if self.gate.active:
            if (self._next_arrival < self.jobs.n and
                    self.gate.wants_next()):
                return t                      # admission due next tick
        elif self._next_arrival < self.jobs.n:
            nxt = int(self.jobs.submit[
                self.arrival_order[self._next_arrival]])
        run = None
        if core.running:
            run = np.fromiter(core.running, np.int64, count=len(core.running))
            # remaining r after a step -> the job finishes during the
            # step at tick (t - 1) + r
            ev = t - 1 + int(self.remaining[run].min())
            nxt = ev if nxt is None else min(nxt, ev)
        g = core.min_grace_left()
        if g is not None:
            # grace_left g after a step -> vacates at the top of tick t + g
            ev = t + g
            nxt = ev if nxt is None else min(nxt, ev)
        if nxt is None:
            raise RuntimeError(
                "simulation stalled: jobs remain but no arrival, finish or "
                "grace expiry is pending and nothing can be scheduled")
        if nxt <= t:
            return t
        if nxt >= max_ticks:
            raise RuntimeError(
                f"simulation did not converge in {max_ticks} ticks")
        k = nxt - t
        if run is not None:
            self.remaining[run] -= k
        core.tick_clocks(k)
        return nxt

    def run(self, max_ticks: int = 10_000_000,
            mode: str = "event") -> SimResult:
        """``mode="event"`` (default) and ``mode="tick"`` produce
        bit-identical results; event mode just skips no-op ticks."""
        if mode not in ("event", "tick"):
            raise ValueError(f"unknown advancement mode: {mode!r}")
        t = 0
        n = self.jobs.n
        while self.core.n_done < n:
            self.step(t)
            t += 1
            if self.core.n_done < n:
                if t >= max_ticks:
                    raise RuntimeError(
                        f"simulation did not converge in {t} ticks")
                if mode == "event":
                    t = self._fast_forward(t, max_ticks)
        return SimResult(
            finish=self.finish.copy(),
            exec_total=self.jobs.exec_total.copy(),
            submit=self.jobs.submit.copy(),
            is_te=self.jobs.is_te.copy(),
            preempt_count=self.core.preempt_count.copy(),
            events=self.events,
            makespan=t,
            trace=self.trace_events,
        )


def simulate(cfg: SimConfig, jobs: JobSet, mode: str = "event",
             trace: bool = False) -> SimResult:
    return Simulator(cfg, jobs, trace=trace).run(mode=mode)
