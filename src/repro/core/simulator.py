"""Reference discrete-time cluster simulator (paper §4.1 semantics).

Faithful, transparent implementation used to reproduce Tables 1-5 and
Figures 3-7, and as the parity oracle for the JAX engine.

Mechanics (choices documented in DESIGN.md §3):
  * 1-minute ticks; allocation decided every tick.
  * Strict FIFO for the BE queue (no backfill -> head-of-line blocking).
  * TE jobs: under preemptive policies they live in a TE-priority FIFO
    served before the BE queue; under vanilla FIFO they share the queue.
  * Preemption: victims get a grace period (GP); resources free when the
    GP expires (GP=0 vacates the same tick); the victim re-enters the
    TOP of the BE queue with its remaining execution time intact.
  * A TE that triggered preemption re-triggers victim selection only
    after all victims it signalled have vacated (defensive; rare).

Data structures: the job queues are lazy-deletion heaps and the running/
grace sets are Python sets — running jobs are bounded by cluster
capacity (<~1k), so every tick is O(active), not O(n_jobs).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Set

import numpy as np

from repro.configs.cluster import SimConfig
from repro.core import policies as pol
from repro.core.types import (DONE, GRACE, NOT_ARRIVED, QUEUED, RUNNING,
                              JobSet, PreemptionEvent, SimResult)


class Simulator:
    def __init__(self, cfg: SimConfig, jobs: JobSet,
                 admission_target: float = 0.0):
        """``admission_target`` > 0 switches to closed-loop admission:
        ``jobs.submit`` is ignored and the next job (in index order) is
        admitted whenever the backlog load (cluster-normalized demand of
        all admitted, unfinished jobs) is below the target. Used once,
        under FIFO, to realize the paper's "load kept at 2.0 if scheduled
        by FIFO" arrival process; the recorded admit times then serve as
        open-loop submit times for every policy."""
        self.cfg = cfg
        self.jobs = jobs
        self.admission_target = admission_target
        self.admit_time = np.full(jobs.n, -1, np.int64)
        self._load = 0.0
        self.policy = pol.make_policy(cfg.policy, cfg.s)
        self.node_cap = np.asarray(cfg.cluster.node.as_tuple(), np.float64)
        self.n_nodes = cfg.cluster.n_nodes
        self.rng = np.random.default_rng(cfg.seed + 104729)

        n = jobs.n
        self.state = np.full(n, NOT_ARRIVED, np.int8)
        self.remaining = jobs.exec_total.astype(np.int64).copy()
        self.node = np.full(n, -1, np.int64)
        self.preempt_count = np.zeros(n, np.int64)
        self.grace_left = np.zeros(n, np.int64)
        self.queue_key = np.full(n, np.inf)      # lower = closer to head
        self.top_key = -1.0                       # next "top of queue" key
        self.finish = np.full(n, -1, np.int64)
        self.vacated_at = np.full(n, -1, np.int64)
        self.te_pending = np.zeros(n, np.int64)  # victims still in grace
        self.victim_of = np.full(n, -1, np.int64)
        self.free = np.tile(self.node_cap, (self.n_nodes, 1))
        self.events: List[PreemptionEvent] = []
        self.open_events: Dict[int, PreemptionEvent] = {}

        self.te_heap: List = []      # (key, job)
        self.be_heap: List = []
        # resources already promised by in-flight grace periods, per node
        self.pending_free = np.zeros((self.n_nodes, 3))
        self.running: Set[int] = set()
        self.running_be: Set[int] = set()
        self.grace: Set[int] = set()
        self.n_done = 0

        self.job_nodes: Dict[int, np.ndarray] = {}   # gang placements
        order = np.argsort(jobs.submit, kind="stable")
        self.arrival_order = order
        self._next_arrival = 0
        cluster_cap = self.node_cap * self.n_nodes
        self.frac = (jobs.demand / cluster_cap[None, :]).mean(axis=1) \
            * jobs.n_nodes

    # -- queue helpers -------------------------------------------------------

    def _push(self, j: int, key: float) -> None:
        self.queue_key[j] = key
        use_te_lane = self.policy.preemptive and self.jobs.is_te[j]
        heapq.heappush(self.te_heap if use_te_lane else self.be_heap,
                       (key, j))

    def _pop_valid(self, heap) -> int:
        """-> head job index or -1. Skips stale (lazy-deleted) entries."""
        while heap:
            key, j = heap[0]
            if self.state[j] == QUEUED and self.queue_key[j] == key:
                return j
            heapq.heappop(heap)
        return -1

    # -- resource helpers ----------------------------------------------------

    def _first_fit(self, demand: np.ndarray, k: int = 1) -> int:
        """First node fitting ``demand`` (k=1), or -1. For gangs (k>1)
        use _gang_fit."""
        fits = np.all(self.free >= demand[None, :] - 1e-9, axis=1)
        idx = np.flatnonzero(fits)
        if k > 1:
            return -1 if len(idx) < k else int(idx[0])
        return int(idx[0]) if len(idx) else -1

    def _gang_fit(self, demand: np.ndarray, k: int):
        """First k nodes that each fit ``demand`` (gang: all-or-nothing)."""
        fits = np.all(self.free >= demand[None, :] - 1e-9, axis=1)
        idx = np.flatnonzero(fits)
        return idx[:k] if len(idx) >= k else None

    def _fits_job(self, j: int):
        """-> node array for job j (len n_nodes[j]) or None."""
        k = int(self.jobs.n_nodes[j])
        if k == 1:
            n = self._first_fit(self.jobs.demand[j])
            return None if n < 0 else np.asarray([n])
        return self._gang_fit(self.jobs.demand[j], k)

    def _start(self, j: int, nodes, t: int) -> None:
        nodes = np.atleast_1d(np.asarray(nodes))
        self.state[j] = RUNNING
        self.node[j] = int(nodes[0])
        self.job_nodes[j] = nodes
        self.free[nodes] -= self.jobs.demand[j]
        self.queue_key[j] = np.inf
        self.running.add(j)
        if not self.jobs.is_te[j]:
            self.running_be.add(j)
        if self.vacated_at[j] >= 0:
            ev = self.open_events.pop(j, None)
            if ev is not None:
                ev.resume_time = t
            self.vacated_at[j] = -1

    def _signal_preemption(self, j: int, te: int, t: int) -> None:
        """Move a running BE job into its grace period."""
        assert self.state[j] == RUNNING and not self.jobs.is_te[j]
        self.state[j] = GRACE
        self.grace_left[j] = self.jobs.gp[j]
        self.preempt_count[j] += 1
        self.victim_of[j] = te
        self.te_pending[te] += 1
        self.running.discard(j)
        self.running_be.discard(j)
        self.pending_free[self.job_nodes[j]] += self.jobs.demand[j]
        ev = PreemptionEvent(job=j, te_job=te, signal_time=t)
        self.events.append(ev)
        self.open_events[j] = ev
        if self.grace_left[j] <= 0:          # GP=0: vacate immediately
            self._vacate(j, t)
        else:
            self.grace.add(j)

    def _vacate(self, j: int, t: int) -> None:
        nodes = self.job_nodes.pop(j)
        self.free[nodes] += self.jobs.demand[j]
        self.pending_free[nodes] -= self.jobs.demand[j]
        self.node[j] = -1
        self.state[j] = QUEUED
        self.grace.discard(j)
        self._push(j, self.top_key)
        self.top_key -= 1.0
        self.vacated_at[j] = t
        if j in self.open_events:
            self.open_events[j].vacate_time = t
        te = int(self.victim_of[j])
        if te >= 0:
            self.te_pending[te] -= 1
            self.victim_of[j] = -1

    # -- victim selection ------------------------------------------------------

    def _cand_best_node(self, j: int, te_demand: np.ndarray) -> int:
        """Node of job j with the most slack for ``te_demand`` (Eq. 2 is
        evaluated against the victim's best node; single-node jobs keep
        their only node, preserving the paper's exact semantics)."""
        nodes = self.job_nodes[j]
        if len(nodes) == 1:
            return int(nodes[0])
        slack = np.min(self.free[nodes] + self.jobs.demand[j][None, :]
                       - te_demand[None, :], axis=1)
        return int(nodes[int(np.argmax(slack))])

    def _gang_preempt(self, te: int, t: int) -> None:
        """Multi-node TE (paper future work): Eq. 2/4 generalized —
        prefer the min-score SINGLE victim whose eviction alone yields
        >= k satisfying nodes (the paper's minimize-preemption-count
        strategy); otherwise signal victims in policy order until the
        gang fits (counting this selection's pending frees)."""
        k = int(self.jobs.n_nodes[te])
        d = self.jobs.demand[te]

        def n_fit(free):
            return int(np.all(free >= d[None, :] - 1e-9, axis=1).sum())

        cand = sorted(self.running_be)
        ranked = self._policy_rank(cand)
        if self.policy.name == "fitgpp":
            under = [j for j in ranked
                     if self.preempt_count[j] < self.cfg.max_preemptions]
            for j in (under or ranked):          # Eq. 4: min score first
                trial = self.free.copy()
                trial[self.job_nodes[j]] += self.jobs.demand[j]
                if n_fit(trial) >= k:
                    self._signal_preemption(j, te, t)
                    return
        pending = self.free.copy()
        victims = []
        for j in ranked:
            if n_fit(pending) >= k:
                break
            pending[self.job_nodes[j]] += self.jobs.demand[j]
            victims.append(j)
        if n_fit(pending) >= k:
            for v in victims:
                self._signal_preemption(v, te, t)

    def _policy_rank(self, cand):
        """Candidates in the policy's preemption order (under-cap first)."""
        if not cand:
            return []
        cand = np.asarray(cand)
        under = self.preempt_count[cand] < self.cfg.max_preemptions
        if self.policy.name == "lrtp":
            key = -self.remaining[cand].astype(float)
        elif self.policy.name == "rand":
            key = self.rng.random(len(cand))
        else:   # fitgpp: Eq. 3 score (normalized over running BE)
            key = pol.fitgpp_scores(
                self.jobs.demand[cand] * self.jobs.n_nodes[cand][:, None],
                self.jobs.gp[cand], self.node_cap, self.cfg.s)
        order = np.lexsort((key, ~under))
        return [int(cand[i]) for i in order]

    def _try_preempt_for(self, te: int, t: int) -> None:
        if self.jobs.n_nodes[te] > 1:
            self._gang_preempt(te, t)
            return
        cand = np.sort(np.fromiter(self.running_be, np.int64,
                                   count=len(self.running_be)))
        if len(cand) == 0:
            return
        cand_node = np.asarray([self._cand_best_node(int(j),
                                                     self.jobs.demand[te])
                                for j in cand])
        victims = self.policy.select(
            rng=self.rng,
            te_demand=self.jobs.demand[te],
            cand_ids=cand,
            cand_demand=self.jobs.demand[cand],
            cand_node_free=self.free[cand_node],
            cand_gp=self.jobs.gp[cand],
            cand_remaining=self.remaining[cand],
            under_cap=self.preempt_count[cand] < self.cfg.max_preemptions,
            all_run_demand=self.jobs.demand[cand],
            all_run_gp=self.jobs.gp[cand],
            node_cap=self.node_cap,
            free_by_node=self.free,
            cand_node=cand_node,
        )
        for v in victims:
            self._signal_preemption(v, te, t)

    # -- one tick ---------------------------------------------------------------

    def _schedule(self, t: int) -> None:
        # 1) TE priority lane (preemptive policies only)
        if self.policy.preemptive:
            blocked: List[int] = []
            while True:
                j = self._pop_valid(self.te_heap)
                if j < 0:
                    break
                nodes = self._fits_job(j)
                if nodes is not None:
                    heapq.heappop(self.te_heap)
                    self._start(j, nodes, t)
                else:
                    heapq.heappop(self.te_heap)
                    # Preempt only if the TE would not fit even counting
                    # resources already promised by in-flight grace
                    # periods ("the resource is insufficient", §2) — an
                    # imminent vacate is incoming supply, not a shortage.
                    promised = self.free + self.pending_free
                    fits_pending = (np.all(
                        promised >= self.jobs.demand[j][None, :] - 1e-9,
                        axis=1)).sum() >= int(self.jobs.n_nodes[j])
                    if self.te_pending[j] == 0 and not fits_pending:
                        self._try_preempt_for(j, t)
                        # GP=0 victims vacate inline: place the TE NOW,
                        # before the BE pass can reclaim the freed node.
                        nodes = self._fits_job(j)
                        if nodes is not None:
                            self._start(j, nodes, t)
                            continue
                    blocked.append(j)
            for j in blocked:                # keep FIFO order among TE
                heapq.heappush(self.te_heap, (self.queue_key[j], j))
        # 2) BE queue (all jobs under vanilla FIFO): strict head-of-line,
        # or bounded first-fit backfill (beyond-paper, cfg.backfill)
        if not self.cfg.backfill:
            while True:
                head = self._pop_valid(self.be_heap)
                if head < 0:
                    break
                nodes = self._fits_job(head)
                if nodes is None:
                    break                     # head-of-line blocking
                heapq.heappop(self.be_heap)
                self._start(head, nodes, t)
        else:
            skipped = []
            scanned = 0
            while scanned < self.cfg.backfill_depth:
                head = self._pop_valid(self.be_heap)
                if head < 0:
                    break
                heapq.heappop(self.be_heap)
                nodes = self._fits_job(head)
                if nodes is not None:
                    self._start(head, nodes, t)
                else:
                    skipped.append(head)
                    scanned += 1
            for j in skipped:                 # keep original keys
                heapq.heappush(self.be_heap, (self.queue_key[j], j))

    def step(self, t: int) -> None:
        jobs = self.jobs
        # arrivals
        if self.admission_target > 0:
            # closed-loop: admit next jobs while backlog < target
            while (self._next_arrival < jobs.n and
                   self._load < self.admission_target):
                j = self._next_arrival
                self.state[j] = QUEUED
                self._push(j, float(j))
                self.admit_time[j] = t
                self._load += self.frac[j]
                self._next_arrival += 1
        else:
            while (self._next_arrival < jobs.n and
                   jobs.submit[self.arrival_order[self._next_arrival]] <= t):
                j = int(self.arrival_order[self._next_arrival])
                self.state[j] = QUEUED
                self._push(j, float(self._next_arrival))
                self._next_arrival += 1
        # grace countdown -> vacate (job-index order: JAX-engine parity)
        for j in sorted(j for j in self.grace if self.grace_left[j] <= 0):
            self._vacate(j, t)
        # allocate
        self._schedule(t)
        # run for one minute
        if self.running:
            run = np.fromiter(self.running, np.int64, count=len(self.running))
            self.remaining[run] -= 1
            for j in np.sort(run[self.remaining[run] <= 0]):
                j = int(j)
                self.free[self.job_nodes.pop(j)] += jobs.demand[j]
                self.node[j] = -1
                self.state[j] = DONE
                self.finish[j] = t + 1
                self.running.discard(j)
                self.running_be.discard(j)
                self.n_done += 1
                self._load -= self.frac[j]
        if self.grace:
            g = np.fromiter(self.grace, np.int64, count=len(self.grace))
            self.grace_left[g] -= 1

    def run(self, max_ticks: int = 10_000_000) -> SimResult:
        t = 0
        while self.n_done < self.jobs.n:
            self.step(t)
            t += 1
            if t >= max_ticks:
                raise RuntimeError(f"simulation did not converge in {t} ticks")
        return SimResult(
            finish=self.finish.copy(),
            exec_total=self.jobs.exec_total.copy(),
            submit=self.jobs.submit.copy(),
            is_te=self.jobs.is_te.copy(),
            preempt_count=self.preempt_count.copy(),
            events=self.events,
            makespan=t,
        )


def simulate(cfg: SimConfig, jobs: JobSet) -> SimResult:
    return Simulator(cfg, jobs).run()
