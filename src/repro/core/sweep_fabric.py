"""Device-parallel sweep fabric: one sharded trial table for the whole
(scenario × policy × seed × s × P) grid.

The paper's sensitivity studies (§4.3, Figs. 4-7) are hundreds of
independent simulations. Each one is a pure-JAX program
(``core/sim_jax.py``), so a sweep is a *trial table* — stacked
``Jobs`` plus per-trial ``s`` / ``P`` / ``seed`` vectors — padded with
sentinel trials to the device count and ``shard_map``-ed over the 1-D
trial mesh from ``launch.mesh.mesh_for_sweep`` (DESIGN.md §11):

    table = sweep_fabric.build_table(jobsets, s_vals, P_vals, seeds)
    res = sweep_fabric.run_table(cfg, table)          # all local devices
    res.stats["te_slowdown"]                          # (T, 3) ndarray

Sharding is bit-exact with the single-device vmap: every lane of a
vmapped ``lax.while_loop`` computes its trial independently (the carry
is per-lane ``select``s), so grouping lanes into shards changes the
schedule, not the values — and it is *faster even on one core*,
because the vmapped loop runs lockstep (every lane steps until the
slowest finishes) while each shard only runs to its own slowest lane.

Axis contract: ``policy`` (and every other ``SimConfig`` field) is
compile-STATIC — one jitted program per config, cached in ``_RUNNERS``
so repeated calls (and seed-only re-runs) never recompile. ``s`` /
``P`` / ``seed`` are TRACED per-trial inputs: a whole sensitivity grid
over them shares one compilation. Multi-policy grids are one
``run_table`` call per policy over the same table.

Donation: with ``donate=True`` (auto on gpu/tpu backends, where XLA
implements input aliasing) the table's ``Jobs`` buffers are donated
into the jitted program, keeping per-shard memory flat; the trial
table is then CONSUMED by the call. ``init_state`` force-copies
``exec_total`` precisely so this aliasing is safe. The CPU backend
ignores donation, so ``donate=None`` resolves to False there.

``core/sweep.py`` (``run_sweep`` / ``sensitivity_grid`` /
``scenario_sweep``) is a thin wrapper over this module; callers reach
both through ``repro.api``.

Self-test (parity of sharded vs single-device, sentinel padding
exercised) — requires a multi-device runtime, e.g.::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.core.sweep_fabric \\
        --policies deterministic --modes event,tick
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.configs.cluster import SimConfig
from repro.core import metrics, sim_jax
from repro.core.types import JobSet
from repro.launch.mesh import mesh_for_sweep
from repro.sharding import put_trial_sharded, trial_spec

__all__ = [
    "SweepResult", "TrialTable", "build_table", "compile_stats",
    "pad_jobs", "pad_table", "pooled_tables", "run_table",
    "stack_jobsets", "table_from_stacked",
]


# ---------------------------------------------------------------- jobs

def pad_jobs(jobs: sim_jax.Jobs, n_max: int) -> sim_jax.Jobs:
    """Pad a Jobs struct to ``n_max`` rows with sentinel jobs.

    Sentinels carry zero demand, unit execution, ``width=1`` and
    ``valid=False``; ``sim_jax.init_state`` births them DONE so they
    never arrive, queue, run or appear as preemption candidates, and
    every percentile in the per-trial summaries masks them out (the
    sentinel-padding contract, DESIGN.md §5). Real rows keep their
    gang widths through the padding."""
    pad = n_max - jobs.submit.shape[0]
    if pad < 0:
        raise ValueError(f"cannot pad {jobs.submit.shape[0]} jobs "
                         f"down to {n_max}")
    if pad == 0:
        return jobs

    def ext(x, fill):
        tail = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
        return jnp.concatenate([x, tail])

    return sim_jax.Jobs(
        submit=ext(jobs.submit, 0), exec_total=ext(jobs.exec_total, 1),
        demand=ext(jobs.demand, 0.0), is_te=ext(jobs.is_te, False),
        gp=ext(jobs.gp, 0), width=ext(jobs.width, 1),
        valid=ext(jobs.valid, False),
        akey=None if jobs.akey is None else ext(jobs.akey, 0.0))


def stack_jobsets(jobsets: Sequence[JobSet]) -> sim_jax.Jobs:
    """Stack workloads over a leading trial axis.

    Equal-``n`` jobsets stack directly (the original fast path). Ragged
    collections — heterogeneous scenarios, trace replays — are padded to
    the max ``n`` with masked sentinel jobs (``pad_jobs``), so one
    vmapped/shard_mapped sweep can span them all. Gang widths
    (``JobSet.n_nodes`` → ``Jobs.width``) ride through both paths;
    sentinel rows stay width-1."""
    js = [sim_jax.jobs_from_jobset(j) for j in jobsets]
    n_max = max(j.submit.shape[0] for j in js)
    if any(j.submit.shape[0] != n_max for j in js):
        js = [pad_jobs(j, n_max) for j in js]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *js)


# --------------------------------------------------------- trial table

class TrialTable(NamedTuple):
    """The flattened sweep grid: trial t runs ``jobs[t]`` under
    ``(s[t], P[t], seed[t])``. ``n_valid`` counts the real trials; rows
    past it (appended by :func:`pad_table` for even device division)
    are sentinel trials — every job ``valid=False``, so the trial is
    born finished and exits its while_loop immediately."""
    jobs: sim_jax.Jobs       # leaves have leading (T,) axis
    s: jax.Array             # (T,) f32
    P: jax.Array             # (T,) i32
    seed: jax.Array          # (T,) u32
    n_valid: int


def table_from_stacked(jobs: sim_jax.Jobs, s_vals, P_vals,
                       seeds) -> TrialTable:
    """TrialTable from an already-stacked ``Jobs`` batch (the
    ``run_sweep`` calling convention). Scalars broadcast over T."""
    T = int(jobs.submit.shape[0])
    if T == 0:
        raise ValueError("empty trial table")

    def vec(x, dtype):
        a = jnp.asarray(x, dtype)
        if a.ndim == 0:
            a = jnp.full((T,), a, dtype)
        if a.shape != (T,):
            raise ValueError(f"per-trial vector has shape {a.shape}; "
                             f"expected ({T},)")
        return a

    return TrialTable(jobs=jobs, s=vec(s_vals, jnp.float32),
                      P=vec(P_vals, jnp.int32),
                      seed=vec(seeds, jnp.uint32), n_valid=T)


def build_table(jobsets: Sequence[JobSet], s_vals, P_vals,
                seeds) -> TrialTable:
    """TrialTable from one jobset per trial (``stack_jobsets`` pads
    ragged job counts with sentinel JOBS; :func:`pad_table` later pads
    the trial axis with sentinel TRIALS — same ``valid=False``
    contract, different axis)."""
    return table_from_stacked(stack_jobsets(jobsets), s_vals, P_vals,
                              seeds)


def pad_table(table: TrialTable, multiple: int) -> TrialTable:
    """Pad the trial axis to a multiple of ``multiple`` with sentinel
    trials, so an uneven grid still divides the device mesh evenly.
    A sentinel trial is all-sentinel jobs: born DONE, its while_loop
    exits on the first cond check and its summaries are all-nan —
    :func:`run_table` drops the padded rows before returning, and
    :func:`pooled_tables` never sees an invalid job."""
    T = int(table.s.shape[0])
    pad = -T % multiple
    if pad == 0:
        return table

    def ext(x, fill):
        tail = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
        return jnp.concatenate([x, tail])

    j = table.jobs
    jobs = sim_jax.Jobs(
        submit=ext(j.submit, 0), exec_total=ext(j.exec_total, 1),
        demand=ext(j.demand, 0.0), is_te=ext(j.is_te, False),
        gp=ext(j.gp, 0), width=ext(j.width, 1),
        valid=ext(j.valid, False),
        akey=None if j.akey is None else ext(j.akey, 0.0))
    return TrialTable(jobs=jobs, s=ext(table.s, 0.0), P=ext(table.P, 0),
                      seed=ext(table.seed, 0), n_valid=table.n_valid)


# ----------------------------------------------------- per-trial stats

def _masked_pct(vals, mask, ps):
    """Stacked percentiles of ``vals[mask]`` — explicit ``nan`` when
    the mask selects nothing (a trial with zero valid TE or BE jobs
    after sentinel padding): the trial then drops out of every
    nan-aware pooled table instead of contributing garbage."""
    v = jnp.where(mask, vals, jnp.nan)
    some = mask.any()
    return jnp.stack([jnp.where(some, jnp.nanpercentile(v, p), jnp.nan)
                      for p in ps])


def _masked_frac(mask, hit):
    """Fraction of ``mask`` rows with ``hit`` set; nan for an empty
    class (same NaN-safety contract as :func:`_masked_pct`)."""
    frac = jnp.nanmean(jnp.where(mask, hit.astype(jnp.float32), jnp.nan))
    return jnp.where(mask.any(), frac, jnp.nan)


def _trial_percentiles(cfg: SimConfig, jobs: sim_jax.Jobs, s, P_, key,
                       time_mode: Optional[str] = None):
    """The classic ``run_sweep`` per-trial summary dict (kept
    key-for-key: callers index these names)."""
    st = sim_jax.run(cfg, jobs, seed=key, s=s, P=P_, time_mode=time_mode)
    sd = sim_jax.slowdown(jobs, st)
    te = jobs.is_te & jobs.valid

    iv = (st.last_resume - st.last_signal).astype(jnp.float32)
    iv_mask = (st.last_resume >= 0) & jobs.valid
    pc = st.preempt_count
    be = ~jobs.is_te & jobs.valid
    return {
        "te_slowdown": _masked_pct(sd, te, (50, 95, 99)),
        "be_slowdown": _masked_pct(sd, be, (50, 95, 99)),
        "intervals": _masked_pct(iv, iv_mask, (50, 75, 95, 99)),
        "preempted_frac": _masked_frac(be, pc > 0),
        "preempt_1": _masked_frac(be, pc == 1),
        "preempt_2": _masked_frac(be, pc == 2),
        "preempt_3plus": _masked_frac(be, pc >= 3),
        "makespan": st.t,
    }


def _trial_per_job(cfg: SimConfig, jobs: sim_jax.Jobs, s, P_, key,
                   time_mode: Optional[str]):
    """Raw per-job arrays, for host-side pooling ACROSS trials
    (``pooled_tables`` — percentiles over the pooled per-job values,
    the paper's 8-workload pooling, not percentile-of-percentiles).
    Invalid (sentinel) jobs carry nan slowdown / nan interval /
    zero preempt_count; ``intervals`` is the LAST signal→resume gap
    per job (the JAX State tracks the most recent preemption — the
    same statistic ``api.run_experiment(engine="jax")`` reports, while
    the reference event stream can carry several gaps per job)."""
    st = sim_jax.run(cfg, jobs, seed=key, s=s, P=P_, time_mode=time_mode)
    sd = sim_jax.slowdown(jobs, st)
    iv = (st.last_resume - st.last_signal).astype(jnp.float32)
    iv_mask = (st.last_resume >= 0) & jobs.valid
    # valid/is_te ride through as OUTPUTS so pooling never has to read
    # the (possibly donated) input table
    return {
        "slowdown": jnp.where(jobs.valid, sd, jnp.nan),
        "preempt_count": jnp.where(jobs.valid, st.preempt_count, 0),
        "intervals": jnp.where(iv_mask, iv, jnp.nan),
        "valid": jobs.valid,
        "is_te": jobs.is_te,
        "makespan": st.t,
        "fallback_count": st.fallback_count,
    }


_TRIAL_FNS = {"percentiles": _trial_percentiles, "per_job": _trial_per_job}


# -------------------------------------------------------- the runners

# (cfg, time_mode, out, mesh, donate) -> jitted vmapped/shard_mapped
# runner. Module-level so repeated run_table calls — and seed-only
# re-runs, the old per-call jit recompile bug — reuse one compilation.
_RUNNERS: Dict[tuple, "jax.stages.Wrapped"] = {}


def _runner(cfg: SimConfig, time_mode: Optional[str], out: str,
            mesh: Optional[Mesh], donate: bool):
    key = (cfg, time_mode, out, mesh, donate)
    fn = _RUNNERS.get(key)
    if fn is not None:
        return fn
    trial = _TRIAL_FNS[out]

    def one(jobs_t, s, P_, seed):
        return trial(cfg, jobs_t, s, P_, jax.random.key(seed), time_mode)

    batched = jax.vmap(one)
    if mesh is not None:
        spec = trial_spec(mesh)
        batched = shard_map(batched, mesh=mesh, in_specs=(spec,) * 4,
                            out_specs=spec, check_rep=False)
    fn = jax.jit(batched, donate_argnums=(0,) if donate else ())
    _RUNNERS[key] = fn
    return fn


def compile_stats() -> Dict[str, int]:
    """Observability for the compile-once contract: ``runners`` is the
    number of distinct (cfg, mode, out, mesh, donate) programs built;
    ``compiles`` the total jit-cache entries behind them. A seed/s/P
    re-run must leave both unchanged (locked by the bench's
    ``compile_reuse`` row and tests)."""
    return {"runners": len(_RUNNERS),
            "compiles": sum(f._cache_size() for f in _RUNNERS.values())}


# ------------------------------------------------------------ results

@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Host-side result of one fabric run: ``stats`` maps summary name
    to an ndarray with leading trial axis (sentinel-padding rows
    already dropped — every array has exactly ``n_trials`` rows).
    ``out`` records which per-trial summary produced it
    ("percentiles": the classic ``run_sweep`` dict; "per_job": raw
    per-job arrays for :func:`pooled_tables`)."""
    stats: Dict[str, np.ndarray]
    n_trials: int
    n_padded: int
    n_devices: int
    out: str
    time_mode: str

    def __getitem__(self, k: str) -> np.ndarray:
        return self.stats[k]


def run_table(cfg: SimConfig, table: TrialTable, *,
              mesh: Optional[Mesh] = None,
              devices: Optional[int] = None,
              time_mode: Optional[str] = None,
              out: str = "percentiles",
              donate: Optional[bool] = None) -> SweepResult:
    """Run every trial of ``table`` under the static ``cfg``; the one
    entry point everything batches through.

    ``mesh`` (or ``devices``, via ``mesh_for_sweep``; default: every
    local device) picks the trial mesh — the table is sentinel-padded
    to its data-axis size, sharded with ``shard_map`` and gathered
    back to host with the padding rows dropped. A 1-device mesh (or
    ``devices=1``) is the plain single-device vmap; results are
    bit-identical either way. ``time_mode`` defaults to
    ``cfg.time_mode``; ``out`` selects the per-trial summary
    (:data:`_TRIAL_FNS`). ``donate=None`` auto-enables buffer donation
    where XLA supports it (gpu/tpu) — the table is then consumed by
    the call; pass ``donate=False`` to re-run one table."""
    if out not in _TRIAL_FNS:
        raise ValueError(f"unknown out {out!r}; one of "
                         f"{tuple(_TRIAL_FNS)}")
    if time_mode is None:
        time_mode = cfg.time_mode
    T = int(table.s.shape[0])
    if mesh is None:
        mesh = mesh_for_sweep(T, devices=devices)
    spec_axis = None if mesh is None else trial_spec(mesh)[0]
    n_dev = 1 if mesh is None else mesh.shape[spec_axis]
    if n_dev <= 1:
        mesh = None
        n_dev = 1
    if donate is None:
        donate = sim_jax.donation_supported()

    padded = pad_table(table, n_dev)
    args = (padded.jobs, padded.s, padded.P, padded.seed)
    if mesh is not None:
        args = put_trial_sharded(mesh, args)
    raw = _runner(cfg, time_mode, out, mesh, donate)(*args)
    stats = {k: np.asarray(v)[:T] for k, v in raw.items()}
    return SweepResult(stats=stats, n_trials=T,
                       n_padded=int(padded.s.shape[0]) - T,
                       n_devices=n_dev, out=out, time_mode=time_mode)


def pooled_tables(result: SweepResult,
                  trials: Optional[Sequence[int]] = None) -> Dict:
    """Paper-style pooled tables from a ``per_job`` fabric run —
    percentiles over the POOLED per-job values across trials (the
    paper pools its 8 workloads per cell), mirroring
    ``metrics.pooled_tables`` key-for-key. ``trials`` selects the
    subset of trial rows forming one cell (default: all); sentinel
    jobs (and any sentinel-trial rows a caller kept) are masked via
    the ``valid`` output column."""
    if result.out != "per_job":
        raise ValueError("pooled_tables needs a per_job SweepResult; "
                         f"got out={result.out!r}")
    idx = (np.arange(result.n_trials) if trials is None
           else np.asarray(trials, np.intp))
    valid = result.stats["valid"][idx]
    is_te = result.stats["is_te"][idx]
    sd = result.stats["slowdown"][idx]
    pc = result.stats["preempt_count"][idx]
    iv = result.stats["intervals"][idx]
    te, be = valid & is_te, valid & ~is_te
    pc_be = pc[be]
    n_be = len(pc_be) if len(pc_be) else float("nan")
    return {
        "TE": metrics.percentiles(sd[te]),
        "BE": metrics.percentiles(sd[be]),
        "intervals": metrics.percentiles(iv[~np.isnan(iv)],
                                         ps=(50, 75, 95, 99)),
        "preempted_frac": (float((pc_be > 0).mean()) if len(pc_be)
                           else float("nan")),
        "preempt_counts": {
            "1": float((pc_be == 1).sum()) / n_be,
            "2": float((pc_be == 2).sum()) / n_be,
            ">=3": float((pc_be >= 3).sum()) / n_be,
        },
    }


# ----------------------------------------------------------- selftest

def _deterministic_policies() -> List[str]:
    from repro.core import policy_registry
    from repro.core.policy_registry import RNG_ALWAYS
    return [sp.name for sp in policy_registry.all_policies()
            if sp.dual_backend and sp.rng != RNG_ALWAYS]


def _selftest(argv=None) -> None:
    """Sharded-vs-single-device parity on the live device set: every
    requested policy × time mode runs one preemption-heavy grid (a
    trial count that does NOT divide the mesh, so sentinel-trial
    padding is exercised) through the single-device vmap and the
    sharded fabric, asserting bit-identical SweepResult tables.
    Exits loudly when the runtime has fewer than 2 devices — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    import argparse

    from repro import scenarios
    from repro.configs.cluster import ClusterSpec, WorkloadSpec

    ap = argparse.ArgumentParser(description=_selftest.__doc__)
    ap.add_argument("--policies", default="fitgpp",
                    help="csv, or 'deterministic' for every "
                         "deterministic dual-backend policy")
    ap.add_argument("--modes", default="event", help="csv of time modes")
    ap.add_argument("--scenario", default="burst-storm",
                    help="preemption-heavy scenario family")
    ap.add_argument("--n-jobs", type=int, default=64)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--n-seeds", type=int, default=3)
    ap.add_argument("--s-vals", default="0,2,4")
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    if n_dev < 2:
        raise SystemExit(
            "sweep_fabric selftest needs >= 2 devices; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    policies = (_deterministic_policies()
                if args.policies == "deterministic"
                else args.policies.split(","))
    modes = args.modes.split(",")
    s_list = [float(x) for x in args.s_vals.split(",")]

    base = SimConfig(cluster=ClusterSpec(n_nodes=args.nodes),
                     workload=WorkloadSpec(n_jobs=args.n_jobs))
    jobsets = [scenarios.build(args.scenario,
                               dataclasses.replace(base, seed=sd))
               for sd in range(args.n_seeds)]
    T = args.n_seeds * len(s_list)
    s_flat = np.repeat(np.asarray(s_list, np.float32), args.n_seeds)
    seeds = np.tile(np.arange(args.n_seeds, dtype=np.uint32),
                    len(s_list))
    table = build_table(jobsets * len(s_list), s_flat, 1, seeds)
    if T <= n_dev or T % n_dev == 0:
        raise SystemExit(f"selftest wants T={T} trials > {n_dev} "
                         f"devices and NOT divisible by them (sentinel "
                         f"padding must be exercised); adjust "
                         f"--n-seeds/--s-vals")

    for pol in policies:
        cfg = dataclasses.replace(base, policy=pol)
        for mode in modes:
            single = run_table(cfg, table, devices=1, time_mode=mode,
                               donate=False)
            shard = run_table(cfg, table, time_mode=mode, donate=False)
            assert shard.n_devices == n_dev and shard.n_padded > 0
            diff = [k for k in single.stats
                    if not np.array_equal(single.stats[k],
                                          shard.stats[k],
                                          equal_nan=True)]
            if diff:
                raise SystemExit(f"parity FAILED: {pol}/{mode} sharded "
                                 f"vs single-device diff in {diff}")
            print(f"ok {pol:12s} {mode:5s}: {T} trials on {n_dev} "
                  f"devices (pad {shard.n_padded}) bit-exact")
    st = compile_stats()
    print(f"selftest ok: {len(policies)} policies x {len(modes)} modes, "
          f"{st['runners']} compiled runners")


if __name__ == "__main__":
    _selftest()
