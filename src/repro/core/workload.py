"""Synthetic workload generation (paper §4.2) and the §4.4 trace proxy.

The paper fits truncated normals to a private 6-month PFN trace for
execution time, CPU, RAM and GPU per class (TE / BE) and samples jobs
from them; arrival rate is set so the FIFO-normalized cluster load is a
target value (2.0 in §4.2). Exec-time means/truncations and the GP
distribution are taken from the paper verbatim; the resource-demand
parameters are our documented choices (configs/cluster.py).
"""
from __future__ import annotations

import numpy as np

from repro.configs.cluster import ClassDists, SimConfig, TruncNormal, WorkloadSpec
from repro.core.types import JobSet


def sample_trunc_normal(rng: np.random.Generator, d: TruncNormal,
                        size: int) -> np.ndarray:
    """Resampling-based truncated normal (the paper truncates a fit)."""
    out = rng.normal(d.mean, d.std, size)
    bad = (out < d.lo) | (out > d.hi)
    # resample the tails a few times, then clip the stragglers
    for _ in range(8):
        if not bad.any():
            break
        out[bad] = rng.normal(d.mean, d.std, int(bad.sum()))
        bad = (out < d.lo) | (out > d.hi)
    return np.clip(out, d.lo, d.hi)


def snap(x: np.ndarray, quanta) -> np.ndarray:
    """Snap each value to the nearest allocation quantum."""
    q = np.asarray(quanta)
    return q[np.argmin(np.abs(x[:, None] - q[None, :]), axis=1)]


def sample_gang_widths(rng: np.random.Generator, wl: WorkloadSpec,
                       n: int) -> np.ndarray:
    """Gang widths for ``n`` jobs; the one sampler every generator uses
    (rng stream untouched when ``multi_node_frac == 0``)."""
    n_nodes = np.ones(n, np.int64)
    if wl.multi_node_frac > 0:
        gang = rng.random(n) < wl.multi_node_frac
        n_nodes[gang] = rng.choice(wl.multi_node_widths, int(gang.sum()))
    return n_nodes


def sample_class(rng: np.random.Generator, dists: ClassDists, n: int,
                 gpu_quanta=(0.0, 1.0, 2.0, 4.0, 8.0)):
    exec_min = np.maximum(sample_trunc_normal(rng, dists.exec_min, n), 1.0)
    cpu = np.round(sample_trunc_normal(rng, dists.cpu, n))
    # whole GBs: keeps resource arithmetic exact in f32 (JAX engine parity)
    ram = np.round(sample_trunc_normal(rng, dists.ram, n))
    gpu = snap(sample_trunc_normal(rng, dists.gpu, n), gpu_quanta)
    demand = np.stack([np.maximum(cpu, 1.0), np.maximum(ram, 1.0),
                       np.maximum(gpu, 0.0)], axis=1)
    return np.round(exec_min).astype(np.int64), demand


def cluster_fraction(demand: np.ndarray, cluster_cap: np.ndarray
                     ) -> np.ndarray:
    """Mean of the three normalized resources — the load norm (DESIGN §3)."""
    return (demand / cluster_cap[None, :]).mean(axis=1)


def generate(cfg: SimConfig, seed: int = None) -> JobSet:
    wl: WorkloadSpec = cfg.workload
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    n = wl.n_jobs
    is_te = rng.random(n) < wl.te_fraction

    exec_total = np.zeros(n, np.int64)
    demand = np.zeros((n, 3))
    n_te = int(is_te.sum())
    exec_total[is_te], demand[is_te] = sample_class(
        rng, wl.te, n_te, wl.gpu_quanta)
    exec_total[~is_te], demand[~is_te] = sample_class(
        rng, wl.be, n - n_te, wl.gpu_quanta)

    gp = np.round(sample_trunc_normal(rng, wl.scaled_gp(), n)).astype(np.int64)

    n_nodes = sample_gang_widths(rng, wl, n)

    node_cap = np.asarray(cfg.cluster.node.as_tuple())
    js = JobSet(submit=np.zeros(n, np.int64), exec_total=exec_total,
                demand=demand, is_te=is_te, gp=gp, n_nodes=n_nodes)
    js.submit = closed_loop_submit_times(cfg, js)
    js.validate(node_cap)
    return js


def closed_loop_submit_times(cfg: SimConfig, js: JobSet) -> np.ndarray:
    """Paper §4.2: jobs are submitted "at such a rate that the cluster
    load ... would be kept at 2.0 if they were scheduled by FIFO".

    We realize this as closed-loop admission: run a FIFO simulation that
    admits the next job whenever the backlog (cluster-normalized demand
    of admitted, unfinished jobs) drops below ``load``; the recorded
    admit times become the open-loop submit times used by EVERY policy.
    (An open-loop Poisson rate at load>1 would grow the queue without
    bound, contradicting the paper's bounded slowdowns — see DESIGN §3.)
    The streamed twin — same admit times, bit for bit, in bounded
    memory — is ``core/stream/admission.py``.
    """
    from repro.core.simulator import Simulator
    import dataclasses
    fifo_cfg = dataclasses.replace(cfg, policy="fifo")
    sim = Simulator(fifo_cfg, js, admission_target=cfg.workload.load)
    sim.run()
    bad = np.flatnonzero(sim.admit_time < 0)
    if bad.size:
        # a bare assert here is stripped under ``python -O``, silently
        # corrupting every downstream submit ordering — fail loudly
        raise ValueError(
            f"closed-loop admission left job {int(bad[0])} with a "
            f"negative admit time ({bad.size} of {js.n} jobs "
            "unadmitted) — FIFO admission simulation ended early")
    return sim.admit_time.copy()


# backward-compatible alias (pre-PR-9 private name)
_closed_loop_submit_times = closed_loop_submit_times


def generate_trace_proxy(cfg: SimConfig, seed: int = None) -> JobSet:
    """Heavy-tailed proxy for the private PFN trace (§4.4).

    Log-normal execution times (median TE 4', BE 20', long tails to the
    truncation caps) + bursty arrivals (exponential gaps modulated by a
    slow on/off cycle). Reproduces the §4.4 regime where FIFO slowdowns
    explode and preemptive re-ordering can *help* BE jobs.
    """
    wl = cfg.workload
    rng = np.random.default_rng((cfg.seed if seed is None else seed) + 7919)
    n = wl.n_jobs
    is_te = rng.random(n) < wl.te_fraction

    def lognorm(median, sigma, lo, hi, size):
        x = np.exp(np.log(median) + sigma * rng.standard_normal(size))
        return np.clip(x, lo, hi)

    exec_total = np.where(
        is_te,
        lognorm(4.0, 1.0, 1.0, wl.te.exec_min.hi, n),
        lognorm(20.0, 1.6, 3.0, wl.be.exec_min.hi, n)).astype(np.int64)
    exec_total = np.maximum(exec_total, 1)

    demand = np.zeros((n, 3))
    n_te = int(is_te.sum())
    _, demand[is_te] = sample_class(rng, wl.te, n_te, wl.gpu_quanta)
    _, demand[~is_te] = sample_class(rng, wl.be, n - n_te, wl.gpu_quanta)

    gp = np.round(sample_trunc_normal(rng, wl.scaled_gp(), n)).astype(np.int64)

    # gang widths sampled exactly as ``generate`` does (shared sampler;
    # its guard keeps the rng stream — and thus every existing
    # single-node trace proxy — byte-identical when multi_node_frac == 0)
    n_nodes = sample_gang_widths(rng, wl, n)

    node_cap = np.asarray(cfg.cluster.node.as_tuple())
    cluster_cap = node_cap * cfg.cluster.n_nodes
    work = exec_total * cluster_fraction(demand, cluster_cap) * n_nodes
    lam = wl.load / work.mean()
    # bursty arrivals: rate doubles during "day", halves during "night"
    gaps = rng.exponential(1.0 / lam, n)
    phase = np.sin(np.arange(n) / 2048.0 * 2 * np.pi)
    gaps = gaps * np.where(phase > 0, 0.5, 2.0)
    submit = np.floor(np.cumsum(gaps)).astype(np.int64)

    js = JobSet(submit=submit, exec_total=exec_total, demand=demand,
                is_te=is_te, gp=gp, n_nodes=n_nodes)
    js.validate(node_cap)
    return js


def stream_rate(cfg: SimConfig, seed: int = None,
                probe_n: int = 2048) -> float:
    """Open-loop arrival rate (jobs / minute) for the streamed
    synthetic generator: FIFO-normalized load ``wl.load`` over the
    EXPECTED per-job work, estimated from a fixed-size probe sample
    drawn from its own rng stream — deterministic given the seed and
    independent of both the total job count and the chunk size (so
    chunked and materialized streams agree exactly)."""
    wl = cfg.workload
    rng = np.random.default_rng(((cfg.seed if seed is None else seed),
                                 0xA11))
    is_te = rng.random(probe_n) < wl.te_fraction
    n_te = int(is_te.sum())
    exec_total = np.zeros(probe_n, np.int64)
    demand = np.zeros((probe_n, 3))
    exec_total[is_te], demand[is_te] = sample_class(
        rng, wl.te, n_te, wl.gpu_quanta)
    exec_total[~is_te], demand[~is_te] = sample_class(
        rng, wl.be, probe_n - n_te, wl.gpu_quanta)
    n_nodes = sample_gang_widths(rng, wl, probe_n)
    cluster_cap = (np.asarray(cfg.cluster.node.as_tuple())
                   * cfg.cluster.n_nodes)
    work = exec_total * cluster_fraction(demand, cluster_cap) * n_nodes
    return wl.load / float(work.mean())


def stream_chunks(cfg: SimConfig, n_jobs: int = None, chunk: int = 1024,
                  seed: int = None):
    """Chunked, seeded synthetic job stream (DESIGN.md §10): yields
    submit-sorted ``JobSet`` chunks totalling ``n_jobs`` jobs, O(chunk)
    memory. Chunk ``k`` is drawn entirely from
    ``default_rng((seed, k))`` and the arrival clock is the ONLY state
    carried between chunks — so concatenating the chunks IS the
    monolithic equivalent of the stream (the streaming engine's
    parity-window tests and ``stream.materialize`` rely on this), and
    any chunk is reproducible without generating its prefix.

    Arrivals are open-loop (exponential gaps at the :func:`stream_rate`
    rate, the §4.4 trace-proxy model). For the paper's §4.2 closed-loop
    admission, wrap this stream in
    ``core/stream/admission.ClosedLoopAdmission`` (which discards these
    submit times and re-stamps admit ticks from its incremental FIFO
    backlog simulation — bit-exact with
    :func:`closed_loop_submit_times`). Class/GP/width sampling matches
    :func:`generate`'s samplers per chunk."""
    wl = cfg.workload
    seed = cfg.seed if seed is None else seed
    n_total = int(wl.n_jobs if n_jobs is None else n_jobs)
    lam = stream_rate(cfg, seed)
    clock = 0.0
    start, k = 0, 0
    while start < n_total:
        n = min(int(chunk), n_total - start)
        rng = np.random.default_rng((seed, k))
        is_te = rng.random(n) < wl.te_fraction
        n_te = int(is_te.sum())
        exec_total = np.zeros(n, np.int64)
        demand = np.zeros((n, 3))
        exec_total[is_te], demand[is_te] = sample_class(
            rng, wl.te, n_te, wl.gpu_quanta)
        exec_total[~is_te], demand[~is_te] = sample_class(
            rng, wl.be, n - n_te, wl.gpu_quanta)
        gp = np.round(sample_trunc_normal(
            rng, wl.scaled_gp(), n)).astype(np.int64)
        n_nodes = sample_gang_widths(rng, wl, n)
        at = clock + np.cumsum(rng.exponential(1.0 / lam, n))
        clock = float(at[-1])
        yield JobSet(submit=np.floor(at).astype(np.int64),
                     exec_total=exec_total, demand=demand,
                     is_te=is_te, gp=gp, n_nodes=n_nodes)
        start += n
        k += 1


def sparse_long_horizon(n: int = 512, seed: int = 0,
                        gap_mean: float = 180.0) -> JobSet:
    """Trickle arrivals (exponential gaps, mean ``gap_mean`` minutes)
    with heavy-tailed executions: the regime where an O(makespan) tick
    loop wastes almost every iteration. Shared by the engine benchmark
    and the event-vs-tick parity tests (DESIGN.md §4)."""
    rng = np.random.default_rng(seed)
    submit = np.cumsum(rng.exponential(gap_mean, n).astype(np.int64))
    is_te = rng.random(n) < 0.3
    exec_total = np.maximum(
        rng.lognormal(np.log(60), 1.2, n).astype(np.int64), 1)
    exec_total = np.minimum(exec_total, 1440)
    exec_total[is_te] = np.minimum(exec_total[is_te], 30)
    demand = np.stack([
        np.clip(np.round(rng.normal(8, 6, n)), 1, 32),
        np.clip(np.round(rng.normal(48, 48, n)), 1, 256),
        rng.choice([0.0, 1.0, 2.0, 4.0, 8.0], n)], axis=1)
    gp = np.round(np.clip(rng.normal(3, 3, n), 0, 20)).astype(np.int64)
    return JobSet(submit=submit, exec_total=exec_total, demand=demand,
                  is_te=is_te, gp=gp)
