"""The FitGpp scheduler as a pure-JAX module.

Fixed-capacity struct-of-arrays state, ``lax.while_loop`` tick loop,
bounded inner while-loops for the schedule-until-blocked phases, and
vectorized Eq. 1-4 victim selection (masked argmin). ``jit``-able and
``vmap``-able over trials, which is what lets the sensitivity sweeps
(Figs. 4-7) distribute over the production mesh with ``shard_map``
(see core/sweep.py).

Parity: semantics mirror ``core/simulator.py`` tick-for-tick for the
deterministic policies (fifo / lrtp / srtp / the score policies'
main path); the random fallback and RAND use a jax PRNG and are
excluded from exact parity (property-tested statistically instead).

Gang (multi-node) jobs: placement state is an ``(n_jobs, n_nodes)``
boolean assignment mask (``State.assign``) instead of a scalar node
index, and every job carries its gang width (``Jobs.width``).
Placement is all-or-nothing first-fit — the first ``width`` nodes
whose free vector covers the PER-NODE demand — the vectorized mirror
of ``engine/placement.ClusterState.fits_job``. Victims vacate and
requeue all their nodes at once, Eq. 2 is evaluated against a
multi-node victim's BEST node (the ``engine/preemption.
best_victim_node`` reduction), and a blocked gang TE selects victims
with the ``engine/preemption.gang_select`` strategy: the min-score
single victim whose eviction alone frees enough nodes, falling back
to accumulation in policy order (and signalling nothing when even
preempting everyone would not suffice).

Victim selection is registry-dispatched (``core/policy_registry.py``,
DESIGN.md §6): ``make_tick`` builds its preemption trigger from the
registered policy's JAX declaration — ``jax_kind == "rank"`` policies
feed :func:`_until_fits_select`, ``"score"`` policies feed
:func:`_score_select` (Eq. 4 masked argmin + the paper's random
fallback), and score policies may route the pass through an
accelerated kernel via ``SimConfig.score_backend`` (``"pallas"`` is
the fused ``kernels/schedule_step`` pass: Eq. 3 scoring, best-node
Eq. 2 reduction, Eq. 4 argmin, gang-fit tiles and the BE backfill
scan in ONE kernel over the (jobs, nodes) tile; parity-tested vs
jnp). Gang TEs dispatch to :func:`_gang_select` on either contract.

The schedule pass itself is computed once per acting tick as a shared
:class:`_Pass` (``_make_queue_pass`` — the jnp twin of the fused
kernel's per-pass outputs) and threaded through the TE lane, the BE
lane and the post-pass trigger gate (``_make_gate``), so the
``while_loop`` body issues one fused tile computation instead of a
kernel-per-op chain; non-acting ticks are gated by the cheap cached
:func:`_make_would_act_cached` check and skip the pass entirely.

The BE queue is strict FIFO (head-of-line blocking) by default;
``SimConfig.backfill`` enables the same bounded first-fit backfill
scan as the reference ``SchedulerCore.schedule`` (skip up to
``backfill_depth`` blocked jobs per pass, FIFO order otherwise).

Time advancement (``SimConfig.time_mode``, DESIGN.md §7): the default
``"event"`` mode compresses runs of provably no-op ticks inside the
jitted ``while_loop`` — after a tick whose schedule pass could not act,
the body jumps ``dt`` quanta straight to the next event (the masked
minimum over the next valid arrival, ``t + remaining`` of running
jobs and ``t + grace_left`` of GRACE jobs), bulk-decrementing
``remaining``/``grace_left`` by the same ``dt``. The jump is gated by
:func:`_make_would_act_cached` — the vectorized mirror of the
reference engine's ``SchedulerCore.schedule_would_act``, gang fits
and the backfill scan included (on acting ticks the gate value is the
exit evaluation of the shared pass, not a recomputation) — so any
tick on which the policy would be (re-)invoked still executes and the
rng stream, every metric timestamp and the full State agree
bit-for-bit with ``"tick"`` mode at every event boundary. When the
queue is empty (``_Cache.n_queued == 0``) no finisher can trigger a
pass, so one iteration drain-jumps straight to the next arrival or
vacate and bulk-retires every job finishing in between — k
consecutive events per ``while_loop`` iteration. All of it is plain array math, so under
``vmap`` the jump ``dt`` is per-lane: ragged sentinel-padded batches
and heterogeneous per-trial horizons each fast-forward at their own
pace.
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cluster import SimConfig
from repro.core import policy_registry
from repro.core.engine.placement import FIT_EPS
from repro.core.types import JobSet
from repro.obs import ring as obs_ring
from repro.obs import schema as obs_schema

NOT_ARRIVED, QUEUED, RUNNING, GRACE, DONE = 0, 1, 2, 3, 4
_INF = jnp.inf
_EPS = FIT_EPS    # one epsilon for every fit check, engine-wide


class Jobs(NamedTuple):
    """Static workload arrays (device-resident).

    ``demand`` is PER NODE; ``width`` is the gang width (1 for the
    paper's single-task jobs) and the job needs ``width`` nodes
    simultaneously (all-or-nothing gang placement).

    ``valid`` marks real jobs; False rows are sentinel padding added by
    ``sweep.stack_jobsets`` so jobsets of unequal ``n`` can share one
    vmapped batch. Sentinels are born DONE (``init_state``) — they never
    arrive, queue, run or get preempted — and are masked out of every
    percentile/mean in ``sweep`` and ``result_summary``, so a padded
    trial is bit-identical to its unpadded run (DESIGN.md §5).
    Sentinels keep ``width == 1``.
    """
    submit: jax.Array        # (N,) i32
    exec_total: jax.Array    # (N,) i32
    demand: jax.Array        # (N, 3) f32, per node
    is_te: jax.Array         # (N,) bool
    gp: jax.Array            # (N,) i32
    width: jax.Array         # (N,) i32 gang width (>= 1)
    valid: jax.Array         # (N,) bool
    # (N,) f32 GLOBAL arrival-order key, or None (the default). None
    # means row index == arrival order (every monolithic jobset: rows
    # are submit-sorted) and the engine keys queues by ``arange(N)``.
    # The streaming engine (core/stream/) recycles slots, so slot
    # index no longer encodes arrival order; it stamps each packed
    # job's global sequence number here and every order-sensitive
    # site — arrival queue keys, vacate requeue ranks, victim-pick
    # tie-breaks — keys on ``akey`` instead, which is what makes a
    # slot-recycled run bit-identical to the monolithic one
    # (DESIGN.md §10). f32 is exact for sequence numbers < 2^24.
    akey: jax.Array = None


class State(NamedTuple):
    t: jax.Array
    state: jax.Array         # (N,) i32
    remaining: jax.Array     # (N,) i32
    assign: jax.Array        # (N, n_nodes) bool placement mask
    preempt_count: jax.Array
    grace_left: jax.Array
    queue_key: jax.Array     # (N,) f32, +inf when not queued
    top_key: jax.Array       # () f32
    finish: jax.Array
    te_pending: jax.Array
    victim_of: jax.Array
    free: jax.Array          # (nodes, 3) f32
    pending_free: jax.Array
    last_signal: jax.Array   # (N,) i32 metrics
    last_vacate: jax.Array
    last_resume: jax.Array
    awaiting_resume: jax.Array   # (N,) bool
    n_done: jax.Array
    rng: jax.Array
    # () i32: victim selections that fell back past the main masked
    # path (score policies' random fallback, rank/gang selections'
    # over-P-cap last resort). Observability for the invariant suite:
    # when 0, the paper's P cap is exact — sum(max(preempt_count - P,
    # 0)) never exceeds this counter.
    fallback_count: jax.Array
    # In-jit event ring buffer (obs/ring.py layout): (capacity + 1,
    # 4 + n_words) i32 rows [t, code, job, aux, node words...]; the
    # extra row is the dump slot for masked/overflowing writes,
    # re-zeroed after every append. ``ev_n`` counts rows EMITTED
    # (monotonic; overflow = max(0, ev_n - capacity)). With tracing
    # off both are zero-size/zero and every append site is compiled
    # out (the ``trace`` flag is Python-static).
    ev_buf: jax.Array        # (cap+1, 4+W) i32
    ev_n: jax.Array          # () i32


def jobs_from_jobset(js: JobSet) -> Jobs:
    return Jobs(
        submit=jnp.asarray(js.submit, jnp.int32),
        exec_total=jnp.asarray(js.exec_total, jnp.int32),
        demand=jnp.asarray(js.demand, jnp.float32),
        is_te=jnp.asarray(js.is_te, bool),
        gp=jnp.asarray(js.gp, jnp.int32),
        width=jnp.asarray(js.n_nodes, jnp.int32),
        valid=jnp.ones(len(js.submit), bool),
    )


def init_state(jobs: Jobs, n_nodes: int, node_cap, seed,
               trace_capacity: int = 0) -> State:
    N = jobs.submit.shape[0]
    cap = jnp.asarray(node_cap, jnp.float32)
    tcap = int(trace_capacity)
    ev_shape = ((tcap + 1, obs_ring.HEADER_WORDS
                 + obs_ring.n_node_words(n_nodes))
                if tcap > 0 else (0, 0))
    return State(
        t=jnp.zeros((), jnp.int32),
        # sentinel (padding) jobs are born DONE: never arrive, never run
        state=jnp.where(jobs.valid, NOT_ARRIVED, DONE).astype(jnp.int32),
        # forced copy: a no-op astype would ALIAS jobs.exec_total, so
        # any caller that donates (or mutates) State buffers would
        # corrupt the workload array under everyone else
        remaining=jnp.array(jobs.exec_total, jnp.int32),
        assign=jnp.zeros((N, n_nodes), bool),
        preempt_count=jnp.zeros((N,), jnp.int32),
        grace_left=jnp.zeros((N,), jnp.int32),
        queue_key=jnp.full((N,), _INF, jnp.float32),
        top_key=jnp.asarray(-1.0, jnp.float32),
        finish=jnp.full((N,), -1, jnp.int32),
        te_pending=jnp.zeros((N,), jnp.int32),
        victim_of=jnp.full((N,), -1, jnp.int32),
        free=jnp.tile(cap[None, :], (n_nodes, 1)),
        pending_free=jnp.zeros((n_nodes, 3), jnp.float32),
        last_signal=jnp.full((N,), -1, jnp.int32),
        last_vacate=jnp.full((N,), -1, jnp.int32),
        last_resume=jnp.full((N,), -1, jnp.int32),
        awaiting_resume=jnp.zeros((N,), bool),
        n_done=jnp.sum(~jobs.valid).astype(jnp.int32),
        rng=seed if (isinstance(seed, jax.Array)
                     and jnp.issubdtype(seed.dtype, jax.dtypes.prng_key))
        else jax.random.key(seed),
        fallback_count=jnp.zeros((), jnp.int32),
        ev_buf=jnp.zeros(ev_shape, jnp.int32),
        ev_n=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# event cache — exact scalars derived from State, threaded as a loop
# carry so the hot path can gate whole phases on O(1) comparisons
# ---------------------------------------------------------------------------

_BIG = 1 << 30   # "no event pending" sentinel (i32-safe)


class _Cache(NamedTuple):
    """Exact next-event scalars, a pure function of ``(jobs, State)``
    (``_cache_from_state``) threaded alongside State through the tick
    loop so maintaining it costs nothing on no-op ticks:

      * ``next_arrival`` — absolute tick of the earliest NOT_ARRIVED
        submit (``_BIG`` when none); recomputed only when an arrival
        fires.
      * ``next_vacate`` — absolute tick of the earliest grace expiry
        (``_BIG`` when none — i.e. exactly when no job is in GRACE,
        since GRACE jobs leave only by vacating); recomputed after
        vacates and after every acting schedule pass.
      * ``n_q_te`` — queued-TE count; TEs enter the queue only at
        arrival (victims are always BE) and leave it only in the
        schedule pass, so those two sites keep it exact.
      * ``n_queued`` — total queued count (BE + TE); jobs queue at
        arrival and at vacate, and leave the queue only in the
        schedule pass. ``n_queued == 0`` means ``would_act`` is False
        no matter what finishes — the gate for the bulk finish drain
        in the event jump.

    Because every field is derivable from State, the cache is purely an
    optimization: ``make_tick`` rebuilds it per call and parity is
    untouched."""
    next_arrival: jax.Array   # () i32
    next_vacate: jax.Array    # () i32
    n_q_te: jax.Array         # () i32
    n_queued: jax.Array       # () i32


def _cache_from_state(jobs: Jobs, st: State,
                      ext_arrival=None) -> _Cache:
    """``ext_arrival`` (absolute tick or None) is the submit time of
    the earliest job NOT in this pool — the streaming engine's round
    boundary. Folding it into ``next_arrival`` at every recompute site
    is what keeps the event jump (the empty-queue drain branch
    especially) from overshooting the boundary: the jump lands ON the
    external arrival's tick exactly as the monolithic engine would."""
    in_grace = st.state == GRACE
    queued = st.state == QUEUED
    nxt = jnp.min(jnp.where(st.state == NOT_ARRIVED,
                            jobs.submit, _BIG)).astype(jnp.int32)
    if ext_arrival is not None:
        nxt = jnp.minimum(nxt, jnp.asarray(ext_arrival, jnp.int32))
    return _Cache(
        next_arrival=nxt,
        next_vacate=jnp.where(
            in_grace.any(),
            st.t + jnp.min(jnp.where(in_grace, st.grace_left, _BIG)),
            _BIG).astype(jnp.int32),
        n_q_te=jnp.sum(queued & jobs.is_te).astype(jnp.int32),
        n_queued=jnp.sum(queued).astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def _gang_fit(free: jax.Array, d: jax.Array, w: jax.Array):
    """All-or-nothing first fit: (ok, node mask of the FIRST ``w``
    nodes whose free vector covers the per-node demand ``d``). The
    vectorized mirror of ``ClusterState.fits_job``; ``w == 1`` is
    plain first-fit. The mask is all-False when the gang does not fit."""
    fits = jnp.all(free >= d[None, :] - _EPS, axis=1)
    ok = jnp.sum(fits) >= w
    mask = fits & (jnp.cumsum(fits) <= w) & ok
    return ok, mask


def _gang_fits(free: jax.Array, demand: jax.Array,
               width: jax.Array) -> jax.Array:
    """Per-job gang feasibility: (N,) bool, True where at least
    ``width[j]`` nodes of ``free`` each cover ``demand[j]`` (the
    vectorized form of ``_gang_fit(...)[0]`` over every job at once)."""
    return _fit_counts(free, demand) >= width


def _fit_counts(free: jax.Array, demand: jax.Array) -> jax.Array:
    """Per-job count of nodes whose free vector covers the per-node
    demand: (N,) i32. ``_gang_fits`` is ``counts >= width``; the fused
    schedule_step kernel computes the same reduction in-tile."""
    fits = jnp.all(free[None, :, :] >= demand[:, None, :] - _EPS, axis=2)
    return jnp.sum(fits, axis=1).astype(jnp.int32)


def _best_victim_node(free: jax.Array, assign: jax.Array,
                      demand: jax.Array, te_d: jax.Array):
    """Eq. 2 glue (``engine/preemption.best_victim_node``): for every
    job, the min-slack of ``free + own demand - te_demand`` per node
    masked to the job's assigned nodes, and the argmax node — the node
    a multi-node victim is evaluated (and accounted) against. Rows
    with no assignment get ``-inf`` slack (never eligible)."""
    slack = jnp.min(free[None, :, :] + demand[:, None, :]
                    - te_d[None, None, :], axis=2)          # (N, nodes)
    slack = jnp.where(assign, slack, -_INF)
    return jnp.max(slack, axis=1), jnp.argmax(slack, axis=1)


def _onehot(N: int, j: jax.Array) -> jax.Array:
    return jnp.arange(N) == j


def _argmin_key(mask: jax.Array, val, akey) -> jax.Array:
    """Masked argmin with GLOBAL-ORDER tie-breaking: among tied
    minima, the smallest ``akey`` (arrival order) wins. With ``akey``
    None — every monolithic jobset, where row index IS arrival order —
    this is plain ``jnp.argmin`` (first minimum), byte-identical to
    the engine's historical behavior. The streaming engine's recycled
    pools set ``akey``, where first-slot ties would otherwise depend
    on which slot a job happened to land in."""
    if akey is None:
        return jnp.argmin(jnp.where(mask, val, _INF)).astype(jnp.int32)
    best = jnp.min(jnp.where(mask, val, _INF))
    tied = mask & (val == best)
    return jnp.argmin(jnp.where(tied, akey, _INF)).astype(jnp.int32)


def _argmax_key(mask: jax.Array, val, akey) -> jax.Array:
    """Masked argmax twin of :func:`_argmin_key` (ties -> min akey)."""
    if akey is None:
        return jnp.argmax(jnp.where(mask, val, -_INF)).astype(jnp.int32)
    best = jnp.max(jnp.where(mask, val, -_INF))
    tied = mask & (val == best)
    return jnp.argmin(jnp.where(tied, akey, _INF)).astype(jnp.int32)


def _gang_release(assign: jax.Array, demand: jax.Array,
                  mask: jax.Array) -> jax.Array:
    """Summed per-node demand of the ``mask``-selected jobs over their
    assigned nodes: (nodes, 3). One matmul replaces the scalar-node
    scatter-add (exact for the integer/quantized demands)."""
    sel = (assign & mask[:, None]).astype(demand.dtype)
    return sel.T @ demand


# -- in-jit event tracing (obs/ring.py layout; DESIGN.md §8) ----------------

class _TraceCtx(NamedTuple):
    """Static per-build trace context: the node-mask packing weights
    (``obs.ring.node_mask_weights``) as a device constant. ``None``
    everywhere a trace context is accepted means tracing is off and
    the emission code is not built at all."""
    weights: jax.Array       # (n_words, n_nodes) uint32


def _trace_ctx(n_nodes: int) -> _TraceCtx:
    return _TraceCtx(
        weights=jnp.asarray(obs_ring.node_mask_weights(n_nodes)))


def _ev_rows(tc: _TraceCtx, t, code, job, aux=None,
             nodes=None) -> jax.Array:
    """Build (K, 4+W) i32 event rows from broadcastable parts. ``job``
    fixes K; ``nodes`` is an optional (K, n_nodes) bool placement mask
    packed 32 nodes per little-endian word."""
    job = jnp.asarray(job, jnp.int32)
    K = job.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t).astype(jnp.int32), (K,))
    code = jnp.broadcast_to(jnp.asarray(code, jnp.int32), (K,))
    aux = (jnp.full((K,), -1, jnp.int32) if aux is None
           else jnp.broadcast_to(jnp.asarray(aux).astype(jnp.int32), (K,)))
    if nodes is None:
        words = jnp.zeros((K, tc.weights.shape[0]), jnp.int32)
    else:
        packed = jnp.sum(jnp.where(nodes[:, None, :],
                                   tc.weights[None, :, :],
                                   jnp.uint32(0)), axis=2)
        words = jax.lax.bitcast_convert_type(packed, jnp.int32)
    return jnp.concatenate(
        [jnp.stack([t, code, job, aux], axis=1), words], axis=1)


def _ev_append(st: State, rows: jax.Array, mask: jax.Array) -> State:
    """Append ``rows[i]`` where ``mask[i]``, preserving row order.
    Masked-out and past-capacity rows scatter into the dump row (index
    ``capacity``), which is re-zeroed afterwards so the buffer stays a
    pure function of the emitted stream (bitwise tick/event parity
    covers the trace). ``ev_n`` counts every emitted row, dropped or
    not — the overflow signal."""
    dump = st.ev_buf.shape[0] - 1
    m = mask.astype(jnp.int32)
    idx = jnp.where(mask, st.ev_n + jnp.cumsum(m) - 1, dump)
    buf = st.ev_buf.at[jnp.minimum(idx, dump)].set(rows)
    buf = buf.at[dump].set(jnp.zeros((buf.shape[1],), jnp.int32))
    return st._replace(ev_buf=buf, ev_n=st.ev_n + jnp.sum(m))


def _ev1(st: State, tc: _TraceCtx, t, code, job, aux=None, nodes=None,
         cond=None) -> State:
    """Append one event row (optionally gated by the traced ``cond``).
    The unconditional case — every row the emission loops produce —
    skips ``_ev_append``'s masked-compaction machinery: one clamped
    scatter, with the row zeroed at capacity so the dump row needs no
    re-zeroing pass (same pure-function-of-the-stream buffer)."""
    row = _ev_rows(tc, t, code, jnp.reshape(job, (1,)), aux=aux,
                   nodes=None if nodes is None
                   else jnp.reshape(nodes, (1, -1)))
    if cond is not None:
        return _ev_append(st, row, jnp.reshape(cond, (1,)))
    dump = st.ev_buf.shape[0] - 1
    keep = st.ev_n < dump
    buf = st.ev_buf.at[jnp.minimum(st.ev_n, dump)].set(
        jnp.where(keep, row[0], 0))
    return st._replace(ev_buf=buf, ev_n=st.ev_n + 1)


def _ev_scan(st: State, tc: _TraceCtx, t, code, mask) -> State:
    """Append one ``code`` row per set ``mask`` bit, ascending job
    index. A bounded loop of single-row appends: a firing tick pays
    O(k) emitted rows, not an N-row scatter — the batch scatters
    otherwise dominate traced-run cost on arrival-heavy workloads
    (their cost is O(N) per firing tick, O(N^2) over a run whose
    firing ticks scale with N)."""
    def body(carry):
        st, m = carry
        j = jnp.argmax(m).astype(jnp.int32)
        return _ev1(st, tc, t, code, j), m.at[j].set(False)

    st, _ = jax.lax.while_loop(lambda c: c[1].any(), body, (st, mask))
    return st


def _place(st: State, jobs: Jobs, j: jax.Array, nodes: jax.Array,
           tc: _TraceCtx = None) -> State:
    """Start job j on the ``nodes`` mask (assumes the gang fits).
    Scatter (row-indexed) updates, not full-array wheres — this runs
    once per placement inside the schedule while-loops, so it must not
    pay O(N) per job started."""
    resumed = st.awaiting_resume[j]
    if tc is not None:
        st = _ev1(st, tc, st.t,
                  jnp.where(resumed, obs_schema.RESUME, obs_schema.START),
                  j, nodes=nodes)
    return st._replace(
        state=st.state.at[j].set(RUNNING),
        assign=st.assign.at[j].set(nodes),
        queue_key=st.queue_key.at[j].set(_INF),
        free=st.free - jobs.demand[j][None, :]
        * nodes[:, None].astype(jnp.float32),
        last_resume=st.last_resume.at[j].set(
            jnp.where(resumed, st.t, st.last_resume[j])),
        awaiting_resume=st.awaiting_resume.at[j].set(False),
    )


def _signal_one(st: State, jobs: Jobs, v: jax.Array, te: jax.Array,
                tc: _TraceCtx = None) -> State:
    """Signal preemption of running BE job v for TE job te (scalars).
    Gang victims promise / vacate ALL their nodes at once.

    GP == 0 vacates inline (same tick); GP > 0 enters grace and the
    victim's resources become "pending". Both branches are expressed as
    per-victim scatters selected by the scalar ``gp0`` — one row write
    per field instead of the old two-full-State ``tree.map`` select, so
    a signal costs O(nodes), not O(N)."""
    row = st.assign[v]
    gp0 = jobs.gp[v] == 0
    if tc is not None:
        # SIGNAL always; a GP=0 victim vacates and requeues inline
        # (no GRACE_EXPIRE — it never entered grace)
        v3 = jnp.stack([v, v, v])
        codes = jnp.asarray([obs_schema.PREEMPT_SIGNAL, obs_schema.VACATE,
                             obs_schema.REQUEUE], jnp.int32)
        aux3 = jnp.stack([te, te, jnp.int32(-1)])
        st = _ev_append(
            st, _ev_rows(tc, st.t, codes, v3, aux=aux3),
            jnp.stack([jnp.asarray(True), gp0, gp0]))
    d = jobs.demand[v][None, :] * row[:, None].astype(jnp.float32)
    zero = jnp.zeros_like(d)
    return st._replace(
        preempt_count=st.preempt_count.at[v].add(1),
        last_signal=st.last_signal.at[v].set(st.t),
        awaiting_resume=st.awaiting_resume.at[v].set(True),
        state=st.state.at[v].set(jnp.where(gp0, QUEUED, GRACE)),
        assign=st.assign.at[v].set(row & ~gp0),
        queue_key=st.queue_key.at[v].set(
            jnp.where(gp0, st.top_key, st.queue_key[v])),
        top_key=jnp.where(gp0, st.top_key - 1.0, st.top_key),
        free=st.free + jnp.where(gp0, d, zero),
        pending_free=st.pending_free + jnp.where(gp0, zero, d),
        last_vacate=st.last_vacate.at[v].set(
            jnp.where(gp0, st.t, st.last_vacate[v])),
        grace_left=st.grace_left.at[v].set(
            jnp.where(gp0, st.grace_left[v], jobs.gp[v])),
        victim_of=st.victim_of.at[v].set(
            jnp.where(gp0, st.victim_of[v], te)),
        te_pending=st.te_pending.at[te].add(
            jnp.where(gp0, 0, 1)),
    )


# ---------------------------------------------------------------------------
# victim selection (registry-dispatched; policies declare jax_rank/jax_score)
# ---------------------------------------------------------------------------

def _score_select(st: State, jobs: Jobs, te: jax.Array, pol, node_cap, s,
                  P, backend: str):
    """Generic score-policy selection -> (state with advanced rng, victim).

    The policy's ``jax_score`` gives per-job scores (lower = better
    victim); this applies Eq. 2 eligibility — evaluated against each
    victim's BEST node (``_best_victim_node``), so gang victims are
    judged where they have the most slack — the P cap and the Eq. 4
    masked argmin, with the paper's random-candidate fallback when no
    job passes the masks. ``backend != "jnp"`` fuses score, best-node
    reduction and masked argmin on the policy's registered accelerated
    kernel (``jax_score_accel``; returns -1 when nothing passes).
    """
    cand = (st.state == RUNNING) & ~jobs.is_te
    under = st.preempt_count < P
    if backend != "jnp":
        be_q = (st.state == QUEUED) & ~jobs.is_te
        main = pol.jax_score_accel(backend, jobs, te, st.free, st.assign,
                                   cand, under, node_cap, s,
                                   pending_free=st.pending_free,
                                   queue_key=st.queue_key, be_q=be_q)
        mask_any = main >= 0
    else:
        score = pol.jax_score(jobs, cand, node_cap, s)
        best_slack, _ = _best_victim_node(st.free, st.assign, jobs.demand,
                                          jobs.demand[te])
        elig = best_slack >= -_EPS
        mask = cand & elig & under
        main = _argmin_key(mask, score, jobs.akey)
        mask_any = mask.any()

    rng, sub = jax.random.split(st.rng)
    p = cand.astype(jnp.float32)
    p = p / jnp.maximum(p.sum(), 1.0)
    rnd = jax.random.choice(sub, jobs.submit.shape[0], p=p).astype(jnp.int32)
    st = st._replace(
        rng=rng,
        fallback_count=st.fallback_count + (~mask_any).astype(jnp.int32))
    return st, jnp.where(mask_any, main, rnd)


def _resolve_score_backend(cfg: SimConfig, spec, s) -> str:
    """Effective score backend: ``cfg.score_backend``. Accelerated
    backends need a static ``s`` (it is baked into the kernel), so
    traced-s sweeps — and policies without the backend — fall back to
    the jnp path silently. Any static Python number counts as static
    (an int ``s`` must not silently downgrade a requested kernel)."""
    if os.environ.get("REPRO_SIM_KERNEL") is not None:
        raise RuntimeError(
            "the REPRO_SIM_KERNEL env override was removed; select the "
            "accelerated score path with SimConfig(score_backend='pallas') "
            "(or --score-backend on the scenarios CLI) instead")
    backend = cfg.score_backend
    static_s = isinstance(s, (int, float)) and not isinstance(s, bool)
    if backend != "jnp" and (backend not in spec.score_backends
                             or not static_s):
        return "jnp"
    return backend


def _until_fits_select(st: State, jobs: Jobs, te: jax.Array, rank_val,
                       P, tc: _TraceCtx = None) -> State:
    """LRTP/RAND: keep signalling victims (best ``rank_val`` first,
    under-P-cap first) until the TE fits on the last victim's BEST
    node, counting the demand signalled there so far. Mirrors
    ``policies._preempt_until_fits`` over the invocation snapshot:
    victims are accounted at the node ``engine/preemption.
    best_victim_node`` would pick (their only node when single-node),
    chosen once from the free vectors at trigger time."""
    N = jobs.submit.shape[0]
    te_d = jobs.demand[te]
    n_nodes = st.free.shape[0]
    free0 = st.free                                # invocation snapshot
    _, best_node = _best_victim_node(free0, st.assign, jobs.demand, te_d)

    def cond(carry):
        st, taken, pending, satisfied = carry
        cand = (st.state == RUNNING) & ~jobs.is_te & ~taken
        return (~satisfied) & cand.any()

    def body(carry):
        st, taken, pending, _ = carry
        cand = (st.state == RUNNING) & ~jobs.is_te & ~taken
        under = st.preempt_count < P
        # under-cap candidates first, then by rank_val descending
        # (two-level pick, NOT an additive offset — a +1e12 offset in f32
        # would swallow rank_val and break the ordering)
        m1 = cand & under
        pick_from = jnp.where(m1.any(), m1, cand)
        v = _argmax_key(pick_from, rank_val, jobs.akey)
        node = best_node[v]
        st = st._replace(
            fallback_count=st.fallback_count + (~m1.any()).astype(jnp.int32))
        st = _signal_one(st, jobs, v, te, tc)
        # Accumulate each selection's demand at its best node and test
        # the TE there against the snapshot — mirrors
        # policies._preempt_until_fits (pending starts at free, adds
        # every victim regardless of GP; GP=0 inline vacates are part
        # of that same accounting).
        pending = pending.at[node].add(jobs.demand[v])
        satisfied = jnp.all(te_d <= free0[node] + pending[node] + _EPS)
        return st, taken | _onehot(N, v), pending, satisfied

    st, _, _, _ = jax.lax.while_loop(
        cond, body, (st, jnp.zeros((N,), bool),
                     jnp.zeros((n_nodes, 3), jnp.float32),
                     jnp.asarray(False)))
    return st


def _gang_select(st: State, jobs: Jobs, te: jax.Array, rank_val, P,
                 score=None, tc: _TraceCtx = None) -> State:
    """Multi-node TE: the vectorized mirror of
    ``engine/preemption.gang_select``. With ``score`` (Eq. 4-style
    argmin policies; LOWER = better victim, computed over TOTAL gang
    demand), prefer the min-score SINGLE victim whose eviction alone
    yields >= width satisfying nodes — restricted to under-P-cap
    candidates when any exist; otherwise accumulate victims in policy
    order (``rank_val`` HIGHER = preempt first, under-cap first) until
    the gang fits, and signal NOTHING when even preempting every
    candidate would not suffice (signalling then would burn preemption
    budget for no gain). Over-P-cap signals count into
    ``fallback_count`` (the P-cap invariant's allowance)."""
    N = jobs.submit.shape[0]
    te_d = jobs.demand[te]
    w = jobs.width[te]
    free0 = st.free
    cand0 = (st.state == RUNNING) & ~jobs.is_te
    under0 = st.preempt_count < P

    def n_fit(fr):
        return jnp.sum(jnp.all(fr >= te_d[None, :] - _EPS, axis=1))

    if score is not None:
        # single-eviction sufficiency: free + the victim's demand on
        # each of its nodes must yield >= width fitting nodes
        trial = free0[None, :, :] + jobs.demand[:, None, :] \
            * st.assign[:, :, None].astype(jnp.float32)
        nfit1 = jnp.sum(jnp.all(trial >= te_d[None, None, :] - _EPS,
                                axis=2), axis=1)
        pool = cand0 & jnp.where((cand0 & under0).any(), under0, True)
        single = pool & (nfit1 >= w)
        v1 = _argmin_key(single, score, jobs.akey)
        have_single = single.any()
    else:
        v1 = jnp.int32(0)
        have_single = jnp.asarray(False)

    # accumulation (pure — no signals until the whole set is known to
    # suffice): walk candidates in policy order, recording selection
    # sequence numbers, until >= width nodes fit the TE
    def acc_cond(carry):
        taken, pending, satisfied, nsel, seq = carry
        return (~satisfied) & (cand0 & ~taken).any()

    def acc_body(carry):
        taken, pending, satisfied, nsel, seq = carry
        c = cand0 & ~taken
        m1 = c & under0
        pick = jnp.where(m1.any(), m1, c)
        v = _argmax_key(pick, rank_val, jobs.akey)
        pending = pending + jobs.demand[v][None, :] \
            * st.assign[v][:, None].astype(jnp.float32)
        return (taken | _onehot(N, v), pending, n_fit(pending) >= w,
                nsel + 1, seq.at[v].set(nsel))

    taken, pending, satisfied, nsel, seq = jax.lax.while_loop(
        acc_cond, acc_body,
        (jnp.zeros((N,), bool), free0, n_fit(free0) >= w,
         jnp.int32(0), jnp.full((N,), -1, jnp.int32)))

    def signal_single(st):
        st = st._replace(fallback_count=st.fallback_count
                         + (~under0[v1]).astype(jnp.int32))
        return _signal_one(st, jobs, v1, te, tc)

    def signal_accum(st):
        n_sig = jnp.where(satisfied, nsel, 0)   # insufficient -> nothing

        def sig_cond(carry):
            return carry[1] < n_sig

        def sig_body(carry):
            st, k = carry
            v = jnp.argmax(seq == k).astype(jnp.int32)
            st = st._replace(fallback_count=st.fallback_count
                             + (~under0[v]).astype(jnp.int32))
            return _signal_one(st, jobs, v, te, tc), k + 1

        st, _ = jax.lax.while_loop(sig_cond, sig_body, (st, jnp.int32(0)))
        return st

    return jax.lax.cond(have_single, signal_single, signal_accum, st)


# ---------------------------------------------------------------------------
# event-compressed time advancement (SimConfig.time_mode, DESIGN.md §7)
# ---------------------------------------------------------------------------

class _Pass(NamedTuple):
    """One fused schedule-pass evaluation over the current State — the
    engine-side (TE-independent) half of the ``kernels/schedule_step``
    contract, computed ONCE per state version and shared by the
    would-act gate, the TE lane and the BE lane inside a single
    while-loop iteration (the TE-dependent half — Eq. 3 score, Eq. 2
    best-node reduction, Eq. 4 argmin — is per-trigger and lives in
    ``_score_select`` / the fused kernel)."""
    fits: jax.Array      # (N, M) bool : free covers demand, per node
    fit_now: jax.Array   # (N,)  i32  : row sums of ``fits``
    fit_pend: jax.Array  # (N,)  i32  : counts vs free + pending_free
    be_pick: jax.Array   # ()    i32  : BE job the lane would try next
    be_can: jax.Array    # ()    bool : the pick exists and fits
    nskip: jax.Array     # ()    i32  : non-fitting queued BE ahead of
    #                                   the pick (backfill scan budget)


def _make_queue_pass(jobs: Jobs, backfill: bool):
    """Build ``queue_pass(st, be_mask) -> _Pass``: the per-job fit
    tile against ``free`` (and, bitwise-gated on any pending residue,
    against ``free + pending_free`` — residue-exact mirror of the full
    promised-capacity evaluation), plus the BE queue scan over
    ``be_mask``. Without backfill the pick is the queue head
    (head-of-line blocking: ``be_can`` is False when the head does not
    fit); with backfill it is the first FITTING job in key order and
    ``nskip`` counts the non-fitting jobs ahead of it (the bounded
    scan depth the reference consumes before placing it)."""
    def queue_pass(st: State, be_mask: jax.Array) -> _Pass:
        fits_b = jnp.all(st.free[None, :, :]
                         >= jobs.demand[:, None, :] - _EPS, axis=2)
        fit_now = jnp.sum(fits_b, axis=1).astype(jnp.int32)
        fit_pend = jax.lax.cond(
            (st.pending_free != 0).any(),
            lambda: _fit_counts(st.free + st.pending_free, jobs.demand),
            lambda: fit_now)
        okj = fit_now >= jobs.width
        if not backfill:
            pick = jnp.argmin(jnp.where(be_mask, st.queue_key, _INF)) \
                .astype(jnp.int32)
            be_can = be_mask.any() & okj[pick]
            nskip = jnp.int32(0)
        else:
            mq = be_mask & okj
            be_can = mq.any()
            pick = jnp.argmin(jnp.where(mq, st.queue_key, _INF)) \
                .astype(jnp.int32)
            pick_key = jnp.where(be_can, st.queue_key[pick], _INF)
            nskip = jnp.sum(be_mask & ~okj
                            & (st.queue_key < pick_key)).astype(jnp.int32)
        return _Pass(fits_b, fit_now, fit_pend, pick, be_can, nskip)

    return queue_pass


def _make_gate(jobs: Jobs, preemptive: bool, backfill: bool = False,
               backfill_depth: int = 64):
    """Gate glue over a precomputed :class:`_Pass` — the same verdict
    as :func:`_make_would_act_cached`, for call sites that already
    hold a fresh pass (the schedule lanes' exit evaluation)."""
    N = jobs.submit.shape[0]
    depth = min(int(backfill_depth), N)

    def gate(st: State, ps: _Pass) -> jax.Array:
        act = ps.be_can if not backfill else ps.be_can & (ps.nskip < depth)
        if preemptive:
            te_q = (st.state == QUEUED) & jobs.is_te
            has_cand = ((st.state == RUNNING) & ~jobs.is_te).any()
            trigger = (st.te_pending == 0) & ~(ps.fit_pend >= jobs.width) \
                & has_cand
            act = act | (te_q & ((ps.fit_now >= jobs.width)
                                 | trigger)).any()
        return act

    return gate


def _make_would_act_cached(jobs: Jobs, preemptive: bool,
                           backfill: bool = False,
                           backfill_depth: int = 64):
    """Vectorized mirror of ``SchedulerCore.schedule_would_act``,
    taking the threaded ``_Cache`` so the common no-op evaluation is
    cheap —

      * the BE head check gathers ONE demand row and fits it against
        the free vectors (O(nodes)), instead of the full (jobs, nodes)
        feasibility tile;
      * the whole TE part (fit counts, trigger arming) sits behind an
        O(1) ``n_q_te > 0`` gate, and the pending-capacity recount
        behind a ``pending_free != 0`` gate (bitwise — residue-exact
        mirror of the full ``free + pending_free`` evaluation).

    True whenever a schedule pass on this State could start a job or
    (re-)invoke victim selection: a queued TE's gang fits, a queued
    TE's preemption trigger is armed (``te_pending == 0``, does not fit
    even counting ``pending_free``, running BE candidates exist), the
    BE head fits — or, under backfill, any of the first
    ``backfill_depth`` queued BE jobs (queue order) fits. Deliberately
    conservative in the same way as the reference: a fruitless policy
    invocation still counts, because RAND and the score policies'
    random fallback consume rng on every invocation — this is what
    keeps the event jump bit-exact for the stochastic paths too
    (DESIGN.md §4/§7).
    """
    N = jobs.submit.shape[0]
    depth = min(int(backfill_depth), N)

    def would_act(st: State, cache: _Cache) -> jax.Array:
        queued = st.state == QUEUED
        be_q = queued & ~jobs.is_te if preemptive else queued
        if not backfill:
            head = jnp.argmin(jnp.where(be_q, st.queue_key, _INF))
            ok_head = jnp.sum(jnp.all(
                st.free >= jobs.demand[head][None, :] - _EPS,
                axis=1)) >= jobs.width[head]
            act = be_q.any() & ok_head
        else:
            # the reference scan examines the first `depth` jobs in
            # queue order and acts iff any of them fits
            fits_all = _gang_fits(st.free, jobs.demand, jobs.width)
            order = jnp.argsort(jnp.where(be_q, st.queue_key, _INF))
            scan = order[:depth]
            act = (be_q[scan] & fits_all[scan]).any()
        if preemptive:
            def te_part():
                te_q = queued & jobs.is_te
                fits_now = _fit_counts(st.free, jobs.demand) >= jobs.width
                fits_pend = jax.lax.cond(
                    (st.pending_free != 0).any(),
                    lambda: _fit_counts(st.free + st.pending_free,
                                        jobs.demand) >= jobs.width,
                    lambda: fits_now)
                has_cand = ((st.state == RUNNING) & ~jobs.is_te).any()
                trigger = (st.te_pending == 0) & ~fits_pend & has_cand
                return (te_q & (fits_now | trigger)).any()

            act = act | jax.lax.cond(cache.n_q_te > 0, te_part,
                                     lambda: jnp.asarray(False))
        return act

    return would_act


def _make_step(cfg: SimConfig, jobs: Jobs, n_nodes: int,
               s=None, P=None, time_mode: str = None,
               max_ticks: int = 1 << 22, trace: bool = False,
               ext_arrival=None):
    """Build the ``(State, _Cache) -> (State, _Cache)`` while-loop
    body: one scheduling tick, plus — in ``"event"`` time mode — the
    event jump that compresses the following run of provably no-op
    ticks into a single ``dt`` step (bit-exact either way; see module
    docstring and DESIGN.md §7).

    Every phase is gated so a no-op tick touches as few arrays as
    possible: arrivals and vacates fire only when the cache says their
    event is due, the whole schedule pass sits behind one
    ``would_act`` evaluation (rng-safe — all rng draws live behind the
    preemption trigger, which ``would_act`` mirrors exactly), and the
    post-run jump re-evaluates ``would_act`` only when the tick acted
    or finished jobs (otherwise the pre-run value provably still
    holds: the run phase without finishers only decrements clocks).

    ``time_mode`` defaults to ``cfg.time_mode``; ``s`` and ``P`` may
    be traced scalars (for vmapped sweeps); ``max_ticks`` bounds the
    stall jump and must match the driving loop's bound. ``trace``
    (Python-static) builds the in-jit event emission — off, none of it
    exists in the compiled program (zero cost); on, the State must
    carry a real ring buffer (``init_state(trace_capacity=...)``).

    ``ext_arrival`` (None, or an absolute tick, possibly traced) is
    the streaming engine's round boundary: the submit time of the
    earliest job NOT materialized in this pool. It is folded into
    ``cache.next_arrival`` wherever that scalar is recomputed, so no
    event jump can skip past it (see :func:`_cache_from_state`)."""
    node_cap = jnp.asarray(cfg.cluster.node.as_tuple(), jnp.float32)
    N = jobs.submit.shape[0]
    time_mode = cfg.time_mode if time_mode is None else time_mode
    if time_mode not in ("tick", "event"):
        raise ValueError(f"unknown time_mode {time_mode!r}; "
                         "one of ('tick', 'event')")
    spec = policy_registry.get_policy(cfg.policy)
    preemptive = spec.preemptive
    P = cfg.max_preemptions if P is None else P
    s = cfg.s if s is None else s
    pol = spec.make()                  # decision rule (jax declarations)
    backend = _resolve_score_backend(cfg, spec, s)
    tc = _trace_ctx(n_nodes) if trace else None
    if preemptive and spec.jax_kind is None:
        raise NotImplementedError(
            f"policy {cfg.policy!r} registers no JAX implementation "
            "(jax_kind); run it on the reference engine")

    def trigger_preemption(st: State, te: jax.Array) -> State:
        if spec.jax_kind == "score":
            def width1(s_):
                s_, v = _score_select(s_, jobs, te, pol, node_cap, s, P,
                                      backend)
                return _signal_one(s_, jobs, v, te, tc)

            def gang(s_):
                # gang ordering keys on the score of the TOTAL gang
                # demand (mirror of gang_select's rank_key call on
                # cand_demand * cand_width); no rng — the gang path
                # has no random fallback, matching the reference
                cand = (s_.state == RUNNING) & ~jobs.is_te
                total = jobs._replace(
                    demand=jobs.demand * jobs.width[:, None]
                    .astype(jnp.float32))
                gscore = pol.jax_score(total, cand, node_cap, s)
                return _gang_select(s_, jobs, te, -gscore, P, score=gscore,
                                    tc=tc)

            return jax.lax.cond(jobs.width[te] == 1, width1, gang, st)

        def width1(s_):
            s_, rank = pol.jax_rank(s_, jobs)      # may consume s_.rng
            return _until_fits_select(s_, jobs, te, rank, P, tc)

        def gang(s_):
            s_, rank = pol.jax_rank(s_, jobs)      # may consume s_.rng
            return _gang_select(s_, jobs, te, rank, P, tc=tc)

        return jax.lax.cond(jobs.width[te] == 1, width1, gang, st)

    queue_pass = _make_queue_pass(jobs, cfg.backfill)
    gate = _make_gate(jobs, preemptive, cfg.backfill, cfg.backfill_depth)
    would_act = _make_would_act_cached(jobs, preemptive, cfg.backfill,
                                       cfg.backfill_depth)

    def head_mask(st):
        q = st.state == QUEUED
        if preemptive:
            q = q & ~jobs.is_te
        return q

    def te_actionable(st: State, ps: _Pass, processed):
        """(queued-TE mask, actionable subset) from the shared pass:
        gang fits now, or the preemption trigger is armed."""
        q = (st.state == QUEUED) & jobs.is_te & ~processed
        has_cand = ((st.state == RUNNING) & ~jobs.is_te).any()
        trigger = (st.te_pending == 0) & ~(ps.fit_pend >= jobs.width) \
            & has_cand
        return q, q & ((ps.fit_now >= jobs.width) | trigger)

    def te_lane(st: State, ps: _Pass):
        """Process queued TEs in queue-key order — but only the
        ACTIONABLE ones (gang fits now, or the preemption trigger is
        armed). A queued TE that is neither is a provable no-op under
        the serial reference walk (no placement, no signal, no rng),
        so every non-actionable TE ahead of the next actionable one is
        skipped wholesale: iterations scale with TEs that actually
        act, not with queue depth. Every action refreshes the shared
        pass, which doubles as the loop's exit evaluation."""
        def cond(carry):
            return carry[3].any()

        def body(carry):
            st, ps, processed, can = carry
            j = jnp.argmin(jnp.where(can, st.queue_key, _INF)) \
                .astype(jnp.int32)
            # everything queued ahead of j is non-actionable: mark it
            # processed together with j itself
            q = (st.state == QUEUED) & jobs.is_te & ~processed
            processed = processed | (q & (st.queue_key <= st.queue_key[j]))
            ok = ps.fit_now[j] >= jobs.width[j]
            row = ps.fits[j]
            nodes = row & (jnp.cumsum(row) <= jobs.width[j]) & ok

            def place(st):
                return _place(st, jobs, j, nodes, tc)

            def blocked(st):
                fits_pending = ps.fit_pend[j] >= jobs.width[j]
                has_cand = ((st.state == RUNNING) & ~jobs.is_te).any()
                do = (st.te_pending[j] == 0) & ~fits_pending & has_cand
                st = jax.lax.cond(do,
                                  lambda s_: trigger_preemption(s_, j),
                                  lambda s_: s_, st)
                # GP=0 victims vacate inline: place the TE NOW, before
                # the BE pass can reclaim the freed nodes (mirrors the
                # reference).
                ok2, nodes2 = _gang_fit(st.free, jobs.demand[j],
                                        jobs.width[j])
                return jax.lax.cond(do & ok2,
                                    lambda s_: _place(s_, jobs, j, nodes2,
                                                      tc),
                                    lambda s_: s_, st)

            st = jax.lax.cond(ok, place, blocked, st)
            ps = queue_pass(st, head_mask(st))
            _, can = te_actionable(st, ps, processed)
            return st, ps, processed, can

        processed0 = jnp.zeros((N,), bool)
        _, can0 = te_actionable(st, ps, processed0)
        st, ps, _, _ = jax.lax.while_loop(
            cond, body, (st, ps, processed0, can0))
        return st, ps

    def be_queue(st: State, ps: _Pass):
        """FIFO head-of-line BE lane: place the head while it fits
        (the pass already holds the head's identity, fit verdict and
        node-fit row — the body is one placement scatter plus the
        pass refresh)."""
        def body(carry):
            st, ps = carry
            j = ps.be_pick
            row = ps.fits[j]
            nodes = row & (jnp.cumsum(row) <= jobs.width[j])
            st = _place(st, jobs, j, nodes, tc)
            ps = queue_pass(st, head_mask(st))
            return st, ps

        return jax.lax.while_loop(lambda c: c[1].be_can, body, (st, ps))

    def be_queue_backfill(st: State, ps: _Pass):
        """Bounded first-fit backfill (``SchedulerCore.schedule``'s
        beyond-paper branch): walk the BE queue in FIFO order, start
        whatever fits, skip (at most ``backfill_depth``) whatever does
        not — skipped jobs keep their keys and are not revisited this
        pass. The pass's ``be_pick``/``nskip`` fold the reference's
        one-job-per-iteration scan into one placement per iteration:
        the pick is placeable iff the skips ahead of it still fit the
        depth budget, and those skips are marked in bulk."""
        depth = jnp.int32(cfg.backfill_depth)

        def cond(carry):
            st, ps, skipped, scanned = carry
            return ps.be_can & (scanned + ps.nskip < depth)

        def body(carry):
            st, ps, skipped, scanned = carry
            j = ps.be_pick
            q = head_mask(st) & ~skipped
            skipped = skipped | (q & (ps.fit_now < jobs.width)
                                 & (st.queue_key < st.queue_key[j]))
            scanned = scanned + ps.nskip
            row = ps.fits[j]
            nodes = row & (jnp.cumsum(row) <= jobs.width[j])
            st = _place(st, jobs, j, nodes, tc)
            if tc is not None:
                # marker after a placement that skipped ahead; aux =
                # cumulative skips this pass (reference `scanned`)
                st = _ev1(st, tc, st.t, obs_schema.BACKFILL, j,
                          aux=scanned, cond=scanned > 0)
            ps = queue_pass(st, head_mask(st) & ~skipped)
            return st, ps, skipped, scanned

        st, ps, _, _ = jax.lax.while_loop(
            cond, body, (st, ps, jnp.zeros((N,), bool), jnp.int32(0)))
        # the lane's pass excludes skipped jobs; refresh over the full
        # queue so the caller's gate re-evaluation sees tick semantics
        return st, queue_pass(st, head_mask(st))

    arrival_keys = (jnp.arange(N, dtype=jnp.float32)
                    if jobs.akey is None else
                    jobs.akey.astype(jnp.float32))

    def arrivals(st: State, cache: _Cache):
        """Queue every submitted job (key = global arrival order:
        slot index for monolithic jobsets, ``Jobs.akey`` for recycled
        pools) — gated on the cached next-arrival tick, so ticks
        between arrivals skip the whole phase."""
        def fire(args):
            st, cache = args
            arrive = (jobs.submit <= st.t) & (st.state == NOT_ARRIVED)
            if tc is not None:
                st = _ev_scan(st, tc, st.t, obs_schema.SUBMIT, arrive)
            state = jnp.where(arrive, QUEUED, st.state)
            st = st._replace(
                state=state,
                queue_key=jnp.where(arrive, arrival_keys, st.queue_key))
            nxt = jnp.min(jnp.where(
                state == NOT_ARRIVED, jobs.submit,
                _BIG)).astype(jnp.int32)
            if ext_arrival is not None:
                nxt = jnp.minimum(nxt,
                                  jnp.asarray(ext_arrival, jnp.int32))
            cache = cache._replace(
                next_arrival=nxt,
                n_q_te=cache.n_q_te + jnp.sum(
                    arrive & jobs.is_te).astype(jnp.int32),
                n_queued=cache.n_queued
                + jnp.sum(arrive).astype(jnp.int32))
            return st, cache

        return jax.lax.cond(cache.next_arrival <= st.t, fire,
                            lambda args: args, (st, cache))

    def vacates(st: State, cache: _Cache):
        """Vacate grace-expired victims (processed in job-index order)
        — gated on the cached (exact) next grace expiry."""
        def fire(args):
            st, cache = args
            vac = (st.state == GRACE) & (st.grace_left <= 0)
            if tc is not None:
                # [GRACE_EXPIRE, VACATE(aux=te), REQUEUE] per job,
                # job-major in index order — aux read BEFORE victim_of
                # is cleared below. GRACE jobs always have GP > 0, so
                # the expiry row is unconditional here. One 3-row
                # append per vacating job (``_ev_scan`` rationale).
                codes = jnp.asarray([obs_schema.GRACE_EXPIRE,
                                     obs_schema.VACATE,
                                     obs_schema.REQUEUE], jnp.int32)

                def vbody(carry):
                    st, m = carry
                    j = jnp.argmax(m).astype(jnp.int32)
                    aux = jnp.stack([jnp.int32(-1),
                                     st.victim_of[j].astype(jnp.int32),
                                     jnp.int32(-1)])
                    rows = _ev_rows(tc, st.t, codes,
                                    jnp.full((3,), j, jnp.int32),
                                    aux=aux)
                    st = _ev_append(st, rows, jnp.ones((3,), bool))
                    return st, m.at[j].set(False)

                st, _ = jax.lax.while_loop(lambda c: c[1].any(), vbody,
                                           (st, vac))
            if jobs.akey is None:
                # rank among the vacating set in slot order (== global
                # arrival order for monolithic jobsets)
                rank = jnp.cumsum(vac) - 1
            else:
                # recycled pool: slot order is arbitrary — rank the
                # vacating set by global arrival order so the requeue
                # keys (top-of-lane, FIFO among same-tick vacates)
                # match the monolithic engine bit-for-bit
                ok = jnp.where(vac, jobs.akey, _INF)
                rank = jnp.sum(ok[None, :] < ok[:, None], axis=1)
            n_vac = jnp.sum(vac)
            te_dec = jnp.zeros((N,), jnp.int32).at[
                jnp.where(vac, st.victim_of, N)].add(1, mode="drop")
            freed = _gang_release(st.assign, jobs.demand, vac)
            st = st._replace(
                queue_key=jnp.where(
                    vac, st.top_key - rank.astype(jnp.float32),
                    st.queue_key),
                top_key=st.top_key - n_vac.astype(jnp.float32),
                free=st.free + freed,
                pending_free=st.pending_free - freed,
                last_vacate=jnp.where(vac, st.t, st.last_vacate),
                te_pending=st.te_pending - te_dec,
                victim_of=jnp.where(vac, -1, st.victim_of),
                assign=st.assign & ~vac[:, None],
                state=jnp.where(vac, QUEUED, st.state),
            )
            in_grace = st.state == GRACE
            cache = cache._replace(
                next_vacate=jnp.where(
                    in_grace.any(),
                    st.t + jnp.min(jnp.where(in_grace, st.grace_left,
                                             _BIG)),
                    _BIG).astype(jnp.int32),
                n_queued=cache.n_queued + n_vac.astype(jnp.int32))
            return st, cache

        return jax.lax.cond(cache.next_vacate <= st.t, fire,
                            lambda args: args, (st, cache))

    def schedule(args):
        """The full schedule pass + cache refresh — runs only on ticks
        where ``would_act`` fired. Computes the shared pass once and
        threads it through both lanes; the lanes' final refresh
        doubles as the event jump's gate re-evaluation (``act_next``),
        so an acting tick never recomputes ``would_act`` from
        scratch."""
        st, cache = args
        ps = queue_pass(st, head_mask(st))
        if preemptive:
            st, ps = te_lane(st, ps)
        st, ps = (be_queue_backfill(st, ps) if cfg.backfill
                  else be_queue(st, ps))
        in_grace = st.state == GRACE
        queued = st.state == QUEUED
        cache = cache._replace(
            next_vacate=jnp.where(
                in_grace.any(),
                st.t + jnp.min(jnp.where(in_grace, st.grace_left, _BIG)),
                _BIG).astype(jnp.int32),
            n_q_te=jnp.sum(queued & jobs.is_te).astype(jnp.int32),
            n_queued=jnp.sum(queued).astype(jnp.int32))
        return st, cache, gate(st, ps)

    def run_minute(st: State, cache: _Cache):
        """Decrement running clocks, record finishers (one scatter per
        finishing job, behind an ``nfin > 0`` gate), decrement grace
        clocks (gated on any grace job existing)."""
        running = st.state == RUNNING
        remaining = st.remaining - running.astype(jnp.int32)
        fin = running & (remaining <= 0)
        nfin = jnp.sum(fin).astype(jnp.int32)
        st = st._replace(remaining=remaining)

        def finish_all(args):
            st, fin = args

            def fbody(carry):
                st, f = carry
                j = jnp.argmax(f).astype(jnp.int32)
                row = st.assign[j]
                if tc is not None:
                    st = _ev1(st, tc, st.t + 1, obs_schema.FINISH, j)
                st = st._replace(
                    state=st.state.at[j].set(DONE),
                    finish=st.finish.at[j].set(st.t + 1),
                    free=st.free + jobs.demand[j][None, :]
                    * row[:, None].astype(jnp.float32),
                    assign=st.assign.at[j].set(jnp.zeros_like(row)),
                    n_done=st.n_done + 1,
                )
                return st, f.at[j].set(False)

            st, _ = jax.lax.while_loop(lambda c: c[1].any(), fbody,
                                       (st, fin))
            return st

        st = jax.lax.cond(nfin > 0, finish_all, lambda args: args[0],
                          (st, fin))
        st = st._replace(
            grace_left=jax.lax.cond(
                cache.next_vacate < _BIG,
                lambda g: g - (st.state == GRACE).astype(jnp.int32),
                lambda g: g, st.grace_left),
            t=st.t + 1,
        )
        return st, nfin

    big = jnp.int32(max_ticks)

    def jump(st: State, cache: _Cache, hold) -> State:
        """Advance ``dt`` quanta in one step — the gap to the next
        event (cached next arrival / grace expiry, plus the masked-min
        next finish) — bulk-decrementing the clocks by the same
        ``dt``. Every skipped tick is a pure countdown (``hold`` is
        False only when ``would_act`` provably stays False), so free
        vectors, queues and the rng stream cannot change before the
        event; ``last_*`` metrics need no adjustment because every
        tick that records them still executes. Plain array math: under
        ``vmap`` the jump is per-lane."""
        def fire(st):
            t1 = st.t
            running = st.state == RUNNING
            in_grace = st.state == GRACE
            # Deltas from t1 (all >= 0): a NOT_ARRIVED job queues at
            # the top of tick submit; a running job with remaining r
            # finishes during tick t1 + r - 1; a GRACE job vacates at
            # the cached expiry. No events pending at all -> jump to
            # max_ticks (the tick loop's stall terminal).
            d_arr = cache.next_arrival - t1
            d_vac = cache.next_vacate - t1
            d_ev = jnp.minimum(d_arr, d_vac)

            def drain(st):
                # Nothing queued: would_act stays False no matter what
                # finishes (every act needs a queued job), so jump
                # straight to the next arrival / grace expiry and
                # retire EVERY finish on the way in one bulk update —
                # k consecutive finish events collapse into this one
                # iteration. With nothing left to arrive or vacate,
                # land on the last finish instead (the loop's natural
                # terminal boundary, same t as tick mode).
                last_fin = jnp.max(jnp.where(running, st.remaining, 0))
                dt = jnp.where(d_ev >= _BIG - t1, last_fin,
                               jnp.clip(d_ev, 0,
                                        jnp.maximum(big - t1, 0)))
                dt = dt.astype(jnp.int32)
                fin = running & (st.remaining <= dt)
                if tc is not None:
                    # the bulk retire must emit the FINISH rows the
                    # skipped ticks would have: sorted by finish time,
                    # job-index order within a tick (first-occurrence
                    # argmin) — bitwise identical to tick mode's
                    # stream, one row per retired job (``_ev_scan``
                    # rationale)
                    ft = jnp.where(fin, t1 + st.remaining, _BIG)

                    def dbody(carry):
                        st, ftm = carry
                        j = jnp.argmin(ftm).astype(jnp.int32)
                        st = _ev1(st, tc, ftm[j], obs_schema.FINISH, j)
                        return st, ftm.at[j].set(_BIG)

                    st, _ = jax.lax.while_loop(
                        lambda c: (c[1] < _BIG).any(), dbody, (st, ft))
                return st._replace(
                    t=t1 + dt,
                    remaining=st.remaining - jnp.where(
                        fin, st.remaining, dt * running.astype(jnp.int32)),
                    state=jnp.where(fin, DONE, st.state),
                    finish=jnp.where(fin, t1 + st.remaining, st.finish),
                    free=st.free + _gang_release(st.assign, jobs.demand,
                                                 fin),
                    assign=st.assign & ~fin[:, None],
                    n_done=st.n_done + jnp.sum(fin),
                    grace_left=st.grace_left
                    - dt * in_grace.astype(jnp.int32),
                )

            def normal(st):
                d_fin = jnp.min(jnp.where(running, st.remaining - 1, big))
                dt = jnp.minimum(d_ev, d_fin)
                dt = jnp.clip(dt, 0, jnp.maximum(big - t1, 0)) \
                    .astype(jnp.int32)
                return st._replace(
                    t=t1 + dt,
                    remaining=st.remaining
                    - dt * running.astype(jnp.int32),
                    grace_left=st.grace_left
                    - dt * in_grace.astype(jnp.int32),
                )

            return jax.lax.cond(cache.n_queued == 0, drain, normal, st)

        return jax.lax.cond(hold, lambda st: st, fire, st)

    def step(carry):
        st, cache = carry
        st, cache = arrivals(st, cache)
        st, cache = vacates(st, cache)
        # Every schedule action starts from a queued job, so an empty
        # queue short-circuits the whole gate.
        act = jax.lax.cond(cache.n_queued > 0,
                           lambda: would_act(st, cache),
                           lambda: jnp.asarray(False))
        st, cache, act_next = jax.lax.cond(
            act, schedule,
            lambda args: (args[0], args[1], jnp.asarray(False)),
            (st, cache))
        st, nfin = run_minute(st, cache)
        if time_mode == "tick":
            return st, cache
        # Event jump. When jobs finished, the freed capacity
        # invalidates the pre-run gate verdict — re-evaluate it (an
        # empty queue stays a no-act); otherwise ``act_next`` — the
        # schedule lanes' own exit evaluation — already answers for
        # the post-run state, which differs only by clock decrements
        # the gate does not read.
        hold_act = jax.lax.cond(
            nfin > 0,
            lambda: jax.lax.cond(cache.n_queued > 0,
                                 lambda: would_act(st, cache),
                                 lambda: jnp.asarray(False)),
            lambda: act_next)
        hold = (st.n_done >= N) | hold_act
        return jump(st, cache, hold), cache

    return step


def make_tick(cfg: SimConfig, jobs: Jobs, n_nodes: int,
              s=None, P=None, time_mode: str = None,
              max_ticks: int = 1 << 22, trace: bool = False):
    """Build a ``State -> State`` step: one scheduling tick ("tick"
    mode) or one executed tick plus the event jump ("event" mode) —
    the per-step public face of :func:`_make_step`, used by the
    invariant suites to observe every intermediate State. The event
    cache is rebuilt from the State on every call (it is a pure
    function of the State), so single-stepping is bit-identical to
    :func:`run`'s threaded loop."""
    step = _make_step(cfg, jobs, n_nodes, s=s, P=P, time_mode=time_mode,
                      max_ticks=max_ticks, trace=trace)

    def tick_step(st: State) -> State:
        st, _ = step((st, _cache_from_state(jobs, st)))
        return st

    return tick_step


def resolve_trace_capacity(cfg: SimConfig, jobs: Jobs,
                           trace_capacity=None) -> int:
    """The static ring capacity a traced run uses:
    ``trace_capacity`` verbatim when given, else
    ``obs.ring.default_capacity`` sized from the jobset and the
    config's P cap."""
    if trace_capacity is not None:
        return int(trace_capacity)
    return obs_ring.default_capacity(jobs.submit.shape[0],
                                     cfg.max_preemptions)


def run(cfg: SimConfig, jobs: Jobs, seed=0,
        max_ticks: int = 1 << 22, s=None, P=None,
        time_mode: str = None, trace: bool = False,
        trace_capacity=None) -> State:
    """Run the full simulation; returns the final state.

    ``time_mode`` ("tick" | "event", default ``cfg.time_mode``) selects
    per-quantum stepping vs the event-compressed jump — bit-identical
    States, wall-clock proportional to events instead of makespan.
    ``trace`` records every scheduler event into the in-jit ring
    buffer (decode with :func:`decode_trace`); off by default and then
    entirely compiled out."""
    cap = resolve_trace_capacity(cfg, jobs, trace_capacity) if trace else 0
    st = init_state(jobs, cfg.cluster.n_nodes, cfg.cluster.node.as_tuple(),
                    seed, trace_capacity=cap)
    return _run_loop(cfg, jobs, st, max_ticks, s, P, time_mode,
                     trace=trace)


def _run_loop(cfg: SimConfig, jobs: Jobs, st: State, max_ticks: int,
              s, P, time_mode: str, trace: bool = False,
              round_end=None) -> State:
    """The traceable core of :func:`run`: drive ``_make_step`` from an
    existing initial State (so :func:`run_jit` can build it eagerly
    and donate its buffers into the jitted loop).

    ``round_end`` (None, or an absolute tick — may be traced) turns
    the loop into ONE streaming macro-round: run until every pool job
    is DONE or ``t`` reaches ``round_end`` (the earliest submit not in
    this pool). The boundary tick itself is NOT executed — the next
    round's first iteration processes it, with the new arrivals packed
    in, exactly as the monolithic loop would have (DESIGN.md §10)."""
    step = _make_step(cfg, jobs, cfg.cluster.n_nodes, s=s, P=P,
                      time_mode=time_mode, max_ticks=max_ticks,
                      trace=trace, ext_arrival=round_end)
    N = jobs.submit.shape[0]

    def cond(carry):
        c = (carry[0].n_done < N) & (carry[0].t < max_ticks)
        if round_end is not None:
            c = c & (carry[0].t < round_end)
        return c

    st, _ = jax.lax.while_loop(
        cond, step, (st, _cache_from_state(jobs, st, round_end)))
    return st


def _run_jit_impl(cfg: SimConfig, jobs: Jobs, seed, time_mode: str,
                  trace: bool = False, trace_capacity: int = 0) -> State:
    st = init_state(jobs, cfg.cluster.n_nodes, cfg.cluster.node.as_tuple(),
                    seed, trace_capacity=trace_capacity if trace else 0)
    return _run_loop(cfg, jobs, st, 1 << 22, None, None, time_mode,
                     trace=trace)


_JIT_STATICS = ("cfg", "time_mode", "trace", "trace_capacity")
_run_jit_full = jax.jit(_run_jit_impl, static_argnames=_JIT_STATICS)
# Same program with the Jobs buffers DONATED into the jit: the sweep
# fabric's memory-flat entry. Safe by construction — init_state
# force-copies ``exec_total`` (the one State field derived from a Jobs
# array), so no live output aliases a donated input.
_run_jit_donated = jax.jit(_run_jit_impl, static_argnames=_JIT_STATICS,
                           donate_argnums=(1,))


def donation_supported() -> bool:
    """Whether the active backend implements input-output aliasing
    (gpu/tpu). The CPU backend silently keeps its copies (XLA warns
    and ignores the donation), so auto-donating callers — the sweep
    fabric — skip it there."""
    return jax.default_backend() in ("gpu", "tpu")


def run_jit(cfg: SimConfig, jobs: Jobs, seed: int = 0,
            time_mode: str = None, trace: bool = False,
            trace_capacity=None, donate: bool = False) -> State:
    """Jitted :func:`run`. The initial State is built INSIDE the jit
    (``seed`` is traced, so sweeping seeds reuses the compilation), so
    no State buffer ever crosses the jit boundary inward: every ~20
    small construction dispatches the old eager init paid per call are
    compiled into the loop program, and XLA owns (and reuses) the
    State buffers end-to-end — the stronger form of the buffer
    donation this entry point used to do. ``trace``/``trace_capacity``
    are jit-static: toggling tracing recompiles (the traced program is
    a different program), sweeping seeds does not.

    ``donate=True`` additionally donates the ``jobs`` buffers into the
    program (the caller's Jobs are CONSUMED; re-running them is an
    error on backends that implement aliasing). Results are identical
    either way — donation only changes buffer ownership. On CPU the
    donation is a no-op (see :func:`donation_supported`)."""
    if not (isinstance(seed, jax.Array) and jnp.issubdtype(
            seed.dtype, jax.dtypes.prng_key)):
        seed = jnp.asarray(seed, jnp.int32)
    cap = resolve_trace_capacity(cfg, jobs, trace_capacity) if trace else 0
    fn = _run_jit_donated if donate else _run_jit_full
    return fn(cfg, jobs, seed, time_mode, trace, cap)


@functools.partial(jax.jit, static_argnames=("cfg", "time_mode", "trace"))
def _run_round_jit(cfg: SimConfig, jobs: Jobs, st: State, round_end,
                   time_mode: str, trace: bool) -> State:
    return _run_loop(cfg, jobs, st, 1 << 22, None, None, time_mode,
                     trace=trace, round_end=round_end)


def run_round(cfg: SimConfig, jobs: Jobs, st: State, round_end=None,
              time_mode: str = None, trace: bool = False) -> State:
    """Resume an in-flight State for one jitted macro-round.

    The streaming engine's inner step (DESIGN.md §10): run the fused
    tick/event loop until every pool job is DONE or ``st.t`` reaches
    ``round_end`` — the submit tick of the earliest job that has not
    been packed into the pool yet (None = no more external arrivals;
    run to completion). ``round_end`` is traced, so every round of a
    streamed replay reuses one compilation; ``jobs`` carries the
    recycled slot pool and MUST have ``Jobs.akey`` stamped with global
    arrival order for queue keys / tie-breaks to match the monolithic
    engine (parity-window contract; use ``score_backend='jnp'`` — the
    fused kernels tie-break by slot index)."""
    re = jnp.asarray(_BIG if round_end is None else round_end, jnp.int32)
    return _run_round_jit(cfg, jobs, st, re, time_mode, trace)


def trace_overflow(st: State) -> jax.Array:
    """Ring-buffer rows dropped past capacity (() i32; 0 with tracing
    off). Non-zero means the trace is TRUNCATED — loud in
    ``result_summary`` and the CLI/bench output."""
    if st.ev_buf.size == 0:
        return jnp.zeros((), jnp.int32)
    cap = st.ev_buf.shape[-2] - 1
    return jnp.maximum(st.ev_n - cap, 0)


def decode_trace(st: State):
    """Decode the final State's ring buffer into the canonical event
    schema: ``(list[obs.schema.Event], overflow)`` — the JAX half of
    the cross-engine trace-parity contract (the reference half is
    ``Simulator(trace=True)``)."""
    if st.ev_buf.size == 0:
        return [], 0
    return obs_ring.decode_ring(st.ev_buf, st.ev_n)


def state_diff_fields(a: State, b: State) -> list:
    """Names of State fields that differ bitwise — rng keys compared by
    key data. Empty list == full-State bit equality, THE tick-vs-event
    parity contract; the engine benchmark and the parity/property
    suites all share this one definition so a new State field is
    covered everywhere at once."""
    diff = []
    for f in a._fields:
        x, y = getattr(a, f), getattr(b, f)
        if f == "rng":
            x, y = jax.random.key_data(x), jax.random.key_data(y)
        if not bool((np.asarray(x) == np.asarray(y)).all()):
            diff.append(f)
    return diff


def slowdown(jobs: Jobs, st: State) -> jax.Array:
    waiting = st.finish - jobs.submit - jobs.exec_total
    return 1.0 + waiting / jobs.exec_total


def masked_percentiles(vals, mask, ps) -> dict:
    """``{f"p{p}": percentile of vals[mask]}`` — NaN-safe: when the
    mask selects nothing (a trial with zero valid TE or BE jobs after
    sentinel padding, or no preemption ever resumed), every entry is an
    EXPLICIT ``nan`` rather than whatever a reduction over an all-NaN
    slice happens to produce; nan-aware poolers then exclude the trial
    (DESIGN.md §5)."""
    v = jnp.where(mask, vals, jnp.nan)
    some = mask.any()
    return {f"p{p}": jnp.where(some, jnp.nanpercentile(v, p), jnp.nan)
            for p in ps}


def result_summary(jobs: Jobs, st: State) -> dict:
    """Percentile summary mirroring metrics.pooled_tables (jnp).

    Sentinel (padding) rows are masked out of every statistic; empty
    classes (all-BE / all-TE jobsets) yield explicit ``nan`` rows."""
    sd = slowdown(jobs, st)
    te = jobs.is_te & jobs.valid
    be = ~jobs.is_te & jobs.valid
    out = {}
    for name, m in (("TE", te), ("BE", be)):
        out[name] = masked_percentiles(sd, m, (50, 95, 99))
    pre = jnp.where(be, (st.preempt_count > 0).astype(jnp.float32), jnp.nan)
    out["preempted_frac"] = jnp.where(be.any(), jnp.nanmean(pre), jnp.nan)
    iv_mask = (st.last_resume >= 0) & jobs.valid
    out["intervals"] = masked_percentiles(
        (st.last_resume - st.last_signal).astype(jnp.float32),
        iv_mask, (50, 75, 95, 99))
    # loud observability counters: non-zero fallback_count voids the
    # P-cap exactness claim, non-zero trace_overflow means a truncated
    # trace — both surfaced in CLI and bench output, not just tests
    out["fallback_count"] = st.fallback_count
    out["trace_overflow"] = trace_overflow(st)
    return out
