"""The FitGpp scheduler as a pure-JAX module.

Fixed-capacity struct-of-arrays state, ``lax.while_loop`` tick loop,
bounded inner while-loops for the schedule-until-blocked phases, and
vectorized Eq. 1-4 victim selection (masked argmin). ``jit``-able and
``vmap``-able over trials, which is what lets the sensitivity sweeps
(Figs. 4-7) distribute over the production mesh with ``shard_map``
(see core/sweep.py).

Parity: semantics mirror ``core/simulator.py`` tick-for-tick for the
deterministic policies (fifo / lrtp / srtp / the score policies'
main path); the random fallback and RAND use a jax PRNG and are
excluded from exact parity (property-tested statistically instead).

Gang (multi-node) jobs: placement state is an ``(n_jobs, n_nodes)``
boolean assignment mask (``State.assign``) instead of a scalar node
index, and every job carries its gang width (``Jobs.width``).
Placement is all-or-nothing first-fit — the first ``width`` nodes
whose free vector covers the PER-NODE demand — the vectorized mirror
of ``engine/placement.ClusterState.fits_job``. Victims vacate and
requeue all their nodes at once, Eq. 2 is evaluated against a
multi-node victim's BEST node (the ``engine/preemption.
best_victim_node`` reduction), and a blocked gang TE selects victims
with the ``engine/preemption.gang_select`` strategy: the min-score
single victim whose eviction alone frees enough nodes, falling back
to accumulation in policy order (and signalling nothing when even
preempting everyone would not suffice).

Victim selection is registry-dispatched (``core/policy_registry.py``,
DESIGN.md §6): ``make_tick`` builds its preemption trigger from the
registered policy's JAX declaration — ``jax_kind == "rank"`` policies
feed :func:`_until_fits_select`, ``"score"`` policies feed
:func:`_score_select` (Eq. 4 masked argmin + the paper's random
fallback), and score policies may route the score + argmin through an
accelerated kernel via ``SimConfig.score_backend`` (FitGpp's Pallas
``fitgpp_score`` kernel as ``"pallas"``; it takes the (jobs, nodes)
assignment tile and does the best-node Eq. 2 reduction in-kernel;
parity-tested vs jnp). Gang TEs dispatch to :func:`_gang_select` on
either contract.

The BE queue is strict FIFO (head-of-line blocking) by default;
``SimConfig.backfill`` enables the same bounded first-fit backfill
scan as the reference ``SchedulerCore.schedule`` (skip up to
``backfill_depth`` blocked jobs per pass, FIFO order otherwise).

Time advancement (``SimConfig.time_mode``, DESIGN.md §7): the default
``"event"`` mode compresses runs of provably no-op ticks inside the
jitted ``while_loop`` — after a tick whose schedule pass could not act,
the body jumps ``dt`` quanta straight to the next event (the masked
minimum over the next valid arrival, ``t + remaining`` of running
jobs and ``t + grace_left`` of GRACE jobs), bulk-decrementing
``remaining``/``grace_left`` by the same ``dt``. The jump is gated by
:func:`_make_would_act` — the vectorized mirror of the reference
engine's ``SchedulerCore.schedule_would_act``, gang fits and the
backfill scan included — so any tick on which the policy would be
(re-)invoked still executes and the rng stream, every metric
timestamp and the full State agree bit-for-bit with ``"tick"`` mode
at every event boundary. All of it is plain array math, so under
``vmap`` the jump ``dt`` is per-lane: ragged sentinel-padded batches
and heterogeneous per-trial horizons each fast-forward at their own
pace.
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cluster import SimConfig
from repro.core import policy_registry
from repro.core.engine.placement import FIT_EPS
from repro.core.types import JobSet

NOT_ARRIVED, QUEUED, RUNNING, GRACE, DONE = 0, 1, 2, 3, 4
_INF = jnp.inf
_EPS = FIT_EPS    # one epsilon for every fit check, engine-wide


class Jobs(NamedTuple):
    """Static workload arrays (device-resident).

    ``demand`` is PER NODE; ``width`` is the gang width (1 for the
    paper's single-task jobs) and the job needs ``width`` nodes
    simultaneously (all-or-nothing gang placement).

    ``valid`` marks real jobs; False rows are sentinel padding added by
    ``sweep.stack_jobsets`` so jobsets of unequal ``n`` can share one
    vmapped batch. Sentinels are born DONE (``init_state``) — they never
    arrive, queue, run or get preempted — and are masked out of every
    percentile/mean in ``sweep`` and ``result_summary``, so a padded
    trial is bit-identical to its unpadded run (DESIGN.md §5).
    Sentinels keep ``width == 1``.
    """
    submit: jax.Array        # (N,) i32
    exec_total: jax.Array    # (N,) i32
    demand: jax.Array        # (N, 3) f32, per node
    is_te: jax.Array         # (N,) bool
    gp: jax.Array            # (N,) i32
    width: jax.Array         # (N,) i32 gang width (>= 1)
    valid: jax.Array         # (N,) bool


class State(NamedTuple):
    t: jax.Array
    state: jax.Array         # (N,) i32
    remaining: jax.Array     # (N,) i32
    assign: jax.Array        # (N, n_nodes) bool placement mask
    preempt_count: jax.Array
    grace_left: jax.Array
    queue_key: jax.Array     # (N,) f32, +inf when not queued
    top_key: jax.Array       # () f32
    finish: jax.Array
    te_pending: jax.Array
    victim_of: jax.Array
    free: jax.Array          # (nodes, 3) f32
    pending_free: jax.Array
    last_signal: jax.Array   # (N,) i32 metrics
    last_vacate: jax.Array
    last_resume: jax.Array
    awaiting_resume: jax.Array   # (N,) bool
    n_done: jax.Array
    rng: jax.Array
    # () i32: victim selections that fell back past the main masked
    # path (score policies' random fallback, rank/gang selections'
    # over-P-cap last resort). Observability for the invariant suite:
    # when 0, the paper's P cap is exact — sum(max(preempt_count - P,
    # 0)) never exceeds this counter.
    fallback_count: jax.Array


def jobs_from_jobset(js: JobSet) -> Jobs:
    return Jobs(
        submit=jnp.asarray(js.submit, jnp.int32),
        exec_total=jnp.asarray(js.exec_total, jnp.int32),
        demand=jnp.asarray(js.demand, jnp.float32),
        is_te=jnp.asarray(js.is_te, bool),
        gp=jnp.asarray(js.gp, jnp.int32),
        width=jnp.asarray(js.n_nodes, jnp.int32),
        valid=jnp.ones(len(js.submit), bool),
    )


def init_state(jobs: Jobs, n_nodes: int, node_cap, seed) -> State:
    N = jobs.submit.shape[0]
    cap = jnp.asarray(node_cap, jnp.float32)
    return State(
        t=jnp.zeros((), jnp.int32),
        # sentinel (padding) jobs are born DONE: never arrive, never run
        state=jnp.where(jobs.valid, NOT_ARRIVED, DONE).astype(jnp.int32),
        remaining=jobs.exec_total.astype(jnp.int32),
        assign=jnp.zeros((N, n_nodes), bool),
        preempt_count=jnp.zeros((N,), jnp.int32),
        grace_left=jnp.zeros((N,), jnp.int32),
        queue_key=jnp.full((N,), _INF, jnp.float32),
        top_key=jnp.asarray(-1.0, jnp.float32),
        finish=jnp.full((N,), -1, jnp.int32),
        te_pending=jnp.zeros((N,), jnp.int32),
        victim_of=jnp.full((N,), -1, jnp.int32),
        free=jnp.tile(cap[None, :], (n_nodes, 1)),
        pending_free=jnp.zeros((n_nodes, 3), jnp.float32),
        last_signal=jnp.full((N,), -1, jnp.int32),
        last_vacate=jnp.full((N,), -1, jnp.int32),
        last_resume=jnp.full((N,), -1, jnp.int32),
        awaiting_resume=jnp.zeros((N,), bool),
        n_done=jnp.sum(~jobs.valid).astype(jnp.int32),
        rng=seed if (isinstance(seed, jax.Array)
                     and jnp.issubdtype(seed.dtype, jax.dtypes.prng_key))
        else jax.random.key(seed),
        fallback_count=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def _gang_fit(free: jax.Array, d: jax.Array, w: jax.Array):
    """All-or-nothing first fit: (ok, node mask of the FIRST ``w``
    nodes whose free vector covers the per-node demand ``d``). The
    vectorized mirror of ``ClusterState.fits_job``; ``w == 1`` is
    plain first-fit. The mask is all-False when the gang does not fit."""
    fits = jnp.all(free >= d[None, :] - _EPS, axis=1)
    ok = jnp.sum(fits) >= w
    mask = fits & (jnp.cumsum(fits) <= w) & ok
    return ok, mask


def _gang_fits(free: jax.Array, demand: jax.Array,
               width: jax.Array) -> jax.Array:
    """Per-job gang feasibility: (N,) bool, True where at least
    ``width[j]`` nodes of ``free`` each cover ``demand[j]`` (the
    vectorized form of ``_gang_fit(...)[0]`` over every job at once)."""
    fits = jnp.all(free[None, :, :] >= demand[:, None, :] - _EPS, axis=2)
    return jnp.sum(fits, axis=1) >= width


def _best_victim_node(free: jax.Array, assign: jax.Array,
                      demand: jax.Array, te_d: jax.Array):
    """Eq. 2 glue (``engine/preemption.best_victim_node``): for every
    job, the min-slack of ``free + own demand - te_demand`` per node
    masked to the job's assigned nodes, and the argmax node — the node
    a multi-node victim is evaluated (and accounted) against. Rows
    with no assignment get ``-inf`` slack (never eligible)."""
    slack = jnp.min(free[None, :, :] + demand[:, None, :]
                    - te_d[None, None, :], axis=2)          # (N, nodes)
    slack = jnp.where(assign, slack, -_INF)
    return jnp.max(slack, axis=1), jnp.argmax(slack, axis=1)


def _onehot(N: int, j: jax.Array) -> jax.Array:
    return jnp.arange(N) == j


def _gang_release(assign: jax.Array, demand: jax.Array,
                  mask: jax.Array) -> jax.Array:
    """Summed per-node demand of the ``mask``-selected jobs over their
    assigned nodes: (nodes, 3). One matmul replaces the scalar-node
    scatter-add (exact for the integer/quantized demands)."""
    sel = (assign & mask[:, None]).astype(demand.dtype)
    return sel.T @ demand


def _place(st: State, jobs: Jobs, j: jax.Array, nodes: jax.Array) -> State:
    """Start job j on the ``nodes`` mask (assumes the gang fits)."""
    N = jobs.submit.shape[0]
    oh = _onehot(N, j)
    resumed = st.awaiting_resume[j]
    return st._replace(
        state=jnp.where(oh, RUNNING, st.state),
        assign=jnp.where(oh[:, None], nodes[None, :], st.assign),
        queue_key=jnp.where(oh, _INF, st.queue_key),
        free=st.free - jobs.demand[j][None, :]
        * nodes[:, None].astype(jnp.float32),
        last_resume=jnp.where(oh & resumed, st.t, st.last_resume),
        awaiting_resume=st.awaiting_resume & ~oh,
    )


def _signal_one(st: State, jobs: Jobs, v: jax.Array, te: jax.Array) -> State:
    """Signal preemption of running BE job v for TE job te (scalars).
    Gang victims promise / vacate ALL their nodes at once."""
    N = jobs.submit.shape[0]
    oh = _onehot(N, v)
    gp0 = jobs.gp[v] == 0
    d = jobs.demand[v][None, :] * st.assign[v][:, None].astype(jnp.float32)
    te_oh = _onehot(N, te)
    st = st._replace(
        preempt_count=st.preempt_count + oh.astype(jnp.int32),
        last_signal=jnp.where(oh, st.t, st.last_signal),
        awaiting_resume=st.awaiting_resume | oh,
    )
    # GP == 0: vacate inline (same tick), matching the reference.
    vac = st._replace(
        state=jnp.where(oh, QUEUED, st.state),
        assign=st.assign & ~oh[:, None],
        queue_key=jnp.where(oh, st.top_key, st.queue_key),
        top_key=st.top_key - 1.0,
        free=st.free + d,
        last_vacate=jnp.where(oh, st.t, st.last_vacate),
    )
    # GP > 0: enter grace; resources become "pending".
    grc = st._replace(
        state=jnp.where(oh, GRACE, st.state),
        grace_left=jnp.where(oh, jobs.gp[v], st.grace_left),
        victim_of=jnp.where(oh, te, st.victim_of),
        te_pending=st.te_pending + te_oh.astype(jnp.int32),
        pending_free=st.pending_free + d,
    )
    return jax.tree.map(lambda a, b: jnp.where(gp0, a, b), vac, grc)


# ---------------------------------------------------------------------------
# victim selection (registry-dispatched; policies declare jax_rank/jax_score)
# ---------------------------------------------------------------------------

def _score_select(st: State, jobs: Jobs, te: jax.Array, pol, node_cap, s,
                  P, backend: str):
    """Generic score-policy selection -> (state with advanced rng, victim).

    The policy's ``jax_score`` gives per-job scores (lower = better
    victim); this applies Eq. 2 eligibility — evaluated against each
    victim's BEST node (``_best_victim_node``), so gang victims are
    judged where they have the most slack — the P cap and the Eq. 4
    masked argmin, with the paper's random-candidate fallback when no
    job passes the masks. ``backend != "jnp"`` fuses score, best-node
    reduction and masked argmin on the policy's registered accelerated
    kernel (``jax_score_accel``; returns -1 when nothing passes).
    """
    cand = (st.state == RUNNING) & ~jobs.is_te
    under = st.preempt_count < P
    if backend != "jnp":
        main = pol.jax_score_accel(backend, jobs, te, st.free, st.assign,
                                   cand, under, node_cap, s)
        mask_any = main >= 0
    else:
        score = pol.jax_score(jobs, cand, node_cap, s)
        best_slack, _ = _best_victim_node(st.free, st.assign, jobs.demand,
                                          jobs.demand[te])
        elig = best_slack >= -_EPS
        mask = cand & elig & under
        main = jnp.argmin(jnp.where(mask, score, _INF)).astype(jnp.int32)
        mask_any = mask.any()

    rng, sub = jax.random.split(st.rng)
    p = cand.astype(jnp.float32)
    p = p / jnp.maximum(p.sum(), 1.0)
    rnd = jax.random.choice(sub, jobs.submit.shape[0], p=p).astype(jnp.int32)
    st = st._replace(
        rng=rng,
        fallback_count=st.fallback_count + (~mask_any).astype(jnp.int32))
    return st, jnp.where(mask_any, main, rnd)


def _resolve_score_backend(cfg: SimConfig, spec, s) -> str:
    """Effective score backend: ``cfg.score_backend``. Accelerated
    backends need a static ``s`` (it is baked into the kernel), so
    traced-s sweeps — and policies without the backend — fall back to
    the jnp path silently. Any static Python number counts as static
    (an int ``s`` must not silently downgrade a requested kernel)."""
    if os.environ.get("REPRO_SIM_KERNEL") is not None:
        raise RuntimeError(
            "the REPRO_SIM_KERNEL env override was removed; select the "
            "accelerated score path with SimConfig(score_backend='pallas') "
            "(or --score-backend on the scenarios CLI) instead")
    backend = cfg.score_backend
    static_s = isinstance(s, (int, float)) and not isinstance(s, bool)
    if backend != "jnp" and (backend not in spec.score_backends
                             or not static_s):
        return "jnp"
    return backend


def _until_fits_select(st: State, jobs: Jobs, te: jax.Array, rank_val,
                       P) -> State:
    """LRTP/RAND: keep signalling victims (best ``rank_val`` first,
    under-P-cap first) until the TE fits on the last victim's BEST
    node, counting the demand signalled there so far. Mirrors
    ``policies._preempt_until_fits`` over the invocation snapshot:
    victims are accounted at the node ``engine/preemption.
    best_victim_node`` would pick (their only node when single-node),
    chosen once from the free vectors at trigger time."""
    N = jobs.submit.shape[0]
    te_d = jobs.demand[te]
    n_nodes = st.free.shape[0]
    free0 = st.free                                # invocation snapshot
    _, best_node = _best_victim_node(free0, st.assign, jobs.demand, te_d)

    def cond(carry):
        st, taken, pending, satisfied = carry
        cand = (st.state == RUNNING) & ~jobs.is_te & ~taken
        return (~satisfied) & cand.any()

    def body(carry):
        st, taken, pending, _ = carry
        cand = (st.state == RUNNING) & ~jobs.is_te & ~taken
        under = st.preempt_count < P
        # under-cap candidates first, then by rank_val descending
        # (two-level pick, NOT an additive offset — a +1e12 offset in f32
        # would swallow rank_val and break the ordering)
        m1 = cand & under
        pick_from = jnp.where(m1.any(), m1, cand)
        v = jnp.argmax(jnp.where(pick_from, rank_val, -_INF)).astype(jnp.int32)
        node = best_node[v]
        st = st._replace(
            fallback_count=st.fallback_count + (~m1.any()).astype(jnp.int32))
        st = _signal_one(st, jobs, v, te)
        # Accumulate each selection's demand at its best node and test
        # the TE there against the snapshot — mirrors
        # policies._preempt_until_fits (pending starts at free, adds
        # every victim regardless of GP; GP=0 inline vacates are part
        # of that same accounting).
        pending = pending.at[node].add(jobs.demand[v])
        satisfied = jnp.all(te_d <= free0[node] + pending[node] + _EPS)
        return st, taken | _onehot(N, v), pending, satisfied

    st, _, _, _ = jax.lax.while_loop(
        cond, body, (st, jnp.zeros((N,), bool),
                     jnp.zeros((n_nodes, 3), jnp.float32),
                     jnp.asarray(False)))
    return st


def _gang_select(st: State, jobs: Jobs, te: jax.Array, rank_val, P,
                 score=None) -> State:
    """Multi-node TE: the vectorized mirror of
    ``engine/preemption.gang_select``. With ``score`` (Eq. 4-style
    argmin policies; LOWER = better victim, computed over TOTAL gang
    demand), prefer the min-score SINGLE victim whose eviction alone
    yields >= width satisfying nodes — restricted to under-P-cap
    candidates when any exist; otherwise accumulate victims in policy
    order (``rank_val`` HIGHER = preempt first, under-cap first) until
    the gang fits, and signal NOTHING when even preempting every
    candidate would not suffice (signalling then would burn preemption
    budget for no gain). Over-P-cap signals count into
    ``fallback_count`` (the P-cap invariant's allowance)."""
    N = jobs.submit.shape[0]
    te_d = jobs.demand[te]
    w = jobs.width[te]
    free0 = st.free
    cand0 = (st.state == RUNNING) & ~jobs.is_te
    under0 = st.preempt_count < P

    def n_fit(fr):
        return jnp.sum(jnp.all(fr >= te_d[None, :] - _EPS, axis=1))

    if score is not None:
        # single-eviction sufficiency: free + the victim's demand on
        # each of its nodes must yield >= width fitting nodes
        trial = free0[None, :, :] + jobs.demand[:, None, :] \
            * st.assign[:, :, None].astype(jnp.float32)
        nfit1 = jnp.sum(jnp.all(trial >= te_d[None, None, :] - _EPS,
                                axis=2), axis=1)
        pool = cand0 & jnp.where((cand0 & under0).any(), under0, True)
        single = pool & (nfit1 >= w)
        v1 = jnp.argmin(jnp.where(single, score, _INF)).astype(jnp.int32)
        have_single = single.any()
    else:
        v1 = jnp.int32(0)
        have_single = jnp.asarray(False)

    # accumulation (pure — no signals until the whole set is known to
    # suffice): walk candidates in policy order, recording selection
    # sequence numbers, until >= width nodes fit the TE
    def acc_cond(carry):
        taken, pending, satisfied, nsel, seq = carry
        return (~satisfied) & (cand0 & ~taken).any()

    def acc_body(carry):
        taken, pending, satisfied, nsel, seq = carry
        c = cand0 & ~taken
        m1 = c & under0
        pick = jnp.where(m1.any(), m1, c)
        v = jnp.argmax(jnp.where(pick, rank_val, -_INF)).astype(jnp.int32)
        pending = pending + jobs.demand[v][None, :] \
            * st.assign[v][:, None].astype(jnp.float32)
        return (taken | _onehot(N, v), pending, n_fit(pending) >= w,
                nsel + 1, seq.at[v].set(nsel))

    taken, pending, satisfied, nsel, seq = jax.lax.while_loop(
        acc_cond, acc_body,
        (jnp.zeros((N,), bool), free0, n_fit(free0) >= w,
         jnp.int32(0), jnp.full((N,), -1, jnp.int32)))

    def signal_single(st):
        st = st._replace(fallback_count=st.fallback_count
                         + (~under0[v1]).astype(jnp.int32))
        return _signal_one(st, jobs, v1, te)

    def signal_accum(st):
        n_sig = jnp.where(satisfied, nsel, 0)   # insufficient -> nothing

        def sig_cond(carry):
            return carry[1] < n_sig

        def sig_body(carry):
            st, k = carry
            v = jnp.argmax(seq == k).astype(jnp.int32)
            st = st._replace(fallback_count=st.fallback_count
                             + (~under0[v]).astype(jnp.int32))
            return _signal_one(st, jobs, v, te), k + 1

        st, _ = jax.lax.while_loop(sig_cond, sig_body, (st, jnp.int32(0)))
        return st

    return jax.lax.cond(have_single, signal_single, signal_accum, st)


# ---------------------------------------------------------------------------
# event-compressed time advancement (SimConfig.time_mode, DESIGN.md §7)
# ---------------------------------------------------------------------------

def _make_would_act(jobs: Jobs, preemptive: bool, backfill: bool = False,
                    backfill_depth: int = 64):
    """Vectorized mirror of ``SchedulerCore.schedule_would_act``.

    True whenever a schedule pass on this State could start a job or
    (re-)invoke victim selection: a queued TE's gang fits, a queued
    TE's preemption trigger is armed (``te_pending == 0``, does not fit
    even counting ``pending_free``, running BE candidates exist), the
    BE head fits — or, under backfill, any of the first
    ``backfill_depth`` queued BE jobs (queue order) fits. Deliberately
    conservative in the same way as the reference: a fruitless policy
    invocation still counts, because RAND and the score policies'
    random fallback consume rng on every invocation — this is what
    keeps the event jump bit-exact for the stochastic paths too
    (DESIGN.md §4/§7).
    """
    N = jobs.submit.shape[0]
    depth = min(int(backfill_depth), N)

    def would_act(st: State) -> jax.Array:
        queued = st.state == QUEUED
        be_q = queued & ~jobs.is_te if preemptive else queued
        fits_now = _gang_fits(st.free, jobs.demand, jobs.width)
        if not backfill:
            head = jnp.argmin(jnp.where(be_q, st.queue_key, _INF))
            act = be_q.any() & fits_now[head]
        else:
            # the reference scan examines the first `depth` jobs in
            # queue order and acts iff any of them fits
            order = jnp.argsort(jnp.where(be_q, st.queue_key, _INF))
            scan = order[:depth]
            act = (be_q[scan] & fits_now[scan]).any()
        if preemptive:
            te_q = queued & jobs.is_te
            fits_pend = _gang_fits(st.free + st.pending_free,
                                   jobs.demand, jobs.width)
            has_cand = ((st.state == RUNNING) & ~jobs.is_te).any()
            trigger = (st.te_pending == 0) & ~fits_pend & has_cand
            act = act | (te_q & (fits_now | trigger)).any()
        return act

    return would_act


def _make_event_advance(jobs: Jobs, preemptive: bool, n_jobs: int,
                        max_ticks: int, backfill: bool,
                        backfill_depth: int):
    """Build the post-tick event jump: advance ``dt`` quanta in one
    step, where ``dt`` is the gap to the next event — the masked
    minimum over (next valid arrival, ``t + remaining`` of running
    jobs, ``t + grace_left`` of GRACE jobs) — and every skipped tick is
    a pure countdown (``would_act`` False, so free vectors, queues and
    the rng stream provably cannot change before the event).
    ``remaining``/``grace_left`` are bulk-decremented by the same
    ``dt``; ``last_signal``/``last_vacate``/``last_resume`` need no
    adjustment because every tick that records them still executes.
    Plain array math: under ``vmap`` the jump is per-lane.
    """
    would_act = _make_would_act(jobs, preemptive, backfill, backfill_depth)
    big = jnp.int32(max_ticks)

    def advance(st: State) -> State:
        t1 = st.t                       # the tick just executed is t1 - 1
        running = st.state == RUNNING
        in_grace = st.state == GRACE
        # Deltas from t1 to each next event (all masked mins; >= 0):
        # a NOT_ARRIVED job enters the queue at the top of tick submit;
        # a running job with remaining r finishes during tick t1 + r - 1;
        # a GRACE job with grace_left g vacates at the top of tick t1 + g.
        d_arr = jnp.min(jnp.where(st.state == NOT_ARRIVED,
                                  jobs.submit - t1, big))
        d_fin = jnp.min(jnp.where(running, st.remaining - 1, big))
        d_vac = jnp.min(jnp.where(in_grace, st.grace_left, big))
        dt = jnp.minimum(jnp.minimum(d_arr, d_fin), d_vac)
        # No events pending at all -> jump to max_ticks (the tick loop's
        # stall terminal, same as tick mode reaching its bound); never
        # jump while the schedule could still act or everything is done.
        dt = jnp.clip(dt, 0, jnp.maximum(big - t1, 0))
        hold = would_act(st) | (st.n_done >= n_jobs)
        dt = jnp.where(hold, 0, dt).astype(jnp.int32)
        return st._replace(
            t=t1 + dt,
            remaining=st.remaining - dt * running.astype(jnp.int32),
            grace_left=st.grace_left - dt * in_grace.astype(jnp.int32),
        )

    return advance


def make_tick(cfg: SimConfig, jobs: Jobs, n_nodes: int,
              s=None, P=None, time_mode: str = None,
              max_ticks: int = 1 << 22):
    """Build the while-loop body: one scheduling tick, plus — in
    ``"event"`` time mode — the event jump that compresses the
    following run of provably no-op ticks into a single ``dt`` step
    (bit-exact either way; see module docstring). ``time_mode``
    defaults to ``cfg.time_mode``; ``s`` and ``P`` may be traced
    scalars (for vmapped sweeps); ``max_ticks`` bounds the stall jump
    and must match the driving loop's bound."""
    node_cap = jnp.asarray(cfg.cluster.node.as_tuple(), jnp.float32)
    N = jobs.submit.shape[0]
    time_mode = cfg.time_mode if time_mode is None else time_mode
    if time_mode not in ("tick", "event"):
        raise ValueError(f"unknown time_mode {time_mode!r}; "
                         "one of ('tick', 'event')")
    spec = policy_registry.get_policy(cfg.policy)
    preemptive = spec.preemptive
    P = cfg.max_preemptions if P is None else P
    s = cfg.s if s is None else s
    pol = spec.make()                  # decision rule (jax declarations)
    backend = _resolve_score_backend(cfg, spec, s)
    if preemptive and spec.jax_kind is None:
        raise NotImplementedError(
            f"policy {cfg.policy!r} registers no JAX implementation "
            "(jax_kind); run it on the reference engine")

    def trigger_preemption(st: State, te: jax.Array) -> State:
        if spec.jax_kind == "score":
            def width1(s_):
                s_, v = _score_select(s_, jobs, te, pol, node_cap, s, P,
                                      backend)
                return _signal_one(s_, jobs, v, te)

            def gang(s_):
                # gang ordering keys on the score of the TOTAL gang
                # demand (mirror of gang_select's rank_key call on
                # cand_demand * cand_width); no rng — the gang path
                # has no random fallback, matching the reference
                cand = (s_.state == RUNNING) & ~jobs.is_te
                total = jobs._replace(
                    demand=jobs.demand * jobs.width[:, None]
                    .astype(jnp.float32))
                gscore = pol.jax_score(total, cand, node_cap, s)
                return _gang_select(s_, jobs, te, -gscore, P, score=gscore)

            return jax.lax.cond(jobs.width[te] == 1, width1, gang, st)

        def width1(s_):
            s_, rank = pol.jax_rank(s_, jobs)      # may consume s_.rng
            return _until_fits_select(s_, jobs, te, rank, P)

        def gang(s_):
            s_, rank = pol.jax_rank(s_, jobs)      # may consume s_.rng
            return _gang_select(s_, jobs, te, rank, P)

        return jax.lax.cond(jobs.width[te] == 1, width1, gang, st)

    def te_lane(st: State) -> State:
        def cond(carry):
            st, processed = carry
            q = (st.state == QUEUED) & jobs.is_te & ~processed
            return q.any()

        def body(carry):
            st, processed = carry
            q = (st.state == QUEUED) & jobs.is_te & ~processed
            j = jnp.argmin(jnp.where(q, st.queue_key, _INF)).astype(jnp.int32)
            ok, nodes = _gang_fit(st.free, jobs.demand[j], jobs.width[j])

            def place(st):
                return _place(st, jobs, j, nodes)

            def blocked(st):
                promised = st.free + st.pending_free
                fits_pending = jnp.sum(jnp.all(
                    promised >= jobs.demand[j][None, :] - _EPS,
                    axis=1)) >= jobs.width[j]
                has_cand = ((st.state == RUNNING) & ~jobs.is_te).any()
                do = (st.te_pending[j] == 0) & ~fits_pending & has_cand
                st = jax.lax.cond(do,
                                  lambda s_: trigger_preemption(s_, j),
                                  lambda s_: s_, st)
                # GP=0 victims vacate inline: place the TE NOW, before
                # the BE pass can reclaim the freed nodes (mirrors the
                # reference).
                ok2, nodes2 = _gang_fit(st.free, jobs.demand[j],
                                        jobs.width[j])
                return jax.lax.cond(do & ok2,
                                    lambda s_: _place(s_, jobs, j, nodes2),
                                    lambda s_: s_, st)

            st = jax.lax.cond(ok, place, blocked, st)
            return st, processed | _onehot(N, j)

        st, _ = jax.lax.while_loop(cond, body,
                                   (st, jnp.zeros((N,), bool)))
        return st

    def head_mask(st):
        q = st.state == QUEUED
        if preemptive:
            q = q & ~jobs.is_te
        return q

    def be_queue(st: State) -> State:
        def cond(carry):
            st, blocked = carry
            return (~blocked) & head_mask(st).any()

        def body(carry):
            st, _ = carry
            q = head_mask(st)
            j = jnp.argmin(jnp.where(q, st.queue_key, _INF)).astype(jnp.int32)
            ok, nodes = _gang_fit(st.free, jobs.demand[j], jobs.width[j])
            st = jax.lax.cond(ok,
                              lambda s_: _place(s_, jobs, j, nodes),
                              lambda s_: s_, st)
            return st, ~ok

        st, _ = jax.lax.while_loop(cond, body, (st, jnp.asarray(False)))
        return st

    def be_queue_backfill(st: State) -> State:
        """Bounded first-fit backfill (``SchedulerCore.schedule``'s
        beyond-paper branch): walk the BE queue in FIFO order, start
        whatever fits, skip (at most ``backfill_depth``) whatever does
        not — skipped jobs keep their keys and are not revisited this
        pass."""
        depth = jnp.int32(cfg.backfill_depth)

        def cond(carry):
            st, skipped, scanned = carry
            q = head_mask(st) & ~skipped
            return q.any() & (scanned < depth)

        def body(carry):
            st, skipped, scanned = carry
            q = head_mask(st) & ~skipped
            j = jnp.argmin(jnp.where(q, st.queue_key, _INF)).astype(jnp.int32)
            ok, nodes = _gang_fit(st.free, jobs.demand[j], jobs.width[j])
            st = jax.lax.cond(ok,
                              lambda s_: _place(s_, jobs, j, nodes),
                              lambda s_: s_, st)
            return (st, skipped | (~ok & _onehot(N, j)),
                    scanned + (~ok).astype(jnp.int32))

        st, _, _ = jax.lax.while_loop(
            cond, body, (st, jnp.zeros((N,), bool), jnp.int32(0)))
        return st

    def tick(st: State) -> State:
        t = st.t
        # arrivals (queue key = submit-order index; jobs pre-sorted)
        arrive = (jobs.submit <= t) & (st.state == NOT_ARRIVED)
        st = st._replace(
            state=jnp.where(arrive, QUEUED, st.state),
            queue_key=jnp.where(arrive, jnp.arange(N, dtype=jnp.float32),
                                st.queue_key),
        )
        # vacates (grace expired), processed in job-index order
        vac = (st.state == GRACE) & (st.grace_left <= 0)
        rank = jnp.cumsum(vac) - 1
        n_vac = jnp.sum(vac)
        te_dec = jnp.zeros((N,), jnp.int32).at[
            jnp.where(vac, st.victim_of, N)].add(1, mode="drop")
        freed = _gang_release(st.assign, jobs.demand, vac)
        st = st._replace(
            queue_key=jnp.where(vac, st.top_key - rank.astype(jnp.float32),
                                st.queue_key),
            top_key=st.top_key - n_vac.astype(jnp.float32),
            free=st.free + freed,
            pending_free=st.pending_free - freed,
            last_vacate=jnp.where(vac, t, st.last_vacate),
            te_pending=st.te_pending - te_dec,
            victim_of=jnp.where(vac, -1, st.victim_of),
            assign=st.assign & ~vac[:, None],
            state=jnp.where(vac, QUEUED, st.state),
        )
        # schedule
        if preemptive:
            st = te_lane(st)
        st = be_queue_backfill(st) if cfg.backfill else be_queue(st)
        # run one minute
        running = st.state == RUNNING
        remaining = st.remaining - running.astype(jnp.int32)
        fin = running & (remaining <= 0)
        st = st._replace(
            remaining=remaining,
            free=st.free + _gang_release(st.assign, jobs.demand, fin),
            assign=st.assign & ~fin[:, None],
            state=jnp.where(fin, DONE, st.state),
            finish=jnp.where(fin, t + 1, st.finish),
            n_done=st.n_done + jnp.sum(fin),
            grace_left=st.grace_left - (st.state == GRACE).astype(jnp.int32),
            t=t + 1,
        )
        return st

    if time_mode == "tick":
        return tick
    advance = _make_event_advance(jobs, preemptive, N, max_ticks,
                                  cfg.backfill, cfg.backfill_depth)

    def event_step(st: State) -> State:
        return advance(tick(st))

    return event_step


def run(cfg: SimConfig, jobs: Jobs, seed=0,
        max_ticks: int = 1 << 22, s=None, P=None,
        time_mode: str = None) -> State:
    """Run the full simulation; returns the final state.

    ``time_mode`` ("tick" | "event", default ``cfg.time_mode``) selects
    per-quantum stepping vs the event-compressed jump — bit-identical
    States, wall-clock proportional to events instead of makespan."""
    n_nodes = cfg.cluster.n_nodes
    node_cap = cfg.cluster.node.as_tuple()
    step = make_tick(cfg, jobs, n_nodes, s=s, P=P, time_mode=time_mode,
                     max_ticks=max_ticks)
    st = init_state(jobs, n_nodes, node_cap, seed)
    N = jobs.submit.shape[0]

    def cond(st):
        return (st.n_done < N) & (st.t < max_ticks)

    return jax.lax.while_loop(cond, step, st)


@functools.partial(jax.jit, static_argnames=("cfg", "time_mode"))
def run_jit(cfg: SimConfig, jobs: Jobs, seed: int = 0,
            time_mode: str = None) -> State:
    return run(cfg, jobs, seed, time_mode=time_mode)


def state_diff_fields(a: State, b: State) -> list:
    """Names of State fields that differ bitwise — rng keys compared by
    key data. Empty list == full-State bit equality, THE tick-vs-event
    parity contract; the engine benchmark and the parity/property
    suites all share this one definition so a new State field is
    covered everywhere at once."""
    diff = []
    for f in a._fields:
        x, y = getattr(a, f), getattr(b, f)
        if f == "rng":
            x, y = jax.random.key_data(x), jax.random.key_data(y)
        if not bool((np.asarray(x) == np.asarray(y)).all()):
            diff.append(f)
    return diff


def slowdown(jobs: Jobs, st: State) -> jax.Array:
    waiting = st.finish - jobs.submit - jobs.exec_total
    return 1.0 + waiting / jobs.exec_total


def masked_percentiles(vals, mask, ps) -> dict:
    """``{f"p{p}": percentile of vals[mask]}`` — NaN-safe: when the
    mask selects nothing (a trial with zero valid TE or BE jobs after
    sentinel padding, or no preemption ever resumed), every entry is an
    EXPLICIT ``nan`` rather than whatever a reduction over an all-NaN
    slice happens to produce; nan-aware poolers then exclude the trial
    (DESIGN.md §5)."""
    v = jnp.where(mask, vals, jnp.nan)
    some = mask.any()
    return {f"p{p}": jnp.where(some, jnp.nanpercentile(v, p), jnp.nan)
            for p in ps}


def result_summary(jobs: Jobs, st: State) -> dict:
    """Percentile summary mirroring metrics.pooled_tables (jnp).

    Sentinel (padding) rows are masked out of every statistic; empty
    classes (all-BE / all-TE jobsets) yield explicit ``nan`` rows."""
    sd = slowdown(jobs, st)
    te = jobs.is_te & jobs.valid
    be = ~jobs.is_te & jobs.valid
    out = {}
    for name, m in (("TE", te), ("BE", be)):
        out[name] = masked_percentiles(sd, m, (50, 95, 99))
    pre = jnp.where(be, (st.preempt_count > 0).astype(jnp.float32), jnp.nan)
    out["preempted_frac"] = jnp.where(be.any(), jnp.nanmean(pre), jnp.nan)
    iv_mask = (st.last_resume >= 0) & jobs.valid
    out["intervals"] = masked_percentiles(
        (st.last_resume - st.last_signal).astype(jnp.float32),
        iv_mask, (50, 75, 95, 99))
    return out
