"""command-r-35b [dense] — GQA, no-bias decoder.

Source: [hf:CohereForAI/c4ai-command-r-v01].
40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_528,
    vocab=256_000,
    head_dim=128,
    activation="silu",
    norm_eps=1e-5,
    rope_theta=8_000_000.0,
    use_bias=False,
    tie_embeddings=True,
    decode_window=4096,   # beyond-paper SWA decode variant for long_500k
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-smoke",
        family="dense",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        head_dim=16,
        activation="silu",
        norm_eps=1e-5,
        tie_embeddings=True,
        decode_window=64,
    )
