"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP.

Source: Nemotron-4 [arXiv:2402.16819].
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73_728,
    vocab=256_000,
    head_dim=192,
    activation="sq_relu",
    gated_mlp=False,       # Nemotron-4 uses plain squared-ReLU MLP
    norm_eps=1e-5,
    use_bias=False,
    decode_window=4096,   # beyond-paper SWA decode variant for long_500k
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke",
        family="dense",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_ff=512,
        vocab=512,
        head_dim=16,
        activation="sq_relu",
        gated_mlp=False,
        norm_eps=1e-5,
        decode_window=64,
    )
