"""Cluster + workload configuration for the FitGpp simulation (paper §4).

The node shape and the exec-time / GP distributions are from the paper.
The per-class resource-demand distributions are NOT published (paper
Fig. 2 plots a private trace); the values below are our documented
choices for a DL cluster and are treated as sensitivity knobs — the
reproduction targets the paper's *relative* claims (see DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

from repro.configs.base import PAPER_P, PAPER_S


@dataclass(frozen=True)
class NodeSpec:
    """One node: capacities for (CPU cores, RAM GB, GPUs). Paper §4.1."""
    cpu: float = 32.0
    ram: float = 256.0
    gpu: float = 8.0

    def as_tuple(self) -> Tuple[float, float, float]:
        return (self.cpu, self.ram, self.gpu)


@dataclass(frozen=True)
class ClusterSpec:
    n_nodes: int = 84                 # paper §4.1
    node: NodeSpec = field(default_factory=NodeSpec)


@dataclass(frozen=True)
class TruncNormal:
    """Normal(mean, std) truncated to [lo, hi]; sampled by resampling."""
    mean: float
    std: float
    lo: float
    hi: float


@dataclass(frozen=True)
class ClassDists:
    """Per-class (TE or BE) job distributions."""
    exec_min: TruncNormal             # execution time [minutes]
    cpu: TruncNormal
    ram: TruncNormal
    gpu: TruncNormal


@dataclass(frozen=True)
class WorkloadSpec:
    """Synthetic workload per paper §4.2.

    Exec-time means/truncations are the paper's (TE 5'/30', BE 30'/24h).
    Stds are unpublished; we use mean-sized stds. Resource demands are
    our documented choices (TE jobs small, BE jobs larger — consistent
    with the paper's narrative that large-demand victims cause
    head-of-line blocking).
    """
    n_jobs: int = 2 ** 16
    te_fraction: float = 0.30         # paper: ~30% of jobs are TE
    load: float = 2.0                 # FIFO-normalized cluster load
    # Calibrated so the FIFO baseline and the preemptive-policy relative
    # numbers land in the paper's regime (see EXPERIMENTS.md §Repro):
    # TE jobs are short (paper: mean 5', trunc 30') but NOT resource-small
    # (debugging a distributed job needs the same GPUs); BE demands are
    # wide (median 2 GPUs, tail to whole-node).
    te: ClassDists = field(default_factory=lambda: ClassDists(
        exec_min=TruncNormal(5.0, 5.0, 1.0, 30.0),
        cpu=TruncNormal(4.0, 4.0, 1.0, 32.0),
        ram=TruncNormal(16.0, 16.0, 1.0, 256.0),
        gpu=TruncNormal(5.0, 2.5, 0.0, 8.0),
    ))
    be: ClassDists = field(default_factory=lambda: ClassDists(
        exec_min=TruncNormal(30.0, 30.0, 3.0, 1440.0),
        cpu=TruncNormal(8.0, 6.0, 1.0, 32.0),
        ram=TruncNormal(48.0, 48.0, 1.0, 256.0),
        gpu=TruncNormal(3.0, 2.5, 0.0, 8.0),
    ))
    # GPU requests snap to the allocation granularity DL users actually
    # ask for; this is what packs nodes tightly enough that TE arrivals
    # need preemption at all (see EXPERIMENTS.md §Repro).
    gpu_quanta: Tuple[float, ...] = (0.0, 1.0, 2.0, 4.0, 8.0)
    # GP ~ N(3, 3) truncated [0, 20] minutes (paper: mean 3, trunc 20).
    gp_min: TruncNormal = field(
        default_factory=lambda: TruncNormal(3.0, 3.0, 0.0, 20.0))
    gp_scale: float = 1.0             # Fig. 7 sweeps {1, 2, 4, 8}
    # BEYOND-PAPER (paper future work: "multi-node jobs in distributed
    # DL"): fraction of jobs that are gangs, widths drawn from
    # multi_node_widths. 0.0 = the paper's single-task model.
    multi_node_frac: float = 0.0
    multi_node_widths: Tuple[int, ...] = (2, 4)

    def scaled_gp(self) -> TruncNormal:
        s = self.gp_scale
        g = self.gp_min
        return TruncNormal(g.mean * s, g.std * s, g.lo, g.hi * s)


@dataclass(frozen=True)
class SimConfig:
    cluster: ClusterSpec = field(default_factory=ClusterSpec)
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    policy: str = "fitgpp"            # any registered policy name
    s: float = PAPER_S                # Eq. 3 GP weight
    max_preemptions: int = PAPER_P    # P (paper uses 1; Fig. 5 sweeps)
    seed: int = 0
    tick_minutes: float = 1.0
    # Time advancement for BOTH engines: "event" (default) jumps the
    # clock over provably no-op ticks (bit-exact with "tick"; reference
    # engine DESIGN.md §4, JAX engine §7). The JAX engine's jump is
    # per-lane under vmap, so ragged/heterogeneous sweeps stay exact.
    time_mode: str = "event"
    # Score-policy backend for the JAX engine: "jnp" runs Eq. 1-4 as
    # plain jnp; "pallas" fuses score + masked argmin on the policy's
    # registered TPU kernel (fitgpp only; parity-tested, needs static s).
    score_backend: str = "jnp"
    # BEYOND-PAPER (the paper's "non-FIFO settings" future work): allow
    # queued BE jobs behind a blocked head to start when they fit
    # (first-fit backfill, bounded scan depth). FIFO arrival order is
    # still the primary key; this only relaxes head-of-line blocking.
    backfill: bool = False
    backfill_depth: int = 64

    def __post_init__(self):
        # Fail at construction time, naming the registered policies —
        # not deep inside make_tick (lazy import: no cycle, and plain
        # cluster/workload configs never touch the registry).
        from repro.core.policy_registry import validate_config
        validate_config(self.policy, self.s, self.max_preemptions,
                        self.score_backend)
        if self.time_mode not in ("tick", "event"):
            raise ValueError(f"unknown time_mode {self.time_mode!r}; "
                             "one of ('tick', 'event')")


PAPER_SIM = SimConfig()
