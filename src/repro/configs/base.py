"""Config dataclasses for models, input shapes, and parallelism plans —
plus the paper's scheduling defaults (single source).

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exposing
``CONFIG: ModelConfig`` (the exact published shape, cited) plus
``smoke_config()`` (a reduced variant of the same family for CPU tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# The paper's FitGpp defaults (§4.3). Single source of truth — SimConfig,
# the policy classes, the live controller and the Pallas kernel wrappers
# all take their defaults from here; do not repeat the literals.
PAPER_S = 4.0       # Eq. 3 grace-period weight s
PAPER_P = 1         # per-job preemption cap P (Fig. 5 sweeps it)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config."""
    num_experts: int
    top_k: int
    d_expert: int                     # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance loss weight


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD config (arXiv:2405.21060)."""
    d_state: int = 128
    d_conv: int = 4
    head_dim: int = 64                # P in the SSD paper
    n_groups: int = 1                 # B/C groups
    expand: int = 2                   # d_inner = expand * d_model
    chunk: int = 256                  # SSD chunk length


@dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU / Griffin recurrent block config (arXiv:2402.19427)."""
    lru_width: int = 0                # 0 -> d_model
    d_conv: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")  # 1:2 attn:rec
    local_window: int = 2048


@dataclass(frozen=True)
class EncoderConfig:
    """Frontend-consuming encoder (whisper) — transformer backbone only.

    The modality frontend (mel+conv / ViT) is a STUB: ``input_specs``
    provides precomputed frame/patch embeddings of shape
    (batch, n_frontend_tokens, d_frontend).
    """
    n_layers: int
    n_heads: int
    d_ff: int
    n_frontend_tokens: int            # 1500 frames (whisper) / patches
    d_frontend: int                   # embedding dim provided by the stub


@dataclass(frozen=True)
class VLMConfig:
    """VLM prefix config — vision tower is a STUB providing embeddings."""
    n_visual_tokens: int = 256
    d_visual: int = 1024              # projector input dim (stub output)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    source: str                       # citation

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    activation: str = "silu"          # silu | gelu | sq_relu
    gated_mlp: bool = True            # SwiGLU/GeGLU vs plain 2-matrix MLP
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    use_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0        # 0 = disabled (gemma-style cap)

    # Attention variants
    window: int = 0                   # 0 = full attention; >0 = native SWA
    decode_window: int = 0            # beyond-paper SWA decode variant used
                                      # only for long_500k on full-attn archs

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    encoder: Optional[EncoderConfig] = None
    vlm: Optional[VLMConfig] = None

    dtype: str = "bfloat16"           # compute/params dtype for dry-run
    remat: str = "full"               # none | full | dots

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class ParallelismPlan:
    """How to lay a (model × shape) onto the mesh.

    Logical sharding rules; ``sharding/plans.py`` turns these into
    PartitionSpecs. ``fsdp`` shards weight major dims over the data (+pod)
    axes on top of tensor parallelism over ``model``.
    """
    batch_axes: Tuple[str, ...] = ("pod", "data")   # axes sharding batch
    tp_axis: str = "model"
    fsdp: bool = False                # weights also sharded over batch axes
    seq_axis: Optional[str] = None    # decode: shard KV cache seq dim
    expert_axis: Optional[str] = None # MoE experts sharded over this axis
    opt_dtype: str = "float32"        # adam moments dtype
    remat: str = "full"
