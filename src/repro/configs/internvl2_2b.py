"""internvl2-2b [vlm] — InternViT (STUB frontend) + InternLM2 decoder.

Source: InternVL2 [arXiv:2404.16821].
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92_553,
    activation="silu",
    decode_window=4096,   # beyond-paper SWA decode variant for long_500k
    vlm=VLMConfig(n_visual_tokens=256, d_visual=1024),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        activation="silu",
        decode_window=64,
        vlm=VLMConfig(n_visual_tokens=16, d_visual=64),
    )
