"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

Source: Mamba-2 [arXiv:2405.21060].
48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128.
d_inner = 2*d_model = 4096, head_dim 64 -> 64 SSD heads.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=2048,
    n_heads=64,            # SSD heads = d_inner / head_dim
    n_kv_heads=64,
    d_ff=0,                # attention-free, no separate MLP block
    vocab=50_280,
    head_dim=64,
    activation="silu",
    norm_eps=1e-5,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, head_dim=64, n_groups=1,
                  expand=2, chunk=256),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=4,             # d_inner 256 / head_dim 64
        n_kv_heads=4,
        d_ff=0,
        vocab=512,
        head_dim=64,
        activation="silu",
        norm_eps=1e-5,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=32, d_conv=4, head_dim=64, n_groups=1,
                      expand=2, chunk=32),
    )
