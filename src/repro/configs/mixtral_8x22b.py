"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

Source: Mixtral [arXiv:2401.04088].
56L d_model=6144 48H (GQA kv=8) d_expert=16384 vocab=32768.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,           # per-expert hidden
    vocab=32_768,
    head_dim=128,
    activation="silu",
    rope_theta=1_000_000.0,
    window=4096,           # native SWA -> long_500k runs natively
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=16_384,
                  capacity_factor=1.25, router_aux_weight=0.01),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke",
        family="moe",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        head_dim=32,
        activation="silu",
        window=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128,
                      capacity_factor=1.5, router_aux_weight=0.01),
    )
