"""stablelm-12b [dense] — GQA decoder.

Source: [hf:stabilityai/stablelm-2-1_6b] (family scaled to 12B).
40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13_824,
    vocab=100_352,
    head_dim=160,
    activation="silu",
    norm_eps=1e-5,
    use_bias=False,
    decode_window=4096,   # beyond-paper SWA decode variant for long_500k
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        family="dense",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        head_dim=32,
        activation="silu",
        norm_eps=1e-5,
        decode_window=64,
    )
