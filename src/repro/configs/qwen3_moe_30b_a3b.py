"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8.

Source: [hf:Qwen/Qwen3-30B-A3B].
48L d_model=2048 32H (GQA kv=4) d_expert=768 vocab=151936, head_dim 128.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,              # per-expert hidden (moe_intermediate_size)
    vocab=151_936,
    head_dim=128,
    activation="silu",
    rope_theta=1_000_000.0,
    decode_window=4096,    # beyond-paper SWA decode variant for long_500k
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768,
                  capacity_factor=1.25, router_aux_weight=0.001),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab=512,
        head_dim=32,
        activation="silu",
        decode_window=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      capacity_factor=1.5, router_aux_weight=0.001),
    )
