"""mistral-large-123b [dense] — GQA decoder.

Source: [hf:mistralai/Mistral-Large-Instruct-2407].
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    n_layers=88,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28_672,
    vocab=32_768,
    head_dim=128,
    activation="silu",
    norm_eps=1e-5,
    rope_theta=1_000_000.0,
    use_bias=False,
    decode_window=4096,   # beyond-paper SWA decode variant for long_500k
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke",
        family="dense",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        head_dim=16,
        activation="silu",
        norm_eps=1e-5,
        decode_window=64,
    )
