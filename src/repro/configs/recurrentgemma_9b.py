"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 rec : 1 attn.

Source: Griffin / RecurrentGemma [arXiv:2402.19427].
38L d_model=4096 16H (GQA kv=1 = MQA) d_ff=12288 vocab=256000.
"""
from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256_000,
    head_dim=256,
    activation="gelu",
    tie_embeddings=True,
    logit_softcap=30.0,
    recurrent=RecurrentConfig(
        lru_width=4096,
        d_conv=4,
        block_pattern=("rec", "rec", "attn"),
        local_window=2048,
    ),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        source=CONFIG.source,
        n_layers=3,                       # one full (rec, rec, attn) group
        d_model=128,
        n_heads=4,
        n_kv_heads=1,
        d_ff=256,
        vocab=512,
        head_dim=32,
        activation="gelu",
        tie_embeddings=True,
        logit_softcap=30.0,
        recurrent=RecurrentConfig(
            lru_width=128, d_conv=4,
            block_pattern=("rec", "rec", "attn"), local_window=64,
        ),
    )
