"""Config registry: ``--arch <id>`` resolution for all assigned archs."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    EncoderConfig,
    InputShape,
    ModelConfig,
    MoEConfig,
    ParallelismPlan,
    RecurrentConfig,
    SSMConfig,
    VLMConfig,
)

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-2b": "internvl2_2b",
    "mamba2-1.3b": "mamba2_1_3b",
    "command-r-35b": "command_r_35b",
    "whisper-large-v3": "whisper_large_v3",
    "stablelm-12b": "stablelm_12b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "nemotron-4-340b": "nemotron_4_340b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mistral-large-123b": "mistral_large_123b",
}

# (arch, shape) pairs that are skipped by design; see DESIGN.md §9.
SHAPE_SKIPS = {
    ("whisper-large-v3", "long_500k"):
        "enc-dec ASR; decoder capped at 448 tokens — 524k-token decode "
        "context is meaningless for the family",
}


def list_archs() -> List[str]:
    return sorted(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {list_archs()}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    """Full published config for ``--arch <id>``."""
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    return _module(arch).smoke_config()


def shape_is_supported(arch: str, shape: str) -> bool:
    return (arch, shape) not in SHAPE_SKIPS
