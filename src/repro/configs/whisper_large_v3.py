"""whisper-large-v3 [audio] — encoder-decoder; mel+conv frontend STUBBED.

Source: Whisper [arXiv:2212.04356].
Decoder: 32L d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866.
Encoder: 32L transformer backbone over 1500 precomputed frame embeddings
(the conv feature extractor is the one allowed stub; ``input_specs``
provides (batch, 1500, 1280) frame embeddings).

``long_500k`` is SKIPPED for this arch (see DESIGN.md §9): the decoder is
architecturally capped at 448 tokens and the family has no long-context
decode mode.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51_866,
    head_dim=64,
    activation="gelu",
    gated_mlp=False,       # Whisper uses a plain GELU MLP
    norm_eps=1e-5,
    use_bias=True,
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=32, n_heads=20, d_ff=5120,
                          n_frontend_tokens=1500, d_frontend=1280),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        source=CONFIG.source,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        head_dim=32,
        activation="gelu",
        norm_eps=1e-5,
        use_bias=True,
        tie_embeddings=True,
        encoder=EncoderConfig(n_layers=2, n_heads=4, d_ff=256,
                              n_frontend_tokens=24, d_frontend=128),
    )
