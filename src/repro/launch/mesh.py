"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization and only then calls ``make_production_mesh``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) ("data", "model") single pod; (2, 16, 16) with a leading
    "pod" axis for the 512-chip two-pod deployment."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh over the real local device (CPU smoke / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
