"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization and only then calls ``make_production_mesh``.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) ("data", "model") single pod; (2, 16, 16) with a leading
    "pod" axis for the 512-chip two-pod deployment."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """(n_devices, 1) ("data", "model") mesh over whatever devices the
    local runtime actually has — one CPU device on the smoke container,
    all of them under ``--xla_force_host_platform_device_count`` or on
    a real multi-chip host — instead of assuming a topology."""
    return jax.make_mesh((len(jax.devices()), 1), ("data", "model"))


def mesh_for_sweep(n_trials: Optional[int] = None,
                   devices: Optional[int] = None,
                   axis: str = "data"):
    """1-D trial mesh for the sweep fabric (DESIGN.md §11), or ``None``
    for the single-device fallback.

    Picks ``min(devices or all-local-devices, n_trials)`` devices on a
    1-D ``(axis,)`` mesh. The fallback to single-device (fewer devices
    present than requested, or only one available when more were asked
    for) is LOUD — a ``UserWarning`` — never silent, so a sweep that
    was meant to shard can't quietly run 8x slower. ``None`` (rather
    than a 1-device mesh) tells ``sweep_fabric.run_table`` to skip
    ``shard_map`` entirely; results are bit-identical either way."""
    avail = len(jax.devices())
    want = avail if devices is None else int(devices)
    if want > avail:
        warnings.warn(
            f"mesh_for_sweep: {want} devices requested but only {avail} "
            f"present; falling back to {avail}", stacklevel=2)
        want = avail
    if n_trials is not None:
        want = min(want, max(int(n_trials), 1))
    if want <= 1:
        if devices is not None and devices > 1:
            warnings.warn(
                "mesh_for_sweep: falling back to SINGLE-DEVICE vmap "
                f"(requested {devices} devices, usable {want})",
                stacklevel=2)
        return None
    return jax.make_mesh((want,), (axis,))


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
