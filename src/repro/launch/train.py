"""Training driver: end-to-end train loop for any ``--arch``.

Examples
  # CPU smoke (reduced config, real steps):
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b \
      --smoke --steps 50

  # Production lowering check for the full config on the pod mesh is
  # ``python -m repro.launch.dryrun``; this driver runs REAL steps on
  # the devices that exist.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import models, trainer
from repro.configs import get_config, get_smoke_config, list_archs
from repro.data import make_batch
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.sharding import plans
from repro.configs.base import InputShape


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps)
    print(f"arch={cfg.name} params={models.count_params(cfg) / 1e6:.1f}M "
          f"devices={jax.device_count()}")

    mesh = make_local_mesh()
    shape = InputShape("cli", args.seq_len, args.batch, "train")
    plan = plans.arch_plan(cfg, shape, mesh)
    state = trainer.init_train_state(cfg, ocfg, jax.random.key(args.seed))
    step_fn = jax.jit(trainer.make_train_step(cfg, ocfg, args.microbatches),
                      donate_argnums=(0,))

    t0 = time.time()
    for i in range(args.steps):
        batch = make_batch(cfg, args.batch, args.seq_len, args.seed, i)
        state, m = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {int(m['step']):5d}  loss {float(m['loss']):.4f}  "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    print("done")


if __name__ == "__main__":
    main()
