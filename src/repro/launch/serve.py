"""Serving driver: prefill a batch of prompts, then decode tokens.

CPU smoke example:
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b \
      --smoke --prompt-len 32 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import get_config, get_smoke_config, list_archs
from repro.data import make_batch


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = models.init(cfg, jax.random.key(args.seed))
    batch = make_batch(cfg, args.batch, args.prompt_len, args.seed, 0)
    total = args.prompt_len + args.decode_steps

    t0 = time.time()
    logits, cache = models.prefill(cfg, params, batch, pad_to=total)
    print(f"prefill({args.prompt_len} tokens x{args.batch}) "
          f"{time.time() - t0:.2f}s")

    step = jax.jit(lambda p, c, t: models.serve_step(cfg, p, c, t))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.decode_steps):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.decode_steps} steps in {dt:.2f}s "
          f"({dt / args.decode_steps * 1e3:.1f} ms/token)")
    print("sample token ids:", toks[0].tolist())


if __name__ == "__main__":
    main()
