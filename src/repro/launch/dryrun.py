import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before ANY other import (jax locks the
# device count at first init). Everything below may import jax.

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro import models, trainer                     # noqa: E402
from repro.configs import (INPUT_SHAPES, SHAPE_SKIPS, get_config,  # noqa: E402
                           list_archs, shape_is_supported)
from repro.launch import roofline as rf               # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402
from repro.optim import AdamWConfig                   # noqa: E402
from repro.sharding import plans                      # noqa: E402

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh).

For each combination this produces
  * memory_analysis()  — per-device bytes (args/outputs/temps): the
    "does it fit" evidence,
  * cost_analysis()    — raw XLA FLOPs/bytes (loop bodies counted once;
    see roofline.py),
  * parsed collective traffic (loop-multiplicity corrected), and
  * the three roofline terms,
written as JSON artifacts under experiments/dryrun/.
"""


def variant_config(arch: str, shape_name: str):
    """Apply the long_500k sliding-window decode variant where needed."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.window == 0 and cfg.decode_window:
        cfg = cfg.replace(window=cfg.decode_window)
    return cfg


def lower_one(arch: str, shape_name: str, mesh, *, compile_: bool = True):
    """-> result dict (raises on lowering/compile failure)."""
    cfg = variant_config(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    plan = plans.arch_plan(cfg, shape, mesh)
    cfg = cfg.replace(remat=plan.remat)       # plan controls remat policy
    from repro.sharding import constraints
    constraints.set_strategy(plan.strategy)
    ocfg = AdamWConfig(moment_dtype=plan.opt_dtype)
    t0 = time.time()

    if shape.kind == "train":
        state_abs = trainer.abstract_train_state(cfg, ocfg)
        batch_abs = models.input_specs(cfg, shape.global_batch,
                                       shape.seq_len, "train")
        state_sh = plans.train_state_sharding(cfg, plan, mesh, state_abs)
        batch_sh = plans.batch_sharding(batch_abs, plan, mesh)
        fn = trainer.make_train_step(cfg, ocfg, plan.microbatches)
        with mesh:
            lowered = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                              donate_argnums=(0,)).lower(state_abs, batch_abs)
    elif shape.kind == "prefill":
        params_abs = models.abstract_params(cfg)
        batch_abs = models.input_specs(cfg, shape.global_batch,
                                       shape.seq_len, "prefill")
        p_sh = plans.param_sharding(cfg, plan, mesh)
        b_sh = plans.batch_sharding(batch_abs, plan, mesh)

        def prefill_fn(params, batch):
            return models.prefill(cfg, params, batch)

        with mesh:
            lowered = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh)) \
                .lower(params_abs, batch_abs)
    else:  # decode
        params_abs = models.abstract_params(cfg)
        cache_abs = models.init_decode_cache(cfg, shape.global_batch,
                                             shape.seq_len, abstract=True)
        tok_abs = models.input_specs(cfg, shape.global_batch, shape.seq_len,
                                     "decode")
        p_sh = plans.param_sharding(cfg, plan, mesh)
        c_sh = plans.cache_sharding(cfg, plan, mesh, cache_abs)
        t_sh = plans.batch_sharding(tok_abs, plan, mesh)

        def decode_fn(params, cache, batch):
            return models.serve_step(cfg, params, cache, batch["tokens"])

        with mesh:
            lowered = jax.jit(decode_fn, in_shardings=(p_sh, c_sh, t_sh),
                              donate_argnums=(1,)) \
                .lower(params_abs, cache_abs, tok_abs)
    lower_s = time.time() - t0

    n_chips = mesh.size
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_chips": n_chips, "kind": shape.kind,
        "microbatches": plan.microbatches, "opt_dtype": plan.opt_dtype,
        "strategy": plan.strategy,
        "lower_s": round(lower_s, 1),
    }
    if not compile_:
        return result

    t0 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    result["memory"] = {
        "argument_gb": ma.argument_size_in_bytes / 1e9,
        "output_gb": ma.output_size_in_bytes / 1e9,
        "alias_gb": ma.alias_size_in_bytes / 1e9,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "peak_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                    - ma.alias_size_in_bytes + ma.temp_size_in_bytes) / 1e9,
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0] if ca else {}
    result["cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }
    coll = rf.collective_bytes(compiled.as_text())
    details = coll.pop("_details", [])
    result["collectives"] = coll
    result["top_collectives"] = [
        {"gb": b / 1e9, "kind": kind, "mult": m, "op": line[:120]}
        for b, kind, m, line in details[:8]]

    shape_obj = INPUT_SHAPES[shape_name]
    r = rf.roofline(variant_config(arch, shape_name), shape_obj, n_chips,
                    coll["total"], float(ca.get("flops", 0.0)))
    result["roofline"] = {
        "compute_s": r.compute_s, "memory_s": r.memory_s,
        "collective_s": r.collective_s, "dominant": r.dominant,
        "model_flops": r.model_flops, "analytic_flops": r.analytic_flops,
        "hlo_flops_raw_per_device": r.hlo_flops_raw,
        "useful_ratio": r.useful_ratio,
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see configs.list_archs)")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-compile", action="store_true",
                    help="lower only (fast structural check)")
    args = ap.parse_args(argv)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape_name in shapes:
                if not shape_is_supported(arch, shape_name):
                    print(f"SKIP  {arch} × {shape_name}: "
                          f"{SHAPE_SKIPS[(arch, shape_name)]}")
                    continue
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                try:
                    res = lower_one(arch, shape_name, mesh,
                                    compile_=not args.no_compile)
                    path = os.path.join(args.out, tag + ".json")
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    mem = res.get("memory", {})
                    roof = res.get("roofline", {})
                    print(f"OK    {tag}  lower={res['lower_s']}s "
                          f"compile={res.get('compile_s', '-')}s "
                          f"peak={mem.get('peak_gb', 0):.1f}GB "
                          f"dominant={roof.get('dominant', '-')}")
                except Exception as e:                      # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"FAIL  {tag}: {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        sys.exit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
