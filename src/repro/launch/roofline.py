"""Roofline-term extraction from a compiled dry-run.

Three terms per (arch × shape × mesh), all in seconds (v5e constants):

  compute    = FLOPs        / (chips * 197e12)
  memory     = HBM bytes    / (chips * 819e9)
  collective = link bytes   / (chips * 50e9)

Sources:
  * collective bytes — parsed from the compiled HLO, with while-loop
    bodies multiplied by their ``known_trip_count`` (XLA's own
    cost_analysis counts loop bodies ONCE, which would undercount the
    per-layer TP collectives inside the layer scan by n_layers).
  * FLOPs / HBM bytes — analytic per-arch model (below), cross-checked
    against ``cost_analysis()`` on an unrolled lowering (REPRO_UNROLL_
    SCANS=1) at small scale; the raw (loop-undercounted) cost_analysis
    numbers are reported alongside for transparency.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs.base import InputShape, ModelConfig

# TPU v5e, from the brief
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# traffic multiplier per collective kind (ring algorithms, large-n limit)
_COLLECTIVE_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# computation header: "%name (params...) -> result {" — params may contain
# nested parens (tuples), so match only the leading name
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-_]+), body=%?([\w.\-_]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-_]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-device collective traffic (bytes), loop-multiplicity aware.

    Returns {op_kind: bytes, "total": bytes}.
    """
    # 1) split into computations (headers are non-indented "name (..) {"
    # lines; bodies are indented and end with a bare "}")
    current = None
    comps = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        is_header = (line and not line[0].isspace()
                     and stripped.endswith("{")
                     and (stripped.startswith("%")
                          or stripped.startswith("ENTRY")))
        m = _COMP_RE.match(stripped) if is_header else None
        if m:
            current = m.group(1)
            comps[current] = []
        elif current is not None:
            comps[current].append(line)
        if stripped == "}":
            current = None

    # 2) multiplicity via while trip counts (+ calls), fixed-point
    entry = None
    for name in comps:
        if "main" in name or name.startswith("jit_"):
            entry = entry or name
    mult = {name: 0.0 for name in comps}
    if entry is None and comps:
        entry = next(iter(comps))
    mult[entry] = 1.0
    edges = []   # (parent, child, factor)
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                edges.append((name, wm.group(2), float(trip)))
                edges.append((name, wm.group(1), float(trip) + 1))
                continue
            cm = _CALL_RE.search(line)
            if cm:
                edges.append((name, cm.group(1), 1.0))
    for _ in range(32):   # DAG depth bound
        changed = False
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        for parent, child, f in edges:
            if parent in mult and child in new:
                new[child] += mult[parent] * f
        if any(abs(new[k] - mult[k]) > 1e-9 for k in mult):
            mult = new
            changed = True
        if not changed:
            break

    # 3) collect collectives
    out = {k: 0.0 for k in _COLLECTIVE_FACTOR}
    details = []
    raw_total = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        for line in lines:
            for kind, factor in _COLLECTIVE_FACTOR.items():
                # match "= shape kind(" but not -done ops (avoid double
                # counting start/done pairs)
                if re.search(rf"=\s+\S+\s+{kind}(-start)?\(", line):
                    b = _shape_bytes(line.split("=", 1)[1]
                                     .split("(", 1)[0]) * factor * m
                    raw_total += b
                    # CPU-backend artifact: XLA upcasts bf16 matmuls to
                    # f32 (no native bf16 on host) and SPMD then moves
                    # collectives after the convert. On TPU these run in
                    # bf16 — halve f32 collectives fed by a convert.
                    if " f32[" in line.split("=", 1)[1].split("(")[0] and \
                            "convert" in line.split("(", 1)[1]:
                        b *= 0.5
                    out[kind] += b
                    details.append((b, kind, m, line.strip()[:160]))
                    break
    out["total"] = sum(out.values())
    out["total_raw_f32"] = raw_total
    out["_details"] = sorted(details, reverse=True)
    return out


# ---------------------------------------------------------------------------
# Analytic FLOPs / HBM bytes
# ---------------------------------------------------------------------------

def _matmul_params(cfg: ModelConfig) -> Tuple[float, float]:
    """-> (active matmul params per token, total params)."""
    from repro import models
    total = models.count_params(cfg)
    embed = cfg.vocab * cfg.d_model
    active = total - embed            # embed lookup is a gather
    if cfg.family == "moe":
        m = cfg.moe
        per_ffn = cfg.d_model * m.d_expert * (3 if cfg.gated_mlp else 2)
        inactive = cfg.n_layers * per_ffn * (m.num_experts - m.top_k)
        active -= inactive
    if not cfg.tie_embeddings:
        pass                          # out_head already in total
    else:
        active += cfg.vocab * cfg.d_model   # tied unembed matmul
    return float(active), float(total)


def _attn_flops_per_token(cfg: ModelConfig, kv_len: float) -> float:
    """QK^T + PV flops for one query token against kv_len keys."""
    if cfg.family == "ssm":
        s = cfg.ssm
        H = (s.expand * cfg.d_model) // s.head_dim
        # intra-chunk dual form + state update/read
        return 2.0 * H * (s.chunk * (s.d_state + s.head_dim)
                          + 2 * s.head_dim * s.d_state)
    attn_layers = cfg.n_layers
    win = cfg.window
    if cfg.family == "hybrid":
        pat = cfg.recurrent.block_pattern
        attn_layers = cfg.n_layers * pat.count("attn") / len(pat)
        win = cfg.recurrent.local_window
        R = cfg.recurrent.lru_width or cfg.d_model
        rec_layers = cfg.n_layers - attn_layers
        rec = rec_layers * 6.0 * R          # RG-LRU elementwise recurrence
    else:
        rec = 0.0
    eff = min(kv_len, win) if win > 0 else kv_len
    return 4.0 * attn_layers * eff * cfg.n_heads * cfg.head_dim + rec


def analytic_costs(cfg: ModelConfig, shape: InputShape) -> Dict[str, float]:
    """Global (all-chips) FLOPs and HBM bytes for ONE step of this shape.

    train: fwd + bwd (2x) + full-remat recompute (~1x) = 4x matmul fwd.
    decode: one token per sequence against the cache.
    Returns MODEL_FLOPS (6·N_active·D, the "useful" number) separately.
    """
    active, total = _matmul_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    p_bytes = 2.0                      # bf16 params
    if shape.kind == "train":
        tokens = float(B) * S
        mm = 2.0 * active * tokens * 4.0          # fwd+bwd+remat
        # causal attention: mean kv_len = S/2 (fwd), x4 train multiplier
        at = _attn_flops_per_token(cfg, S / 2) * tokens * 4.0
        flops = mm + at
        model_flops = 6.0 * active * tokens
        # params fwd+bwd + grads + adam m/v read+write (f32-equivalents)
        opt_bytes = 2 * total * 4.0
        hbm = (2 * total * p_bytes            # fwd + bwd param reads
               + total * 4.0                  # grad write (f32)
               + 2 * opt_bytes                # moments read + write
               + tokens * cfg.d_model * p_bytes * cfg.n_layers * 2)  # acts
    elif shape.kind == "prefill":
        tokens = float(B) * S
        flops = 2.0 * active * tokens + \
            _attn_flops_per_token(cfg, S / 2) * tokens
        model_flops = 2.0 * active * tokens
        hbm = total * p_bytes + tokens * cfg.d_model * p_bytes * \
            cfg.n_layers * 2
    else:   # decode: ONE new token, cache of seq_len
        tokens = float(B)
        kv_len = float(S)
        flops = 2.0 * active * tokens + \
            _attn_flops_per_token(cfg, kv_len) * tokens
        model_flops = 2.0 * active * tokens
        hbm = total * p_bytes + _cache_bytes(cfg, B, S)
    return {"flops": flops, "model_flops": model_flops, "hbm_bytes": hbm}


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    if cfg.family == "ssm":
        s = cfg.ssm
        H = (s.expand * cfg.d_model) // s.head_dim
        return 2.0 * cfg.n_layers * B * H * s.head_dim * s.d_state * 2
    win = cfg.window or (cfg.decode_window if S > 65536 else 0)
    eff = min(S, win) if win > 0 else S
    kv = 2.0 * cfg.n_layers * B * eff * cfg.n_kv_heads * cfg.head_dim * 2
    if cfg.family == "hybrid":
        pat = cfg.recurrent.block_pattern
        kv *= pat.count("attn") / len(pat)
        R = cfg.recurrent.lru_width or cfg.d_model
        kv += 2.0 * cfg.n_layers * pat.count("rec") / len(pat) * B * R * 2
    return kv


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_raw: float          # cost_analysis (loops counted once)
    analytic_flops: float
    dominant: str
    useful_ratio: float           # MODEL_FLOPS / analytic FLOPs


def roofline(cfg: ModelConfig, shape: InputShape, n_chips: int,
             coll_bytes_per_device: float,
             hlo_flops_raw: float) -> Roofline:
    a = analytic_costs(cfg, shape)
    compute_s = a["flops"] / (n_chips * PEAK_FLOPS)
    memory_s = a["hbm_bytes"] / (n_chips * HBM_BW)
    collective_s = coll_bytes_per_device / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=a["model_flops"], hlo_flops_raw=hlo_flops_raw,
        analytic_flops=a["flops"], dominant=dominant,
        useful_ratio=a["model_flops"] / max(a["flops"], 1.0),
    )
