"""AdamW on pytrees, with a configurable moment dtype.

``moment_dtype='bfloat16'`` halves optimizer-state HBM — that is what
lets nemotron-4-340b fit a 256-chip pod (see sharding/plans.py); updates
are still computed in f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"


def adamw_init(params: Params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads: Params, opt_state, params: Params,
                 cfg: AdamWConfig) -> Tuple[Params, Any]:
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + g * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mf.astype(mdt), vf.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
