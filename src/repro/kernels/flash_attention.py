"""Blocked GQA flash attention — Pallas TPU kernel.

TPU-native design (not a CUDA port): the (batch × kv_head) pairs and the
query blocks form the parallel grid dims; the KV axis is the innermost
``arbitrary`` (sequential) dim, with the online-softmax running max /
normalizer / accumulator carried across KV steps in VMEM scratch. All
matmuls are MXU-shaped (block_q × head_dim × block_k, 128-aligned), and
each grid step touches only VMEM-resident blocks declared by BlockSpecs.

Supports causal and sliding-window masking plus gemma-style logit
softcap; grouped queries (G = H/KV) ride along in the q block so MQA
archs (recurrentgemma, kv=1) keep full MXU occupancy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, softcap: float, sm_scale: float,
            block_q: int, block_k: int, q_offset: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                       # (bq, G, hd)
    k = k_ref[0]                       # (bk, hd)
    v = v_ref[0]
    bq, G, hd = q.shape

    s = jax.lax.dot_general(
        q.reshape(bq * G, hd), k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (bq*G, bk)
    s = s * sm_scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    qi = pl.program_id(1)
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (bq, G), 0) + q_offset              # (bq, G)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)                    # (1, bk)
    qpos_f = qpos.reshape(bq * G, 1)
    mask = jnp.ones((bq * G, block_k), bool)
    if causal:
        mask &= kpos <= qpos_f
    if window > 0:
        mask &= kpos > qpos_f - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...].reshape(bq * G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)       # (bq*G, bk)
    l_new = l_scr[...].reshape(bq * G, 1) * alpha + \
        jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (bq*G, hd)
    acc = acc_scr[...].reshape(bq * G, hd) * alpha + pv

    m_scr[...] = m_new.reshape(bq, G)
    l_scr[...] = l_new.reshape(bq, G)
    acc_scr[...] = acc.reshape(bq, G, hd)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...].reshape(bq * G, 1)
        out = acc_scr[...].reshape(bq * G, hd) / jnp.maximum(l, 1e-30)
        o_ref[0] = out.reshape(bq, G, hd).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False,
                    q_offset: int = None) -> jax.Array:
    """q (B, Sq, H, hd); k/v (B, Skv, KV, hd) -> (B, Sq, H, hd).

    Query i is at absolute position (q_offset + i); by default
    q_offset = Skv - Sq (queries are the LAST Sq positions), matching
    ref.py. ops.py overrides it to preserve alignment under padding.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    if q_offset is None:
        q_offset = Skv - Sq

    # (B, Sq, KV, G, hd) -> (B*KV, Sq, G, hd)
    qz = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B * KV, Sq, G, hd)
    kz = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vz = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)

    grid = (B * KV, Sq // bq, Skv // bk)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap,
        sm_scale=hd ** -0.5, block_q=bq, block_k=bk, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, G, hd), lambda z, qi, ki: (z, qi, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda z, qi, ki: (z, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda z, qi, ki: (z, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, G, hd), lambda z, qi, ki: (z, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, Sq, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, G), jnp.float32),
            pltpu.VMEM((bq, G), jnp.float32),
            pltpu.VMEM((bq, G, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qz, kz, vz)

    return out.reshape(B, KV, Sq, G, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Sq, H, hd)
