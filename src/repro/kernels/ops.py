"""Jit'd dispatch wrappers for the Pallas kernels.

Handles padding to block multiples, dtype plumbing, and backend
selection: on the CPU container the kernels execute in interpret mode
(the kernel body runs as traced Python — bit-accurate semantics, no
Mosaic); on TPU they compile natively. Set REPRO_PALLAS_INTERPRET=0/1 to
force either way.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.configs.base import PAPER_S
from repro.kernels import flash_attention as _fa
from repro.kernels import lru_scan as _ls
from repro.kernels import schedule_step as _ss
from repro.kernels import ssd_chunk as _sc


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "")
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = _fa.DEFAULT_BLOCK_Q,
                    block_k: int = _fa.DEFAULT_BLOCK_K):
    """GQA flash attention; pads Sq/Skv to block multiples.

    Query i sits at absolute position Skv - Sq + i (see kernel docs).
    KV padding is appended AFTER the queries, so causal masking makes the
    padded keys unreachable; padded query rows are sliced off.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    qp, _ = _pad_to(q, 1, block_q)
    kp, _ = _pad_to(k, 1, block_k)
    vp, _ = _pad_to(v, 1, block_k)
    padded = qp.shape[1] != Sq or kp.shape[1] != Skv
    if padded and not causal:
        raise ValueError("non-causal attention requires block-aligned "
                         "Sq and Skv (padded keys would be attended)")
    # Keep the ORIGINAL query/key alignment: padded keys land at positions
    # beyond every real query and are causally masked; padded query rows
    # are sliced off below.
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              softcap=softcap, block_q=block_q,
                              block_k=block_k, interpret=_interpret(),
                              q_offset=Skv - Sq)
    return out[:, :Sq]


@functools.partial(jax.jit, static_argnames=("block_t", "block_r"))
def lru_scan(a, b, h0=None, *, block_t: int = _ls.DEFAULT_BLOCK_T,
             block_r: int = _ls.DEFAULT_BLOCK_R):
    """Diagonal linear recurrence; pads L (with a=1, b=0) and R."""
    B, L, R = a.shape
    ap, _ = _pad_to(a, 1, block_t, value=1.0)
    bp, _ = _pad_to(b, 1, block_t, value=0.0)
    ap, _ = _pad_to(ap, 2, block_r, value=1.0)
    bp, _ = _pad_to(bp, 2, block_r, value=0.0)
    if h0 is not None:
        h0p, _ = _pad_to(h0, 1, block_r)
    else:
        h0p = None
    out = _ls.lru_scan(ap, bp, h0p, block_t=min(block_t, ap.shape[1]),
                       block_r=min(block_r, ap.shape[2]),
                       interpret=_interpret())
    return out[:, :L, :R]


@functools.partial(jax.jit, static_argnames=("s", "block_j"))
def schedule_step(demand, gp, width, queue_key, assign, free,
                  pending_free, cand, under, be_q, te_demand, node_cap,
                  *, s: float = PAPER_S,
                  block_j: int = _ss.DEFAULT_BLOCK_J):
    """One fused schedule pass over the (jobs, nodes) tile — Eq. 3
    scoring, Eq. 2 best-victim-node reduction, Eq. 4 masked argmin,
    all-or-nothing gang-fit counts (now and promised), and the BE
    head / first-fit / skip-count scan, in one kernel invocation.

    ``demand`` (J, 3); ``assign`` (J, M); ``free``/``pending_free``
    (M, 3); ``gp``/``queue_key`` (J,) f32; ``width`` (J,) i32;
    ``cand``/``under``/``be_q`` (J,) bool. Pads J to the block
    multiple (padded rows never fit, never selected). Returns a
    ``SchedulePass``; see kernels/schedule_step for the field
    contract."""
    J = demand.shape[0]
    M = free.shape[0]
    sz = jnp.sqrt(jnp.sum(jnp.square(
        demand.astype(jnp.float32) / node_cap.astype(jnp.float32)), -1))
    max_sz = jnp.maximum(jnp.max(jnp.where(cand, sz, 0.0)), 1e-12)
    max_gp = jnp.maximum(
        jnp.max(jnp.where(cand, gp.astype(jnp.float32), 0.0)), 1e-12)

    dp, _ = _pad_to(demand, 0, block_j)
    gpp, _ = _pad_to(gp.astype(jnp.float32), 0, block_j)
    wp, _ = _pad_to(width, 0, block_j, value=M + 1)  # pad rows never fit
    kp, _ = _pad_to(queue_key, 0, block_j, value=jnp.inf)
    ap, _ = _pad_to(assign, 0, block_j, value=False)  # no nodes: ineligible
    cp, _ = _pad_to(cand, 0, block_j, value=False)
    up, _ = _pad_to(under, 0, block_j, value=False)
    bp, _ = _pad_to(be_q, 0, block_j, value=False)
    ps = _ss.schedule_step_pallas(
        dp, gpp, wp, kp, ap, free, pending_free, cp, up, bp,
        te_demand, node_cap, max_sz, max_gp, s,
        block_j=min(block_j, dp.shape[0]), interpret=_interpret())
    return _ss.SchedulePass(ps.scores[:J], ps.fits[:J], ps.fit_now[:J],
                            ps.fit_pend[:J], ps.victim, ps.be_head,
                            ps.be_pick, ps.nskip)


def fitgpp_select(*args, **kwargs):
    """Removed: the standalone Eq. 1-4 victim-selection kernel was
    subsumed by the fused :func:`schedule_step` pass."""
    raise RuntimeError(
        "kernels.ops.fitgpp_select was removed: the standalone fitgpp "
        "victim-selection kernel is subsumed by the fused schedule-pass "
        "kernel. Call kernels.ops.schedule_step (and read .victim / "
        ".scores from the returned SchedulePass); "
        "SimConfig.score_backend='pallas' keeps working and now routes "
        "through the fused kernel.")


@jax.jit
def ssd_chunk(xdt, loga, Bm, Cm):
    """Mamba-2 intra-chunk SSD (zero initial state); see kernels/ssd_chunk."""
    return _sc.ssd_chunk(xdt, loga, Bm, Cm, interpret=_interpret())
