"""Blocked diagonal linear recurrence h_t = a_t * h_{t-1} + b_t — Pallas TPU.

Backs RG-LRU (recurrentgemma) and any diagonal SSM update. TPU layout:
channels (R) ride the vector lanes (parallel grid dim), time is the
innermost ``arbitrary`` grid dim with the carry h held in VMEM scratch
across time blocks; within a block a fori_loop steps the recurrence with
full lane parallelism. (A two-level blocked associative scan is the
§Perf follow-up; this layout already keeps HBM traffic at exactly
read-a,b + write-h.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_R = 512


def _kernel(a_ref, b_ref, h0_ref, o_ref, carry, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        carry[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)          # (bt, br)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        pl.store(o_ref, (0, pl.ds(t, 1), slice(None)),
                 h[None].astype(o_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, block_t, step, carry[0])
    carry[...] = h[None]


def lru_scan(a: jax.Array, b: jax.Array, h0: jax.Array = None, *,
             block_t: int = DEFAULT_BLOCK_T,
             block_r: int = DEFAULT_BLOCK_R,
             interpret: bool = False) -> jax.Array:
    """a, b (B, L, R); h0 (B, R) or None -> h (B, L, R)."""
    B, L, R = a.shape
    bt = min(block_t, L)
    br = min(block_r, R)
    assert L % bt == 0 and R % br == 0, (L, bt, R, br)
    if h0 is None:
        h0 = jnp.zeros((B, R), a.dtype)

    grid = (B, R // br, L // bt)
    out = pl.pallas_call(
        functools.partial(_kernel, block_t=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, br), lambda bi, ri, ti: (bi, ti, ri)),
            pl.BlockSpec((1, bt, br), lambda bi, ri, ti: (bi, ti, ri)),
            pl.BlockSpec((1, br), lambda bi, ri, ti: (bi, ri)),
        ],
        out_specs=pl.BlockSpec((1, bt, br), lambda bi, ri, ti: (bi, ti, ri)),
        out_shape=jax.ShapeDtypeStruct((B, L, R), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, br), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, h0)
    return out
