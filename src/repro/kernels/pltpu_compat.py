"""Pallas-TPU API compatibility shims.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer jax releases; kernels import :data:`CompilerParams` from here so
they run on both (the pinned CI toolchain still ships the old name).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))
if CompilerParams is None:                      # pragma: no cover
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported by the "
        "repro kernels")
