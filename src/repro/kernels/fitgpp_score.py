"""REMOVED — subsumed by the fused schedule-pass kernel.

The standalone Eq. 1-4 victim-selection kernel that lived here
(score + best-victim-node reduction + masked argmin, one output pair)
was folded into :mod:`repro.kernels.schedule_step`, which computes the
same quantities plus the gang-fit tiles and the BE queue scan in a
single invocation per scheduler pass. ``SimConfig.score_backend``
values are unchanged: ``"pallas"`` now routes through the fused
kernel via :func:`repro.kernels.ops.schedule_step`.

This module remains only so stale imports fail loudly at CALL time
(import-time failures would mask which call site is stale).
"""
from __future__ import annotations

_MSG = ("kernels.fitgpp_score.fitgpp_score was removed: the standalone "
        "fitgpp victim-selection kernel is subsumed by the fused "
        "schedule-pass kernel (kernels/schedule_step.py). Call "
        "kernels.ops.schedule_step and read .victim / .scores from the "
        "returned SchedulePass; SimConfig.score_backend='pallas' keeps "
        "working and now routes through the fused kernel.")


def fitgpp_score(*args, **kwargs):
    """Removed; see module docstring."""
    raise RuntimeError(_MSG)
