"""FitGpp victim selection (Eq. 1-4) — Pallas TPU kernel.

The scheduler's per-event hot loop at cluster scale: for J running BE
jobs over M nodes, compute the Eq. 3 score, apply the Eq. 2
eligibility — evaluated against each candidate's BEST assigned node
(the gang-aware ``engine/preemption.best_victim_node`` reduction,
done in-kernel over the (jobs, nodes) assignment tile) — and the
P-cap mask, and take the masked argmin — in one sweep over J with
jobs on the vector lanes. Inputs are struct-of-arrays (J,) vectors
plus the (J, M) assignment tile and the (M, 3) cluster free matrix;
the Eq. 3 normalizers (max Size, max GP over running BE jobs) are
cheap global reductions done by XLA outside and passed in as scalars.

Outputs: per-job scores (for introspection) and the victim index
(-1 when no job passes the masks — the caller falls back to the paper's
random choice).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.engine.placement import FIT_EPS
from repro.kernels.pltpu_compat import CompilerParams

DEFAULT_BLOCK_J = 512
_INF = jnp.inf


def _kernel(scal_ref, dem_ref, free_ref, asg_ref, gp_ref, mask_ref,
            score_ref, idx_ref, best_scr, *, block_j: int):
    ji = pl.program_id(0)
    nj = pl.num_programs(0)

    @pl.when(ji == 0)
    def _init():
        best_scr[0, 0] = _INF          # best score
        best_scr[0, 1] = -1.0          # best index

    s_par = scal_ref[0]                # (8,): te_c te_r te_g  cap_c cap_r
    te = s_par[0:3]                    # cap_g  max_sz max_gp
    cap = s_par[3:6]
    max_sz, max_gp = s_par[6], s_par[7]
    s_w = scal_ref[1, 0]               # Eq. 3 s parameter
    dem = dem_ref[0].astype(jnp.float32)     # (bj, 3)
    free = free_ref[0].astype(jnp.float32)   # (M, 3) cluster free
    asg = asg_ref[0] > 0                     # (bj, M) assignment tile
    gp = gp_ref[0].astype(jnp.float32)       # (bj,)
    ok = mask_ref[0] > 0                     # running BE & under P cap

    size = jnp.sqrt(jnp.sum(jnp.square(dem / cap[None, :]), axis=1))
    score = size / max_sz + s_w * (gp / max_gp)
    # Eq. 2 against the candidate's BEST node: the per-node min-slack
    # of free + own demand - te demand, maximized over assigned nodes
    # (rows with no assignment stay -inf and are never eligible)
    slack = jnp.min(free[None, :, :] + dem[:, None, :]
                    - te[None, None, :], axis=2)        # (bj, M)
    best = jnp.max(jnp.where(asg, slack, -_INF), axis=1)
    elig = best >= -FIT_EPS
    allowed = ok & elig
    val = jnp.where(allowed, score, _INF)

    score_ref[0] = score.astype(score_ref.dtype)

    local_min = jnp.min(val)
    local_arg = jnp.argmin(val).astype(jnp.float32) + ji * block_j
    better = local_min < best_scr[0, 0]
    best_scr[0, 0] = jnp.where(better, local_min, best_scr[0, 0])
    best_scr[0, 1] = jnp.where(better, local_arg, best_scr[0, 1])

    @pl.when(ji == nj - 1)
    def _finish():
        found = best_scr[0, 0] < _INF
        idx_ref[0, 0] = jnp.where(found, best_scr[0, 1], -1.0) \
            .astype(jnp.int32)


def fitgpp_score(demand: jax.Array, free: jax.Array, assign: jax.Array,
                 gp: jax.Array, mask: jax.Array, te_demand: jax.Array,
                 node_cap: jax.Array, max_sz: jax.Array, max_gp: jax.Array,
                 s: float, *, block_j: int = DEFAULT_BLOCK_J,
                 interpret: bool = False):
    """demand (J, 3); free (M, 3); assign (J, M); gp/mask (J,).
    Returns (scores (J,), victim idx () or -1)."""
    J = demand.shape[0]
    M = free.shape[0]
    bj = min(block_j, J)
    assert J % bj == 0, (J, bj)
    scalars = jnp.stack([
        jnp.concatenate([te_demand.astype(jnp.float32),
                         node_cap.astype(jnp.float32),
                         jnp.stack([jnp.maximum(max_sz, 1e-12),
                                    jnp.maximum(max_gp, 1e-12)])]),
        jnp.full((8,), s, jnp.float32),
    ])                                  # (2, 8)

    scores, idx = pl.pallas_call(
        functools.partial(_kernel, block_j=bj),
        grid=(J // bj,),
        in_specs=[
            pl.BlockSpec((2, 8), lambda ji: (0, 0)),
            pl.BlockSpec((1, bj, 3), lambda ji: (0, ji, 0)),
            pl.BlockSpec((1, M, 3), lambda ji: (0, 0, 0)),
            pl.BlockSpec((1, bj, M), lambda ji: (0, ji, 0)),
            pl.BlockSpec((1, bj), lambda ji: (0, ji)),
            pl.BlockSpec((1, bj), lambda ji: (0, ji)),
        ],
        out_specs=[
            pl.BlockSpec((1, bj), lambda ji: (0, ji)),
            pl.BlockSpec((1, 1), lambda ji: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, J), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 2), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(scalars, demand[None].astype(jnp.float32),
      free[None].astype(jnp.float32),
      assign[None].astype(jnp.float32),
      gp[None].astype(jnp.float32),
      mask[None].astype(jnp.float32))
    return scores[0], idx[0, 0]
