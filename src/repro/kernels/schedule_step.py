"""Fused schedule-pass kernel — one invocation per scheduler pass.

The JAX engine's per-event hot loop used to issue a chain of small
kernels per pass (Eq. 3 score, Eq. 2 best-victim-node reduction,
masked argmin, per-job gang fit, BE head/backfill scan).  This module
fuses the whole pass over the ``(jobs, nodes)`` tile into ONE
invocation that returns everything a pass consumes:

* ``scores``   (J,)  f32 — Eq. 3 score (Size/maxSize + s*GP/maxGP,
  normalizers over the running-BE candidates, computed outside and
  passed in as scalars like the te demand).
* ``fits``     (J,M) i32 — per (job, node) fit of ``free`` vs the
  job's per-node demand (the all-or-nothing gang-fit tile; a job fits
  iff its row sums to >= width).
* ``fit_now``  (J,)  i32 — row sums of ``fits``.
* ``fit_pend`` (J,)  i32 — same counts against ``free +
  pending_free`` (the promised-resource gate of the preemption
  trigger).
* ``victim``   ()    i32 — Eq. 4 masked argmin over running-BE &
  under-P-cap & Eq. 2-eligible candidates (eligibility against each
  candidate's BEST assigned node), -1 when nothing passes.
* ``be_head``  ()    i32 — min-queue-key queued BE job, -1 when the
  BE queue is empty.
* ``be_pick``  ()    i32 — min-queue-key queued BE job whose gang
  fits ``free`` right now, -1 when none fits.
* ``nskip``    ()    i32 — how many queued BE jobs ahead of
  ``be_pick`` do NOT fit (the bounded-backfill scan depth consumed
  before the pick; ``be_pick`` is placeable iff ``nskip`` is below
  the remaining depth budget; equals the queued count when
  ``be_pick`` is -1).

Three interchangeable backends share this contract bit-for-bit:
:func:`schedule_step_jnp` (portable jnp twin — the engine's default),
:func:`schedule_step_pallas` (TPU Pallas, jobs on the vector lanes,
two grid phases: reduce then finalize), and the numpy oracle
``kernels.ref.schedule_step_ref``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.engine.placement import FIT_EPS
from repro.kernels.pltpu_compat import CompilerParams

DEFAULT_BLOCK_J = 512
_INF = jnp.inf


class SchedulePass(NamedTuple):
    """Outputs of one fused schedule pass (see module docstring)."""
    scores: jax.Array       # (J,)  f32
    fits: jax.Array         # (J, M) i32
    fit_now: jax.Array      # (J,)  i32
    fit_pend: jax.Array     # (J,)  i32
    victim: jax.Array       # ()    i32, -1 sentinel
    be_head: jax.Array      # ()    i32, -1 sentinel
    be_pick: jax.Array      # ()    i32, -1 sentinel
    nskip: jax.Array        # ()    i32


def schedule_step_jnp(demand, gp, width, queue_key, assign, free,
                      pending_free, cand, under, be_q, te_demand,
                      node_cap, max_sz, max_gp, s) -> SchedulePass:
    """Portable jnp twin — the op-order reference for both the Pallas
    kernel (interpret-mode bit-parity) and the engine's default path.

    demand (J,3) f32; gp/queue_key (J,) f32; width (J,) i32;
    assign (J,M) bool; free/pending_free (M,3) f32; cand/under/be_q
    (J,) bool; te_demand/node_cap (3,) f32; max_sz/max_gp/s scalars
    (normalizers pre-clamped by the caller).
    """
    demand = demand.astype(jnp.float32)
    free = free.astype(jnp.float32)
    # Eq. 3 score over the whole job axis (masking happens at argmin)
    size = jnp.sqrt(jnp.sum(jnp.square(demand / node_cap[None, :]), axis=1))
    scores = size / max_sz + s * (gp / max_gp)
    # per-(job, node) fit tiles, now and promised
    fits_b = jnp.all(free[None, :, :] >= demand[:, None, :] - FIT_EPS,
                     axis=2)                                   # (J, M)
    fit_now = jnp.sum(fits_b, axis=1).astype(jnp.int32)
    fit_pend = jnp.sum(jnp.all(
        (free + pending_free)[None, :, :] >= demand[:, None, :] - FIT_EPS,
        axis=2), axis=1).astype(jnp.int32)
    # Eq. 2 eligibility against each candidate's BEST assigned node
    slack = jnp.min(free[None, :, :] + demand[:, None, :]
                    - te_demand[None, None, :], axis=2)        # (J, M)
    best = jnp.max(jnp.where(assign, slack, -_INF), axis=1)
    allowed = cand & under & (best >= -FIT_EPS)
    victim = jnp.where(allowed.any(),
                       jnp.argmin(jnp.where(allowed, scores, _INF)),
                       -1).astype(jnp.int32)
    # BE queue scan: head, first fit in key order, skips ahead of it
    key_q = jnp.where(be_q, queue_key, _INF)
    be_head = jnp.where(be_q.any(), jnp.argmin(key_q), -1).astype(jnp.int32)
    ok = fit_now >= width
    key_ok = jnp.where(be_q & ok, queue_key, _INF)
    has_pick = (be_q & ok).any()
    be_pick = jnp.where(has_pick, jnp.argmin(key_ok), -1).astype(jnp.int32)
    pick_key = jnp.where(has_pick, queue_key[be_pick], _INF)
    nskip = jnp.sum(be_q & ~ok & (queue_key < pick_key)).astype(jnp.int32)
    return SchedulePass(scores, fits_b.astype(jnp.int32), fit_now,
                        fit_pend, victim, be_head, be_pick, nskip)


def _kernel(scal_ref, dem_ref, gp_ref, wid_ref, key_ref, asg_ref,
            free_ref, pend_ref, cand_ref, under_ref, beq_ref,
            score_ref, fits_ref, fnow_ref, fpend_ref, out_ref,
            red, *, block_j: int):
    """Two grid phases over the job blocks: phase 0 computes every
    blockwise output and accumulates the four global reductions
    (victim argmin, BE head, BE pick) into the ``red`` scratch; phase
    1 re-reads the fit tiles to count the skips ahead of the (now
    known) pick key and finalizes the scalar outputs."""
    ph = pl.program_id(0)
    ji = pl.program_id(1)
    nj = pl.num_programs(1)

    s_par = scal_ref[0]                 # te_c te_r te_g cap_c cap_r cap_g
    te = s_par[0:3]                     # max_sz max_gp
    cap = s_par[3:6]
    max_sz, max_gp = s_par[6], s_par[7]
    s_w = scal_ref[1, 0]

    dem = dem_ref[0].astype(jnp.float32)      # (bj, 3)
    gp = gp_ref[0].astype(jnp.float32)        # (bj,)
    wid = wid_ref[0].astype(jnp.float32)      # (bj,)
    key = key_ref[0].astype(jnp.float32)      # (bj,)
    asg = asg_ref[0] > 0                      # (bj, M)
    free = free_ref[0].astype(jnp.float32)    # (M, 3)
    pend = pend_ref[0].astype(jnp.float32)    # (M, 3)
    cand = cand_ref[0] > 0                    # (bj,)
    under = under_ref[0] > 0
    be_q = beq_ref[0] > 0

    fits_b = jnp.all(free[None, :, :] >= dem[:, None, :] - FIT_EPS,
                     axis=2)                                  # (bj, M)
    fit_now = jnp.sum(fits_b, axis=1)
    ok = be_q & (fit_now >= wid)

    @pl.when(ph == 0)
    def _reduce():
        @pl.when(ji == 0)
        def _init():
            red[0, 0] = _INF            # victim best score
            red[0, 1] = -1.0            # victim index
            red[0, 2] = _INF            # head best key
            red[0, 3] = -1.0            # head index
            red[0, 4] = _INF            # pick best key
            red[0, 5] = -1.0            # pick index
            red[0, 6] = 0.0             # nskip accumulator

        size = jnp.sqrt(jnp.sum(jnp.square(dem / cap[None, :]), axis=1))
        score = size / max_sz + s_w * (gp / max_gp)
        slack = jnp.min(free[None, :, :] + dem[:, None, :]
                        - te[None, None, :], axis=2)          # (bj, M)
        best = jnp.max(jnp.where(asg, slack, -_INF), axis=1)
        allowed = cand & under & (best >= -FIT_EPS)

        score_ref[0] = score.astype(score_ref.dtype)
        fits_ref[0] = fits_b.astype(fits_ref.dtype)
        fnow_ref[0] = fit_now.astype(fnow_ref.dtype)
        fpend_ref[0] = jnp.sum(jnp.all(
            (free + pend)[None, :, :] >= dem[:, None, :] - FIT_EPS,
            axis=2), axis=1).astype(fpend_ref.dtype)

        base = jnp.float32(ji * block_j)
        val = jnp.where(allowed, score, _INF)
        lmin = jnp.min(val)
        larg = jnp.argmin(val).astype(jnp.float32) + base
        better = lmin < red[0, 0]
        red[0, 0] = jnp.where(better, lmin, red[0, 0])
        red[0, 1] = jnp.where(better, larg, red[0, 1])

        kq = jnp.where(be_q, key, _INF)
        lmin = jnp.min(kq)
        larg = jnp.argmin(kq).astype(jnp.float32) + base
        better = lmin < red[0, 2]
        red[0, 2] = jnp.where(better, lmin, red[0, 2])
        red[0, 3] = jnp.where(better, larg, red[0, 3])

        ko = jnp.where(ok, key, _INF)
        lmin = jnp.min(ko)
        larg = jnp.argmin(ko).astype(jnp.float32) + base
        better = lmin < red[0, 4]
        red[0, 4] = jnp.where(better, lmin, red[0, 4])
        red[0, 5] = jnp.where(better, larg, red[0, 5])

    @pl.when(ph == 1)
    def _finalize():
        pick_key = red[0, 4]
        red[0, 6] += jnp.sum((be_q & ~ok & (key < pick_key))
                             .astype(jnp.float32))

        @pl.when(ji == nj - 1)
        def _emit():
            out_ref[0, 0] = jnp.where(red[0, 0] < _INF, red[0, 1], -1.0) \
                .astype(jnp.int32)
            out_ref[0, 1] = jnp.where(red[0, 2] < _INF, red[0, 3], -1.0) \
                .astype(jnp.int32)
            out_ref[0, 2] = jnp.where(red[0, 4] < _INF, red[0, 5], -1.0) \
                .astype(jnp.int32)
            out_ref[0, 3] = red[0, 6].astype(jnp.int32)


def schedule_step_pallas(demand, gp, width, queue_key, assign, free,
                         pending_free, cand, under, be_q, te_demand,
                         node_cap, max_sz, max_gp, s, *,
                         block_j: int = DEFAULT_BLOCK_J,
                         interpret: bool = False) -> SchedulePass:
    """Pallas TPU backend of the fused pass (same contract as
    :func:`schedule_step_jnp`; jobs on the vector lanes, grid =
    (2 phases, J/block_j job blocks))."""
    J = demand.shape[0]
    M = free.shape[0]
    bj = min(block_j, J)
    assert J % bj == 0, (J, bj)
    scalars = jnp.stack([
        jnp.concatenate([te_demand.astype(jnp.float32),
                         node_cap.astype(jnp.float32),
                         jnp.stack([jnp.asarray(max_sz, jnp.float32),
                                    jnp.asarray(max_gp, jnp.float32)])]),
        jnp.full((8,), s, jnp.float32),
    ])                                  # (2, 8)

    job_vec = pl.BlockSpec((1, bj), lambda ph, ji: (0, ji))
    node_mat = pl.BlockSpec((1, M, 3), lambda ph, ji: (0, 0, 0))
    scores, fits, fit_now, fit_pend, out = pl.pallas_call(
        functools.partial(_kernel, block_j=bj),
        grid=(2, J // bj),
        in_specs=[
            pl.BlockSpec((2, 8), lambda ph, ji: (0, 0)),
            pl.BlockSpec((1, bj, 3), lambda ph, ji: (0, ji, 0)),
            job_vec, job_vec, job_vec,
            pl.BlockSpec((1, bj, M), lambda ph, ji: (0, ji, 0)),
            node_mat, node_mat,
            job_vec, job_vec, job_vec,
        ],
        out_specs=[
            job_vec,
            pl.BlockSpec((1, bj, M), lambda ph, ji: (0, ji, 0)),
            job_vec, job_vec,
            pl.BlockSpec((1, 8), lambda ph, ji: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, J), jnp.float32),
            jax.ShapeDtypeStruct((1, J, M), jnp.int32),
            jax.ShapeDtypeStruct((1, J), jnp.int32),
            jax.ShapeDtypeStruct((1, J), jnp.int32),
            jax.ShapeDtypeStruct((1, 8), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 8), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(scalars, demand[None].astype(jnp.float32),
      gp[None].astype(jnp.float32),
      width[None].astype(jnp.float32),
      queue_key[None].astype(jnp.float32),
      assign[None].astype(jnp.float32),
      free[None].astype(jnp.float32),
      pending_free[None].astype(jnp.float32),
      cand[None].astype(jnp.float32),
      under[None].astype(jnp.float32),
      be_q[None].astype(jnp.float32))
    return SchedulePass(scores[0], fits[0], fit_now[0], fit_pend[0],
                        out[0, 0], out[0, 1], out[0, 2], out[0, 3])
