"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """GQA attention oracle. q (B, Sq, H, hd); k/v (B, Skv, KV, hd).

    Queries are the LAST Sq positions of the Skv-long sequence
    (q position i sits at absolute Skv - Sq + i).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (hd ** -0.5)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    Skv = k.shape[1]
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def lru_scan_ref(a: jax.Array, b: jax.Array,
                 h0: jax.Array = None) -> jax.Array:
    """Diagonal linear recurrence oracle. a, b (B, L, R); h0 (B, R)."""
    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(
        op, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    if h0 is not None:
        Bc = Bc + A * h0.astype(jnp.float32)[:, None]
    return Bc.astype(a.dtype)


def ssd_chunk_ref(xdt: jax.Array, loga: jax.Array, Bm: jax.Array,
                  Cm: jax.Array) -> jax.Array:
    """Intra-chunk SSD quadratic dual form oracle (single chunk,
    zero initial state). xdt (B, Q, H, P); loga (B, Q, H);
    Bm/Cm (B, Q, H, N) — groups pre-broadcast to heads."""
    z = jnp.cumsum(loga.astype(jnp.float32), axis=1)
    T = z[:, :, None, :] - z[:, None, :, :]            # (B, Q, Q, H)
    Q = loga.shape[1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, :, :, None], jnp.exp(T), 0.0)
    scores = jnp.einsum("bqhn,bshn->bqsh", Cm.astype(jnp.float32),
                        Bm.astype(jnp.float32))
    y = jnp.einsum("bqsh,bqsh,bshp->bqhp", scores, L,
                   xdt.astype(jnp.float32))
    return y.astype(xdt.dtype)


def schedule_step_ref(demand, gp, width, queue_key, assign, free,
                      pending_free, cand, under, be_q, te_demand,
                      node_cap, max_sz, max_gp, s, eps: float = 1e-9):
    """Oracle for the fused schedule pass (kernels/schedule_step):
    straight-line restatement of the per-pass quantities. Returns the
    same 8-tuple as ``SchedulePass``; see that module's docstring for
    the field contract. Normalizers ``max_sz``/``max_gp`` are passed
    in pre-clamped, mirroring the kernel call."""
    demand = demand.astype(jnp.float32)
    free = free.astype(jnp.float32)
    sz = jnp.sqrt(jnp.sum((demand / node_cap) ** 2, axis=-1))
    scores = sz / max_sz + s * (gp / max_gp)
    fits = jnp.all(free[None, :, :] >= demand[:, None, :] - eps, axis=2)
    fit_now = jnp.sum(fits, axis=1).astype(jnp.int32)
    fit_pend = jnp.sum(jnp.all(
        (free + pending_free)[None, :, :] >= demand[:, None, :] - eps,
        axis=2), axis=1).astype(jnp.int32)
    slack = jnp.min(free[None, :, :] + demand[:, None, :]
                    - te_demand[None, None, :], axis=2)        # (J, M)
    best = jnp.max(jnp.where(assign, slack, -jnp.inf), axis=1)
    allowed = cand & under & (best >= -eps)
    victim = jnp.where(allowed.any(),
                       jnp.argmin(jnp.where(allowed, scores, jnp.inf)),
                       -1).astype(jnp.int32)
    be_head = jnp.where(be_q.any(),
                        jnp.argmin(jnp.where(be_q, queue_key, jnp.inf)),
                        -1).astype(jnp.int32)
    ok = fit_now >= width
    has_pick = (be_q & ok).any()
    be_pick = jnp.where(
        has_pick,
        jnp.argmin(jnp.where(be_q & ok, queue_key, jnp.inf)),
        -1).astype(jnp.int32)
    pick_key = jnp.where(has_pick, queue_key[be_pick], jnp.inf)
    nskip = jnp.sum(be_q & ~ok & (queue_key < pick_key)).astype(jnp.int32)
    return (scores, fits.astype(jnp.int32), fit_now, fit_pend,
            victim, be_head, be_pick, nskip)
