"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """GQA attention oracle. q (B, Sq, H, hd); k/v (B, Skv, KV, hd).

    Queries are the LAST Sq positions of the Skv-long sequence
    (q position i sits at absolute Skv - Sq + i).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (hd ** -0.5)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    Skv = k.shape[1]
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def lru_scan_ref(a: jax.Array, b: jax.Array,
                 h0: jax.Array = None) -> jax.Array:
    """Diagonal linear recurrence oracle. a, b (B, L, R); h0 (B, R)."""
    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(
        op, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    if h0 is not None:
        Bc = Bc + A * h0.astype(jnp.float32)[:, None]
    return Bc.astype(a.dtype)


def ssd_chunk_ref(xdt: jax.Array, loga: jax.Array, Bm: jax.Array,
                  Cm: jax.Array) -> jax.Array:
    """Intra-chunk SSD quadratic dual form oracle (single chunk,
    zero initial state). xdt (B, Q, H, P); loga (B, Q, H);
    Bm/Cm (B, Q, H, N) — groups pre-broadcast to heads."""
    z = jnp.cumsum(loga.astype(jnp.float32), axis=1)
    T = z[:, :, None, :] - z[:, None, :, :]            # (B, Q, Q, H)
    Q = loga.shape[1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, :, :, None], jnp.exp(T), 0.0)
    scores = jnp.einsum("bqhn,bshn->bqsh", Cm.astype(jnp.float32),
                        Bm.astype(jnp.float32))
    y = jnp.einsum("bqsh,bqsh,bshp->bqhp", scores, L,
                   xdt.astype(jnp.float32))
    return y.astype(xdt.dtype)


def fitgpp_score_ref(demand: jax.Array, gp: jax.Array, assign: jax.Array,
                     free: jax.Array, te_demand: jax.Array,
                     running_be: jax.Array, under_cap: jax.Array,
                     node_cap: jax.Array, s: float, eps: float = 1e-9):
    """Eq. 1-4 oracle over the (jobs, nodes) tile. demand (J,3) per
    node; assign (J,M) placement mask; free (M,3). Eq. 2 is evaluated
    against each candidate's BEST assigned node (max min-slack);
    returns (victim_idx or -1, scores (J,))."""
    sz = jnp.sqrt(jnp.sum((demand / node_cap) ** 2, axis=-1))
    max_sz = jnp.maximum(jnp.max(jnp.where(running_be, sz, 0.0)), 1e-12)
    max_gp = jnp.maximum(jnp.max(jnp.where(running_be, gp, 0.0)), 1e-12)
    score = sz / max_sz + s * (gp / max_gp)
    slack = jnp.min(free[None, :, :] + demand[:, None, :]
                    - te_demand[None, None, :], axis=2)       # (J, M)
    best = jnp.max(jnp.where(assign, slack, -jnp.inf), axis=1)
    elig = best >= -eps
    mask = running_be & elig & under_cap
    idx = jnp.argmin(jnp.where(mask, score, jnp.inf))
    return jnp.where(mask.any(), idx, -1).astype(jnp.int32), score
