"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel lives in <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), dispatches through ops.py (jit'd wrappers with padding/backend
selection) and is validated against its pure-jnp oracle in ref.py
(interpret mode on this CPU container; native Mosaic on TPU):

  flash_attention — blocked causal/SWA/softcap GQA, online softmax
  lru_scan        — diagonal linear recurrence (RG-LRU / diagonal SSM)
  ssd_chunk       — Mamba-2 SSD intra-chunk quadratic dual form
  fitgpp_score    — the paper's Eq. 1-4 score + masked argmin over jobs
"""
