"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel lives in <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), dispatches through ops.py (jit'd wrappers with padding/backend
selection) and is validated against its pure-jnp oracle in ref.py
(interpret mode on this CPU container; native Mosaic on TPU):

  flash_attention — blocked causal/SWA/softcap GQA, online softmax
  lru_scan        — diagonal linear recurrence (RG-LRU / diagonal SSM)
  ssd_chunk       — Mamba-2 SSD intra-chunk quadratic dual form
  schedule_step   — the fused scheduler pass: Eq. 3 score, Eq. 2
                    best-node reduction, Eq. 4 argmin, gang-fit tiles
                    and the BE backfill scan over the (jobs, nodes)
                    tile in one invocation (subsumes the former
                    fitgpp_score kernel, kept only as an error shim)
"""
