"""Mamba-2 SSD intra-chunk quadratic dual form — Pallas TPU kernel.

Computes, per (batch·head, chunk) grid cell, the zero-initial-state
chunk output

    y = (C Bᵀ ∘ L) x,   L[i,j] = exp(cumsum(loga)_i - cumsum(loga)_j)·[j<=i]

with the (Q × Q) decay-masked score matrix living entirely in VMEM and
both contractions on the MXU. This is the compute hot spot of the SSD
scan (models/ssm.py ``ssd_scan`` y_diag term, which is its oracle via
``kernels/ref.py::ssd_chunk_ref``); the inter-chunk recurrence stays in
XLA (tiny, bandwidth-bound).

Grid: (B*H, L/Q) — fully parallel; chunk length Q is the block size
(Mamba-2 uses 256, MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams

_NEG_INF = -1e30


def _kernel(x_ref, loga_ref, b_ref, c_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    loga = loga_ref[0].astype(jnp.float32)    # (Q, 1)
    bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0].astype(jnp.float32)         # (Q, N)
    Q = x.shape[0]

    z = jnp.cumsum(loga[:, 0])                # (Q,)
    t = z[:, None] - z[None, :]               # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(kj <= qi, jnp.exp(t), 0.0)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * decay, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_chunk(xdt: jax.Array, loga: jax.Array, Bm: jax.Array,
              Cm: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Intra-chunk SSD, zero initial state.

    xdt (B, L, H, P); loga (B, L, H); Bm/Cm (B, L, H, N) — groups
    pre-broadcast to heads. L must be a multiple of the chunk length Q
    implied by the caller's reshape; here each grid step handles one
    (b·h, chunk) pair with Q = block over L. Returns y (B, L, H, P).
    """
    B, L, H, P = xdt.shape
    N = Bm.shape[-1]
    Q = min(256, L)
    assert L % Q == 0, (L, Q)

    # (B, L, H, *) -> (B*H, L, *)
    xz = xdt.transpose(0, 2, 1, 3).reshape(B * H, L, P)
    lz = loga.transpose(0, 2, 1).reshape(B * H, L, 1)
    bz = Bm.transpose(0, 2, 1, 3).reshape(B * H, L, N)
    cz = Cm.transpose(0, 2, 1, 3).reshape(B * H, L, N)

    out = pl.pallas_call(
        _kernel,
        grid=(B * H, L // Q),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda z, ci: (z, ci, 0)),
            pl.BlockSpec((1, Q, 1), lambda z, ci: (z, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda z, ci: (z, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda z, ci: (z, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda z, ci: (z, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, L, P), xdt.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xz, lz, bz, cz)
    return out.reshape(B, H, L, P).transpose(0, 2, 1, 3)
