"""Trace exporters: Chrome-trace/Perfetto JSON and CSV.

Both formats serialize the canonical ``obs.schema.Event`` stream.
The Perfetto export opens directly in https://ui.perfetto.dev (or
``chrome://tracing``): one track per cluster node showing job
occupancy slices, one counter track per queue lane plus utilization /
jobs-in-grace, and instant markers for preemption signals. CSV is the
lossless round-trippable form (``read_csv(write) == events``).

Timestamps: Chrome trace ``ts`` is microseconds; we map one simulated
minute to 1 µs (``TS_PER_MIN``), so the UI's "1 ms" ruler reads as
1000 simulated minutes.
"""
from __future__ import annotations

import csv
import io
import json
from typing import Dict, List, Optional, Sequence

from repro.obs import schema
from repro.obs.schema import Event

TS_PER_MIN = 1          # Chrome-trace µs per simulated minute
_PID_NODES = 1
_PID_METRICS = 2

CSV_FIELDS = ("t", "event", "job", "aux", "nodes")


def to_csv(events: Sequence[Event]) -> str:
    """Lossless CSV serialization (header + one row per event;
    ``nodes`` is a '+'-joined node list, empty when none)."""
    out = io.StringIO()
    w = csv.writer(out)
    w.writerow(CSV_FIELDS)
    for ev in events:
        w.writerow([ev.t, ev.name, ev.job, ev.aux,
                    "+".join(str(n) for n in ev.nodes)])
    return out.getvalue()


def read_csv(text: str) -> List[Event]:
    """Inverse of :func:`to_csv`."""
    rd = csv.reader(io.StringIO(text))
    header = next(rd)
    if tuple(header) != CSV_FIELDS:
        raise ValueError(f"not a trace CSV (header {header!r})")
    code_of = {name: i for i, name in enumerate(schema.EVENT_NAMES)}
    events = []
    for row in rd:
        if not row:
            continue
        t, name, job, aux, nodes = row
        events.append(Event(
            t=int(t), code=code_of[name], job=int(job), aux=int(aux),
            nodes=tuple(int(n) for n in nodes.split("+")) if nodes else ()))
    return events


def _lane_of(job: int, is_te, preemptive: bool) -> str:
    if preemptive and is_te is not None and bool(is_te[job]):
        return "TE"
    return "BE"


def to_perfetto(events: Sequence[Event], n_nodes: Optional[int] = None,
                is_te=None, preemptive: bool = True) -> Dict:
    """Chrome-trace (Perfetto-compatible) JSON object.

    Track layout: pid 1 "cluster" with one thread per node (occupancy
    slices named after the running job, preemption-signal instants);
    pid 2 "metrics" with counter tracks — queue depth per lane,
    jobs-in-grace, busy nodes (utilization numerator). ``is_te`` (any
    indexable of per-job flags) + ``preemptive`` derive the queue lane
    of each job; omitted, every job counts in the BE lane.
    """
    tr: List[Dict] = [
        {"ph": "M", "pid": _PID_NODES, "name": "process_name",
         "args": {"name": "cluster"}},
        {"ph": "M", "pid": _PID_METRICS, "name": "process_name",
         "args": {"name": "metrics"}},
    ]
    max_node = max((max(ev.nodes) for ev in events if ev.nodes), default=-1)
    n_tracks = max(n_nodes or 0, max_node + 1)
    for node in range(n_tracks):
        tr.append({"ph": "M", "pid": _PID_NODES, "tid": node,
                   "name": "thread_name",
                   "args": {"name": f"node {node}"}})

    placed: Dict[int, tuple] = {}        # job -> (t_placed, node tuple)
    depth = {"TE": 0, "BE": 0}
    in_grace = 0
    # nodes are SHARED (demand packing): busy = nodes held by >= 1 job
    occ: Dict[int, int] = {}
    counters_dirty = True

    def counters(t: int):
        tr.append({"ph": "C", "pid": _PID_METRICS, "name": "queue depth",
                   "ts": t * TS_PER_MIN,
                   "args": {"TE lane": depth["TE"], "BE lane": depth["BE"]}})
        tr.append({"ph": "C", "pid": _PID_METRICS, "name": "in grace",
                   "ts": t * TS_PER_MIN, "args": {"jobs": in_grace}})
        tr.append({"ph": "C", "pid": _PID_METRICS, "name": "busy nodes",
                   "ts": t * TS_PER_MIN, "args": {"nodes": len(occ)}})

    def begin(ev: Event):
        placed[ev.job] = (ev.t, ev.nodes)
        for node in ev.nodes:
            occ[node] = occ.get(node, 0) + 1

    def end(ev: Event, released_by: str):
        # occupancy slices are "X" complete events, emitted at release
        # time with their full duration — concurrent jobs on a shared
        # node overlap freely, which stack-matched B/E pairs cannot
        # represent on one track
        t0, nodes = placed.pop(ev.job, (ev.t, ()))
        for node in nodes:
            tr.append({"ph": "X", "pid": _PID_NODES, "tid": node,
                       "ts": t0 * TS_PER_MIN,
                       "dur": max(ev.t - t0, 0) * TS_PER_MIN,
                       "name": f"job {ev.job}",
                       "args": {"job": ev.job, "released_by": released_by}})
            occ[node] -= 1
            if not occ[node]:
                del occ[node]

    prev_t = None
    for ev in events:
        if counters_dirty and prev_t is not None and ev.t != prev_t:
            counters(prev_t)
            counters_dirty = False
        if ev.t != prev_t:
            prev_t = ev.t
        lane = _lane_of(ev.job, is_te, preemptive)
        if ev.code == schema.SUBMIT:
            depth[lane] += 1
            counters_dirty = True
        elif ev.code in schema.PLACEMENT_CODES:
            depth[lane] -= 1
            begin(ev)
            counters_dirty = True
        elif ev.code == schema.PREEMPT_SIGNAL:
            node = placed.get(ev.job, (ev.t, (0,)))[1]
            tid = node[0] if node else 0
            tr.append({"ph": "i", "pid": _PID_NODES, "tid": tid,
                       "ts": ev.t * TS_PER_MIN, "s": "t",
                       "name": f"signal job {ev.job} (te {ev.aux})"})
            in_grace += 1
            counters_dirty = True
        elif ev.code == schema.VACATE:
            end(ev, "vacate")
            in_grace -= 1
            counters_dirty = True
        elif ev.code == schema.REQUEUE:
            depth[lane] += 1
            counters_dirty = True
        elif ev.code == schema.FINISH:
            end(ev, "finish")
            counters_dirty = True
    if counters_dirty and prev_t is not None:
        counters(prev_t)
    # jobs still placed when the trace ends: close their slices at the
    # last event time so the track is complete
    if prev_t is not None:
        for job in sorted(placed):
            end(Event(t=prev_t, code=schema.FINISH, job=job), "trace-end")
    return {"traceEvents": tr, "displayTimeUnit": "ms",
            "otherData": {"ts_per_minute": TS_PER_MIN}}


class CsvTraceWriter:
    """Incremental trace-CSV writer for streamed runs (DESIGN.md §10).

    Same dialect as :func:`to_csv` / :func:`read_csv`, but appends
    batches as they drain instead of holding the whole stream — pass
    ``writer.write`` as ``core.stream.StreamEngine``'s ``event_sink``
    and the trace lands on disk round by round in O(batch) memory:

        with CsvTraceWriter(path) as w:
            stream.StreamEngine(cfg, src, trace=True,
                                event_sink=w.write).run()
        read_csv(open(path).read())     # == the full event stream
    """

    def __init__(self, path: str):
        self._f = open(path, "w", newline="")
        self._w = csv.writer(self._f)
        self._w.writerow(CSV_FIELDS)
        self.n_written = 0

    def write(self, events: Sequence[Event]) -> None:
        for ev in events:
            self._w.writerow([ev.t, ev.name, ev.job, ev.aux,
                              "+".join(str(n) for n in ev.nodes)])
        self.n_written += len(events)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "CsvTraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def write_trace(path: str, events: Sequence[Event], fmt: str = "perfetto",
                n_nodes: Optional[int] = None, is_te=None,
                preemptive: bool = True) -> None:
    """Write the event stream to ``path`` as ``fmt``
    (``"perfetto"`` JSON or ``"csv"``)."""
    if fmt == "perfetto":
        with open(path, "w") as f:
            json.dump(to_perfetto(events, n_nodes=n_nodes, is_te=is_te,
                                  preemptive=preemptive), f)
    elif fmt == "csv":
        with open(path, "w") as f:
            f.write(to_csv(events))
    else:
        raise ValueError(f"unknown trace format {fmt!r}; "
                         "one of ('perfetto', 'csv')")
