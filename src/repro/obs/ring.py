"""Fixed-capacity in-jit event ring buffer: layout + host-side decode.

The JAX engine cannot call back to the host from inside its
``lax.while_loop``, so tracing appends rows to a preallocated int32
buffer threaded through ``sim_jax.State``:

  * ``ev_buf`` — shape ``(capacity + 1, 4 + n_words)`` int32, where a
    row is ``[t, code, job, aux, node_word_0, ...]``. Node words pack
    the placement node mask 32 nodes per word, little-endian (node
    ``k`` is bit ``k % 32`` of word ``k // 32``); non-placement rows
    carry all-zero words. ``n_words = max(1, ceil(n_nodes / 32))``.
  * ``ev_n`` — () int32, the count of rows EMITTED (monotonic, may
    exceed capacity).

Row ``capacity`` (the extra row) is the dump row: every masked-out or
overflowing write is scattered there (``jnp.minimum(idx, capacity)``)
and the row is re-zeroed after each append, so the buffer contents
stay a pure function of the event stream — bitwise State parity
between tick and event mode covers the trace too.

Overflow rule: rows past capacity are dropped newest-first and
``overflow = max(0, ev_n - capacity)`` is surfaced loudly
(``result_summary``, ``ExperimentResult.trace_overflow``, the CLI and
the bench). :func:`default_capacity` is sized so overflow never
happens for the repo's scenarios unless preemption churn exceeds the
paper's P cap many times over.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.obs import schema
from repro.obs.schema import Event

# Buffer row layout: [t, code, job, aux, node words...]
HEADER_WORDS = 4
NODE_WORD_BITS = 32


def n_node_words(n_nodes: int) -> int:
    return max(1, -(-int(n_nodes) // NODE_WORD_BITS))


def default_capacity(n_jobs: int, max_preemptions: int = 1) -> int:
    """Capacity heuristic: every job emits SUBMIT + START + FINISH
    (+ BACKFILL marker at most once per placement), and each
    preemption of a job costs at most 7 rows (SIGNAL, GRACE_EXPIRE,
    VACATE, REQUEUE, RESUME + a possible BACKFILL on the resume and
    one slack row). ``fallback_count`` signals can exceed the P cap,
    so a generous constant floor is added on top."""
    per_job = 8 + 7 * max(int(max_preemptions), 1)
    return 64 + int(n_jobs) * per_job


def round_capacity(n_slots: int, max_preemptions: int = 1) -> int:
    """Per-round ring capacity for the streaming engine's recycled
    slot pool (``core/stream/``): the ring is drained (and ``ev_n``
    reset) between macro-rounds, a slot hosts at most ONE job within
    a round, and a job's whole-lifetime emission is bounded by
    :func:`default_capacity`'s per-job budget — so the same bound
    applied to SLOTS covers any single round. This is what keeps a
    streamed run's trace memory O(capacity), not O(total jobs)."""
    return default_capacity(n_slots, max_preemptions)


def decode_ring(ev_buf, ev_n) -> Tuple[List[Event], int]:
    """Decode a device ring buffer into canonical :class:`Event` rows.

    Returns ``(events, overflow)`` where ``overflow`` is the count of
    rows dropped past capacity. The dump row (index ``capacity``) is
    never part of the stream."""
    buf = np.asarray(ev_buf)
    n = int(np.asarray(ev_n))
    cap = buf.shape[0] - 1
    n_words = buf.shape[1] - HEADER_WORDS
    overflow = max(0, n - cap)
    kept = min(n, cap)
    events: List[Event] = []
    rows = buf[:kept]
    words = rows[:, HEADER_WORDS:].astype(np.uint32)
    for i in range(kept):
        t, code, job, aux = (int(rows[i, 0]), int(rows[i, 1]),
                             int(rows[i, 2]), int(rows[i, 3]))
        nodes: Tuple[int, ...] = ()
        if code in schema.PLACEMENT_CODES:
            idx = []
            for w in range(n_words):
                word = int(words[i, w])
                while word:
                    b = (word & -word).bit_length() - 1
                    idx.append(w * NODE_WORD_BITS + b)
                    word &= word - 1
            nodes = tuple(idx)
        events.append(Event(t=t, code=code, job=job, aux=aux, nodes=nodes))
    return events, overflow


def node_mask_weights(n_nodes: int) -> np.ndarray:
    """Per-node packing weights: ``(n_words, n_nodes)`` uint32 with
    ``weights[w, k] = 1 << (k % 32)`` iff ``k // 32 == w`` — a bool
    node mask packs to words via ``weights @ mask``. Precomputed on
    the host so the in-jit append is one matmul."""
    n_words = n_node_words(n_nodes)
    w = np.zeros((n_words, n_nodes), np.uint32)
    for k in range(int(n_nodes)):
        w[k // NODE_WORD_BITS, k] = np.uint32(1 << (k % NODE_WORD_BITS))
    return w
