"""Observability: canonical event schema, in-jit ring-buffer decode,
trace exporters and derived telemetry (DESIGN.md §8).

Layering: ``obs`` depends only on numpy + the schema itself — both
engines import FROM here (event codes, ``default_capacity``), never
the other way around, so every consumer of a trace is engine-agnostic.
"""
from repro.obs.export import (CsvTraceWriter, read_csv,  # noqa: F401
                              to_csv, to_perfetto, write_trace)
from repro.obs.ring import (decode_ring, default_capacity,  # noqa: F401
                            n_node_words, round_capacity)
from repro.obs.schema import (BACKFILL, EVENT_NAMES, FINISH,  # noqa: F401
                              GRACE_EXPIRE, PREEMPT_SIGNAL, REQUEUE,
                              RESUME, START, SUBMIT, VACATE, Event,
                              events_of_job, render_preemption,
                              validate_events)
from repro.obs.timeseries import (JobDecomposition,  # noqa: F401
                                  TimeSeries, compute_timeseries,
                                  format_timeseries,
                                  slowdown_decomposition)
