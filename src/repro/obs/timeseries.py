"""Derived telemetry: time-series metrics and the per-job slowdown
decomposition, both computed by replaying the canonical event stream.

Nothing here touches either engine — any trace that validates against
``obs.schema`` replays, so reference runs, decoded JAX ring buffers
and CSV round-trips all feed the same analysis.

The decomposition is the paper's slowdown-rate metric made auditable:
for every finished job,

    finish - submit == initial_wait + grace_stall + requeue_wait
                       + service

where ``initial_wait`` is submit -> first placement, ``grace_stall``
sums signal -> vacate spans, ``requeue_wait`` sums vacate -> resume
spans, and ``service`` sums placement -> (signal | finish) running
spans. The identity holds exactly because a job's remaining time only
counts down while RUNNING — it is property-tested per job on both
engines (tests/test_sim_jax_properties.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from repro.obs import schema
from repro.obs.schema import Event


@dataclass
class TimeSeries:
    """Step-function samples at every distinct event time ``t[i]``:
    each series holds the value AFTER all events at ``t[i]`` applied,
    valid on ``[t[i], t[i+1])``."""
    t: np.ndarray                 # (k,) i64, strictly increasing
    busy_nodes: np.ndarray        # (k,) i64
    utilization: np.ndarray       # (k,) f64, busy / n_nodes
    queue_depth_te: np.ndarray    # (k,) i64
    queue_depth_be: np.ndarray    # (k,) i64
    in_grace: np.ndarray          # (k,) i64
    cum_preemptions: np.ndarray   # (k,) i64 signals so far
    n_nodes: int

    @property
    def makespan(self) -> int:
        return int(self.t[-1]) if len(self.t) else 0

    @property
    def preempt_rate(self) -> float:
        """Preemption signals per simulated minute over the run."""
        span = self.makespan
        total = int(self.cum_preemptions[-1]) if len(self.t) else 0
        return total / span if span > 0 else 0.0

    def mean_utilization(self) -> float:
        """Time-weighted mean node utilization over the run."""
        if len(self.t) < 2:
            return 0.0
        dt = np.diff(self.t.astype(np.float64))
        return float((self.utilization[:-1] * dt).sum() / dt.sum())


def compute_timeseries(events: Sequence[Event], n_nodes: int,
                       is_te=None, preemptive: bool = True) -> TimeSeries:
    """Replay the event stream into step-function series. ``is_te``
    (per-job flags) + ``preemptive`` split the queue-depth series into
    lanes; omitted, everything counts as the BE lane."""
    placed: Dict[int, tuple] = {}
    # nodes are SHARED (demand packing): a node is busy while any job
    # holds it, so occupancy is a per-node refcount, not a set
    occ: Dict[int, int] = {}
    depth = {"TE": 0, "BE": 0}
    in_grace = 0
    signals = 0

    def release(job: int):
        for n in placed.pop(job, ()):
            occ[n] -= 1
            if not occ[n]:
                del occ[n]

    ts, bn, qt, qb, gr, cp = [], [], [], [], [], []

    def sample(t: int):
        ts.append(t)
        bn.append(len(occ))
        qt.append(depth["TE"])
        qb.append(depth["BE"])
        gr.append(in_grace)
        cp.append(signals)

    def lane(job: int) -> str:
        if preemptive and is_te is not None and bool(is_te[job]):
            return "TE"
        return "BE"

    prev_t: Optional[int] = None
    for ev in events:
        if prev_t is not None and ev.t != prev_t:
            sample(prev_t)
        prev_t = ev.t
        if ev.code == schema.SUBMIT:
            depth[lane(ev.job)] += 1
        elif ev.code in schema.PLACEMENT_CODES:
            depth[lane(ev.job)] -= 1
            placed[ev.job] = ev.nodes
            for n in ev.nodes:
                occ[n] = occ.get(n, 0) + 1
        elif ev.code == schema.PREEMPT_SIGNAL:
            signals += 1
            in_grace += 1
        elif ev.code == schema.VACATE:
            in_grace -= 1
            release(ev.job)
        elif ev.code == schema.REQUEUE:
            depth[lane(ev.job)] += 1
        elif ev.code == schema.FINISH:
            release(ev.job)
    if prev_t is not None:
        sample(prev_t)
    return TimeSeries(
        t=np.asarray(ts, np.int64),
        busy_nodes=np.asarray(bn, np.int64),
        utilization=np.asarray(bn, np.float64) / max(int(n_nodes), 1),
        queue_depth_te=np.asarray(qt, np.int64),
        queue_depth_be=np.asarray(qb, np.int64),
        in_grace=np.asarray(gr, np.int64),
        cum_preemptions=np.asarray(cp, np.int64),
        n_nodes=int(n_nodes))


@dataclass
class JobDecomposition:
    """Per-job slowdown decomposition (all in simulated minutes)."""
    job: int
    submit: int
    finish: int                   # -1 when the job never finished
    initial_wait: int
    grace_stall: int
    requeue_wait: int
    service: int

    @property
    def turnaround(self) -> int:
        return self.finish - self.submit

    def identity_holds(self) -> bool:
        return (self.finish >= 0 and
                self.turnaround == self.initial_wait + self.grace_stall
                + self.requeue_wait + self.service)


def slowdown_decomposition(events: Sequence[Event]
                           ) -> Dict[int, JobDecomposition]:
    """Split every job's turnaround into its four phases by replaying
    its lifecycle (see module docstring for the identity)."""
    out: Dict[int, JobDecomposition] = {}
    # per-job running state
    sub: Dict[int, int] = {}
    first_start: Dict[int, int] = {}
    place_t: Dict[int, int] = {}
    signal_t: Dict[int, int] = {}
    vacate_t: Dict[int, int] = {}
    stall: Dict[int, int] = {}
    rq_wait: Dict[int, int] = {}
    service: Dict[int, int] = {}
    for ev in events:
        j = ev.job
        if ev.code == schema.SUBMIT:
            sub[j] = ev.t
        elif ev.code in schema.PLACEMENT_CODES:
            if j not in first_start:
                first_start[j] = ev.t
            if ev.code == schema.RESUME and j in vacate_t:
                rq_wait[j] = rq_wait.get(j, 0) + ev.t - vacate_t.pop(j)
            place_t[j] = ev.t
        elif ev.code == schema.PREEMPT_SIGNAL:
            signal_t[j] = ev.t
            if j in place_t:
                service[j] = service.get(j, 0) + ev.t - place_t.pop(j)
        elif ev.code == schema.VACATE:
            vacate_t[j] = ev.t
            if j in signal_t:
                stall[j] = stall.get(j, 0) + ev.t - signal_t.pop(j)
        elif ev.code == schema.FINISH:
            if j in place_t:
                service[j] = service.get(j, 0) + ev.t - place_t.pop(j)
            out[j] = JobDecomposition(
                job=j, submit=sub.get(j, 0), finish=ev.t,
                initial_wait=first_start.get(j, ev.t) - sub.get(j, 0),
                grace_stall=stall.get(j, 0),
                requeue_wait=rq_wait.get(j, 0),
                service=service.get(j, 0))
    # unfinished jobs: report what is known, finish = -1
    for j, s in sub.items():
        if j not in out:
            out[j] = JobDecomposition(
                job=j, submit=s, finish=-1,
                initial_wait=(first_start[j] - s) if j in first_start
                else -1,
                grace_stall=stall.get(j, 0),
                requeue_wait=rq_wait.get(j, 0),
                service=service.get(j, 0))
    return out


def format_timeseries(series: TimeSeries, max_rows: int = 20) -> str:
    """Aligned text table of the series, downsampled to ``max_rows``
    evenly spaced samples (CLI / example output)."""
    k = len(series.t)
    idx = (range(k) if k <= max_rows
           else np.linspace(0, k - 1, max_rows).astype(int))
    hdr = (f"{'t':>8s} {'util':>6s} {'busy':>5s} {'q_te':>5s} "
           f"{'q_be':>5s} {'grace':>5s} {'preempts':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for i in idx:
        lines.append(
            f"{series.t[i]:8d} {series.utilization[i]:6.2f} "
            f"{series.busy_nodes[i]:5d} {series.queue_depth_te[i]:5d} "
            f"{series.queue_depth_be[i]:5d} {series.in_grace[i]:5d} "
            f"{series.cum_preemptions[i]:8d}")
    return "\n".join(lines)
