"""Canonical scheduler-event schema shared by both engines.

One event vocabulary (DESIGN.md §8) for the whole repo: the reference
simulator records :class:`Event` rows through its driver hooks, the
JAX engine appends the same rows to an in-jit ring buffer
(``sim_jax.State.ev_buf``, decoded by ``obs.ring.decode_ring``), and
every exporter / time-series / decomposition consumer downstream
speaks only this schema. Trace parity — reference events == decoded
JAX events, exactly, per (scenario × policy × time mode) — is the
event-level form of the engines' result-parity contract.

Event codes (``code``), with their ``aux`` meaning:

  ==============  ===========================================  =========
  code            emitted when                                 aux
  ==============  ===========================================  =========
  SUBMIT          job enters its queue lane on arrival         --
  START           first placement of a job                     --
  PREEMPT_SIGNAL  victim signalled; grace period begins        te job
  GRACE_EXPIRE    a GP>0 grace period ran out (before VACATE)  --
  VACATE          victim's resources freed                     te job
  REQUEUE         victim re-enters the TOP of its lane         --
  RESUME          placement of a previously-vacated victim     --
  FINISH          job completed (tick semantics: t+1)          --
  BACKFILL        marker after a placement that skipped ahead  n skipped
  ==============  ===========================================  =========

``t`` is the scheduling tick of the transition; ``job`` the integer
job id; ``nodes`` the placement node-set — recorded ONLY on
START / RESUME (release sites are implied by the preceding placement).
The queue *lane* is derived, not stored: TE lane iff the job is TE
and the policy is preemptive.

Ordering contract (both engines append in exactly this order):
within one tick — SUBMIT (job-index order), then grace expiries
(GRACE_EXPIRE / VACATE / REQUEUE grouped per job, job-index order),
then the schedule pass (TE lane, then BE lane, placements and signals
in pass order), then FINISH rows stamped ``t+1`` (job-index order).
Timestamps are therefore non-decreasing, with FINISH(t) rows
preceding SUBMIT(t) rows of the next tick.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

# Canonical event codes. Stable small ints: they are serialized into
# the JAX ring buffer and into CSV exports.
SUBMIT = 0
START = 1
PREEMPT_SIGNAL = 2
GRACE_EXPIRE = 3
VACATE = 4
REQUEUE = 5
RESUME = 6
FINISH = 7
BACKFILL = 8

EVENT_NAMES: Tuple[str, ...] = (
    "SUBMIT", "START", "PREEMPT_SIGNAL", "GRACE_EXPIRE", "VACATE",
    "REQUEUE", "RESUME", "FINISH", "BACKFILL")
N_CODES = len(EVENT_NAMES)

# Codes that carry a node-set (placements only; everything else
# implies its nodes from the preceding placement of the same job).
PLACEMENT_CODES = (START, RESUME)
# Codes that release the job's current placement.
RELEASE_CODES = (VACATE, FINISH)


@dataclass(frozen=True)
class Event:
    """One canonical scheduler event.

    ``aux`` is code-dependent (see module docstring); -1 means "none".
    ``nodes`` is the sorted placement node tuple for START / RESUME
    and empty otherwise.
    """
    t: int
    code: int
    job: int
    aux: int = -1
    nodes: Tuple[int, ...] = ()

    @property
    def name(self) -> str:
        return (EVENT_NAMES[self.code] if 0 <= self.code < N_CODES
                else f"?{self.code}")

    def as_tuple(self):
        return (self.t, self.code, self.job, self.aux, self.nodes)

    def render(self) -> str:
        s = f"{self.name} t={self.t} job={self.job}"
        if self.code in (PREEMPT_SIGNAL, VACATE) and self.aux >= 0:
            s += f" te={self.aux}"
        elif self.code == BACKFILL:
            s += f" skipped={self.aux}"
        elif self.aux != -1:
            s += f" aux={self.aux}"
        if self.nodes:
            s += f" nodes={'+'.join(str(n) for n in self.nodes)}"
        return s


def render_preemption(ev) -> str:
    """A reference ``PreemptionEvent`` rendered in the schema's
    vocabulary (``assert_result_parity`` divergence messages)."""
    s = (f"PREEMPT_SIGNAL t={ev.signal_time} job={ev.job} "
         f"te={ev.te_job}")
    s += (f" | VACATE t={ev.vacate_time}" if ev.vacate_time >= 0
          else " | VACATE pending")
    s += (f" | RESUME t={ev.resume_time}" if ev.resume_time >= 0
          else " | RESUME pending")
    return s


@dataclass
class _JobTrack:
    submitted: bool = False
    placed: bool = False          # currently holds nodes
    queued: bool = False
    in_grace: bool = False
    finished: bool = False
    ever_vacated: bool = False


def validate_events(events: Sequence[Event], n_jobs: Optional[int] = None,
                    n_nodes: Optional[int] = None) -> None:
    """Schema validation: codes in range, timestamps non-decreasing,
    and the per-job lifecycle legal (SUBMIT first; placements only
    from the queue; RESUME only after a vacate; at most one FINISH and
    nothing after it). Raises ``ValueError`` naming the first
    offending event index."""
    tracks: dict = {}
    last_t = None
    for i, ev in enumerate(events):
        def bad(msg, ev=ev, i=i):
            raise ValueError(f"event {i} [{ev.render()}]: {msg}")
        if not (0 <= ev.code < N_CODES):
            bad(f"unknown code {ev.code}")
        if ev.t < 0:
            bad("negative timestamp")
        if last_t is not None and ev.t < last_t:
            bad(f"timestamp decreases ({last_t} -> {ev.t})")
        last_t = ev.t
        if n_jobs is not None and not (0 <= ev.job < n_jobs):
            bad(f"job id out of range [0, {n_jobs})")
        if n_nodes is not None and any(not (0 <= n < n_nodes)
                                       for n in ev.nodes):
            bad(f"node id out of range [0, {n_nodes})")
        tr = tracks.setdefault(ev.job, _JobTrack())
        if tr.finished:
            bad("event after FINISH")
        if ev.code == SUBMIT:
            if tr.submitted:
                bad("second SUBMIT")
            tr.submitted, tr.queued = True, True
            continue
        if not tr.submitted:
            bad("event before SUBMIT")
        if ev.code in PLACEMENT_CODES:
            if not tr.queued or tr.placed:
                bad("placement of a non-queued job")
            if not ev.nodes:
                bad("placement without a node-set")
            if ev.code == RESUME and not tr.ever_vacated:
                bad("RESUME before any VACATE")
            if ev.code == START and tr.ever_vacated:
                bad("START after a VACATE (should be RESUME)")
            tr.placed, tr.queued = True, False
        elif ev.code == PREEMPT_SIGNAL:
            if not tr.placed:
                bad("signal on a non-placed job")
            tr.in_grace = True
        elif ev.code == GRACE_EXPIRE:
            if not tr.in_grace:
                bad("GRACE_EXPIRE without a pending signal")
        elif ev.code == VACATE:
            if not tr.in_grace:
                bad("VACATE without a pending signal")
            tr.placed, tr.in_grace, tr.ever_vacated = False, False, True
        elif ev.code == REQUEUE:
            if tr.placed or tr.queued:
                bad("REQUEUE of a placed/queued job")
            tr.queued = True
        elif ev.code == FINISH:
            if not tr.placed:
                bad("FINISH of a non-running job")
            tr.placed, tr.finished = False, True
        elif ev.code == BACKFILL:
            if not tr.placed:
                bad("BACKFILL marker without a placement")


def events_of_job(events: Iterable[Event], job: int) -> List[Event]:
    return [e for e in events if e.job == job]
