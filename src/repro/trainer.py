"""Train-step factory: value_and_grad + AdamW + gradient accumulation.

Microbatching (``microbatches > 1``) trades wall-clock for activation
memory: the global batch is split along the batch axis and a lax.scan
accumulates gradients, so the stored-activation footprint per layer drops
by the microbatch factor. The big-arch plans (nemotron, mistral-large)
rely on this to fit train_4k on a pod.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, rng) -> dict:
    params = models.init(cfg, rng)
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


def abstract_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig) -> dict:
    """ShapeDtypeStruct train state for dry-run lowering."""
    params = models.abstract_params(cfg)
    mdt = jnp.dtype(opt_cfg.moment_dtype)
    mom = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, mdt), params)
    return {"params": params,
            "opt": {"m": mom, "v": mom,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def _split_micro(batch: dict, m: int) -> dict:
    def sp(x):
        b = x.shape[0]
        assert b % m == 0, (b, m)
        return x.reshape((m, b // m) + x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """-> train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return models.loss_fn(cfg, params, batch)

    def train_step(state: dict, batch: dict):
        params = state["params"]
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = _split_micro(batch, microbatches)

            def acc_fn(carry, mb):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / microbatches,
                    acc, g)
                return (acc, lsum + l / microbatches), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            from repro.models import common as _c
            (grads, loss), _ = _c.scan(
                acc_fn, (zero, jnp.zeros((), jnp.float32)), micro)
        new_params, new_opt = adamw_update(grads, state["opt"], params,
                                           opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32),
                   "step": new_opt["step"]}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
