"""List top collectives (bytes x loop multiplicity) for one dry-run pair."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
sys.path.insert(0, "src")
import jax
from repro import models, trainer
from repro.configs import INPUT_SHAPES
from repro.launch.dryrun import variant_config
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rf
from repro.optim import AdamWConfig
from repro.sharding import plans

arch, shape_name = sys.argv[1], sys.argv[2]
cfg = variant_config(arch, shape_name)
shape = INPUT_SHAPES[shape_name]
mesh = make_production_mesh(multi_pod=False)
plan = plans.arch_plan(cfg, shape, mesh)
ocfg = AdamWConfig(moment_dtype=plan.opt_dtype)

if shape.kind == "train":
    state_abs = trainer.abstract_train_state(cfg, ocfg)
    batch_abs = models.input_specs(cfg, shape.global_batch, shape.seq_len, "train")
    state_sh = plans.train_state_sharding(cfg, plan, mesh, state_abs)
    batch_sh = plans.batch_sharding(batch_abs, plan, mesh)
    fn = trainer.make_train_step(cfg, ocfg, plan.microbatches)
    with mesh:
        compiled = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                           donate_argnums=(0,)).lower(state_abs, batch_abs).compile()
else:
    params_abs = models.abstract_params(cfg)
    cache_abs = models.init_decode_cache(cfg, shape.global_batch, shape.seq_len, abstract=True)
    tok_abs = models.input_specs(cfg, shape.global_batch, shape.seq_len, "decode")
    p_sh = plans.param_sharding(cfg, plan, mesh)
    c_sh = plans.cache_sharding(cfg, plan, mesh, cache_abs)
    t_sh = plans.batch_sharding(tok_abs, plan, mesh)
    def decode_fn(params, cache, batch):
        return models.serve_step(cfg, params, cache, batch["tokens"])
    with mesh:
        compiled = jax.jit(decode_fn, in_shardings=(p_sh, c_sh, t_sh),
                           donate_argnums=(1,)).lower(params_abs, cache_abs, tok_abs).compile()

txt = compiled.as_text()
# reuse the roofline parser internals but keep per-op detail
import collections
comps = {}
current = None
for line in txt.splitlines():
    m = rf._COMP_RE.match(line.strip()) if "{" in line and "->" in line else None
    if m and not line.strip().startswith("ROOT"):
        current = m.group(1); comps[current] = []
    elif current is not None:
        comps[current].append(line)
    if line.strip() == "}": current = None
entry = None
for name in comps:
    if "main" in name or name.startswith("jit_"): entry = entry or name
if entry is None: entry = next(iter(comps))
mult = {n: 0.0 for n in comps}; mult[entry] = 1.0
edges = []
for name, lines in comps.items():
    for line in lines:
        wm = rf._WHILE_RE.search(line)
        if wm:
            trip = 1
            tm = rf._TRIP_RE.search(line)
            if tm: trip = int(tm.group(1))
            edges.append((name, wm.group(2), float(trip)))
            edges.append((name, wm.group(1), float(trip)+1)); continue
        cm = rf._CALL_RE.search(line)
        if cm: edges.append((name, cm.group(1), 1.0))
for _ in range(32):
    new = {n: 0.0 for n in comps}; new[entry] = 1.0
    for p, c, f in edges:
        if p in mult and c in new: new[c] += mult[p]*f
    if all(abs(new[k]-mult[k])<1e-9 for k in mult): break
    mult = new
rows = []
for name, lines in comps.items():
    m = mult.get(name, 1.0)
    for line in lines:
        for kind, factor in rf._COLLECTIVE_FACTOR.items():
            if re.search(rf"=\s+\S+\s+{kind}(-start)?\(", line):
                b = rf._shape_bytes(line.split("=",1)[1].split("(",1)[0])
                rows.append((b*factor*m, kind, m, line.strip()[:180]))
                break
rows.sort(reverse=True)
total = sum(r[0] for r in rows)
print(f"TOTAL collective bytes/dev: {total/1e9:.2f} GB  ({len(rows)} ops)")
for b, kind, m, line in rows[:15]:
    print(f"{b/1e9:8.3f} GB  x{m:5.0f}  {kind:18s} {line[:140]}")
