"""Measure one (arch x shape): roofline terms + top collectives."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "src")
from repro.launch.dryrun import lower_one
from repro.launch.mesh import make_production_mesh

arch, shape = sys.argv[1], sys.argv[2]
mesh = make_production_mesh(multi_pod=False)
res = lower_one(arch, shape, mesh)
r = res["roofline"]; m = res["memory"]; c = res["collectives"]
print(f"{arch} x {shape}:")
print(f"  terms: compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
      f"collective={r['collective_s']*1e3:.2f}ms dominant={r['dominant']}")
print(f"  peak={m['peak_gb']:.1f}GB temp={m['temp_gb']:.1f}GB coll_total={c['total']/1e9:.2f}GB/dev")
print(f"  hlo_flops_raw={res['cost_analysis']['flops']:.3e}")
for d in res["top_collectives"]:
    print(f"   {d['gb']:8.3f}GB x{d['mult']:5.0f} {d['kind']:15s} {d['op'][:110]}")
