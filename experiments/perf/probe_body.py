"""Inspect while-body dot shapes: is the scan body TP-sharded or replicated?"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
sys.path.insert(0, "src")
import jax
from repro import models, trainer
from repro.configs import INPUT_SHAPES
from repro.launch.dryrun import variant_config
from repro.launch.mesh import make_production_mesh
from repro.optim import AdamWConfig
from repro.sharding import plans

arch, shape_name = sys.argv[1], sys.argv[2]
cfg = variant_config(arch, shape_name)
shape = INPUT_SHAPES[shape_name]
mesh = make_production_mesh(multi_pod=False)
plan = plans.arch_plan(cfg, shape, mesh)
ocfg = AdamWConfig(moment_dtype=plan.opt_dtype)
state_abs = trainer.abstract_train_state(cfg, ocfg)
batch_abs = models.input_specs(cfg, shape.global_batch, shape.seq_len, "train")
state_sh = plans.train_state_sharding(cfg, plan, mesh, state_abs)
batch_sh = plans.batch_sharding(batch_abs, plan, mesh)
fn = trainer.make_train_step(cfg, ocfg, plan.microbatches)
with mesh:
    compiled = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                       donate_argnums=(0,)).lower(state_abs, batch_abs).compile()
txt = compiled.as_text()
print("raw op counts:", {k: len(re.findall(k + r"\(", txt)) for k in
      ["all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute", "dynamic-slice", "while"]})
# find the biggest dots
dots = re.findall(r"= (\S+) dot\(", txt)
from collections import Counter
print("top dot result shapes:", Counter(dots).most_common(12))
